//! ABL — design ablations for the choices the paper argues in §2:
//!
//! 1. **Deque memory-order style** (§2.1): fence-free (adopted,
//!    Filament-style) vs `atomic_thread_fence`-based (Lê et al. /
//!    Taskflow style) — owner push/pop throughput and steal throughput
//!    under contention. The paper's claim is that the fence-free form
//!    is cleaner under TSan *without* losing performance; this bench
//!    shows the performance side.
//! 2. **Injector choice**: Mutex<VecDeque> vs lock-free SegQueue under
//!    external submission storms (the one path where it could matter).
//! 3. **Inline continuation** (§2.2): first-ready-successor-inline vs
//!    resubmit-everything, on chain and wavefront graphs.
//! 4. **Spin rounds before parking**: wakeup latency vs CPU trade.
//! 5. **Hot-path optimizations (PR 1)**: the three independently
//!    toggleable scheduler optimizations — inline task storage
//!    (`PoolConfig::inline_tasks`), batched stealing
//!    (`PoolConfig::steal_batch`), and batched/throttled wakeups
//!    (`PoolConfig::batched_wakeups`) — each switched off against the
//!    all-on baseline, on a fan-out (binary tree), a chain, and a
//!    submission-storm workload.
//! 6. **Graph re-run modes (PR 2)**: the CSR topology arena, run-state
//!    reuse, and caller-assisted execution toggles (`RunOptions`) live
//!    in `benches/graph_rerun.rs` (report "ABL-6"), next to the
//!    re-run latency workload they optimize.
//! 7. **Sharded submission (PR 5, "ABL-8")**: flat single-injector
//!    pool vs sharded pools under a many-producer submission storm —
//!    the workload the per-shard injector lanes exist for — plus a
//!    shard-imbalance probe from the per-shard depth snapshot.
//! 8. **Observability cost (PR 9, "ABL-9")**: the default
//!    configuration (flight recorder + histograms on, recording task
//!    start/end events, duration samples, and profile spans on every
//!    node) against a pool with both toggled off — the claim under
//!    test is that always-on telemetry costs a few ns per node, so
//!    the two arms must be near parity.
//!
//! Knobs: `BENCH_FAST=1`, `THREADS`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::RunOptions;
use scheduling::pool::injector::{Injector, MutexInjector, SegQueue};
use scheduling::pool::{deque, fence_deque, PoolConfig, Steal, ThreadPool};
use scheduling::workloads::Dag;

fn main() {
    let opts = BenchOptions::from_env();
    deque_ablation(&opts);
    injector_ablation(&opts);
    inline_ablation(&opts);
    spin_ablation(&opts);
    hot_path_ablation(&opts);
    sharding_ablation(&opts);
    obs_ablation(&opts);
}

/// ABL-9: cost of always-on observability (PR 9). The default pool
/// records two flight events, one histogram sample, and three span
/// stores per node; the off arm strips the recorder and the
/// histograms (profiles still ride the dynamic-rank sampling, which
/// both arms share). Fine-grained graphs maximize the per-node record
/// overhead relative to useful work — the worst case for the claim.
fn obs_ablation(opts: &BenchOptions) {
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let mut report = Report::new(
        "ABL-9 observability cost (PR 9)",
        format!(
            "flight recorder + histograms on (default) vs both off; \
             per-node record path under fine-grained graphs; {threads} threads"
        ),
    );

    let variants: [(&str, PoolConfig); 2] = [
        ("obs-on", PoolConfig::default()),
        (
            "obs-off",
            PoolConfig { flight_recorder: false, histograms: false, ..PoolConfig::default() },
        ),
    ];

    for (label, config) in variants {
        let pool = ThreadPool::with_config(PoolConfig { num_threads: threads, ..config.clone() });

        // Fan-out: many tiny nodes in parallel — record-path pressure
        // from every worker at once.
        let (mut g, _c) = Dag::binary_tree(13).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("btree(d=13)", label, summary);

        // Chain: the inline-continuation path, one record pair per
        // link, serialized — per-event cost with no parallel slack.
        let (mut g, _c) = Dag::linear_chain(16_384).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("chain(16384)", label, summary);

        // Wavefront: the steady mixed steal/submit regime.
        let (mut g, _c) = Dag::wavefront(48).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("wf(48x48)", label, summary);
        eprintln!("  obs variant {label} done");
    }

    report.print();
    record_json("ablations_obs", "wall", threads, &report);

    for param in ["btree(d=13)", "chain(16384)", "wf(48x48)"] {
        if let Some(r) = report.speedup(param, "obs-on", "obs-off") {
            println!(
                "SHAPE obs-near-parity@{param}: {r:.2}x {}",
                if (0.8..=1.25).contains(&r) { "PASS" } else { "CHECK" }
            );
        }
    }
}

/// ABL-8: sharded submission & locality-aware stealing (PR 5). A
/// many-producer storm — P external threads each firing a stream of
/// independent `submit`s — against the same pool in flat
/// (`shard_size >= num_threads`, the pre-PR 5 single injector) and
/// sharded configurations, plus a graph-workload sanity series to show
/// sharding does not tax the §2.2 fan-out path. Also reports the
/// per-shard depth imbalance sampled mid-storm (satellite: the storm
/// bench must report shard imbalance, not just throughput).
fn sharding_ablation(opts: &BenchOptions) {
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let producers = threads.max(2);
    const PER: usize = 2_500;
    let mut report = Report::new(
        "ABL-8 sharded submission & locality-aware stealing (PR 5)",
        format!(
            "{producers} producer threads x {PER} tasks through submit(); \
             flat = single injector (pre-PR 5), shard=N = N workers per shard; {threads} threads"
        ),
    );

    let variants: [(&str, usize); 3] = [
        ("flat", usize::MAX), // shard_size >= num_threads ⇒ 1 shard
        ("shard=2", 2),
        ("shard=1", 1),
    ];

    for (label, shard_size) in variants {
        let pool = Arc::new(ThreadPool::with_config(PoolConfig {
            num_threads: threads,
            shard_size,
            ..PoolConfig::default()
        }));

        // Many-producer submission storm: the injector-contention path.
        let p = pool.clone();
        let summary = bench_wall(opts, move || {
            let count = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..producers {
                let (pool, count) = (p.clone(), count.clone());
                handles.push(std::thread::spawn(move || {
                    for _ in 0..PER {
                        let c = count.clone();
                        pool.submit(move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            p.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), producers * PER);
        });
        report.push("storm", label, summary);

        // Imbalance probe: wedge-free mid-storm sampling — fire the
        // storm once more and sample depths while producers run.
        {
            let count = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..producers {
                let (pool, count) = (pool.clone(), count.clone());
                handles.push(std::thread::spawn(move || {
                    for _ in 0..PER {
                        let c = count.clone();
                        pool.submit(move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                }));
            }
            let mut max_imbalance = 0.0f64;
            let mut max_depth = 0usize;
            while count.load(Ordering::Relaxed) < producers * PER {
                let snap = pool.metrics();
                max_imbalance = max_imbalance.max(snap.shard_imbalance());
                max_depth =
                    max_depth.max(snap.shards.iter().map(|s| s.queued()).sum::<usize>());
                std::thread::yield_now();
            }
            for h in handles {
                h.join().unwrap();
            }
            pool.wait_idle();
            let snap = pool.metrics().total();
            println!(
                "SHARD imbalance@{label}: max={max_imbalance:.2} peak-depth={max_depth} \
                 remote-injector-pops={} remote-steals={}",
                snap.remote_injector_pops, snap.remote_steals
            );
        }

        // Graph sanity: sharding must not tax worker-local fan-out.
        let (mut g, _c) = Dag::binary_tree(12).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("btree(d=12)", label, summary);
        eprintln!("  sharding variant {label} done");
    }

    report.print();
    record_json("ablations_sharding", "wall", threads, &report);

    if let Some(r) = report.speedup("storm", "shard=2", "flat") {
        println!("SHAPE sharded-storm-wins: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
    }
    if let Some(r) = report.speedup("btree(d=12)", "shard=2", "flat") {
        println!(
            "SHAPE sharding-graph-parity: {r:.2}x {}",
            if (0.8..=1.25).contains(&r) { "PASS" } else { "CHECK" }
        );
    }
}

/// ABL-5: each PR-1 hot-path optimization toggled off individually
/// (and all off together) against the default all-on configuration.
fn hot_path_ablation(opts: &BenchOptions) {
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let mut report = Report::new(
        "ABL-5 hot-path optimizations (PR 1)",
        format!(
            "inline task storage / batched stealing / batched wakeups, each toggled \
             independently; {threads} threads"
        ),
    );

    let variants: [(&str, PoolConfig); 5] = [
        ("all-on", PoolConfig::default()),
        ("no-inline-tasks", PoolConfig { inline_tasks: false, ..PoolConfig::default() }),
        ("no-steal-batch", PoolConfig { steal_batch: false, ..PoolConfig::default() }),
        ("no-batched-wake", PoolConfig { batched_wakeups: false, ..PoolConfig::default() }),
        // NOTE: "all-off" disables the three *toggleable* optimizations
        // (task inlining, batched stealing, batched wakeups). It is not
        // a full seed reproduction: the sharded pending counters and
        // throttled idle wakeups are structural and always on.
        (
            "all-off",
            PoolConfig {
                inline_tasks: false,
                steal_batch: false,
                batched_wakeups: false,
                ..PoolConfig::default()
            },
        ),
    ];

    for (label, config) in variants {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: threads,
            ..config.clone()
        });

        // Fan-out graph: exercises steal batching + wake batching.
        let (mut g, _c) = Dag::binary_tree(13).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("btree(d=13)", label, summary);

        // Chain: inline-continuation heavy, isolates task-cell cost.
        let (mut g, _c) = Dag::linear_chain(16_384).to_task_graph(0);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push("chain(16384)", label, summary);

        // Submission storm: plain closures through submit(), the
        // RawTask allocation path with recursive respawning.
        let summary = bench_wall(opts, || {
            let count = Arc::new(AtomicUsize::new(0));
            for _ in 0..2_000 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), 2_000);
        });
        report.push("submit(2000)", label, summary);
        eprintln!("  hot-path variant {label} done");
    }

    report.print();
    record_json("ablations_hot_path", "wall", threads, &report);

    for param in ["btree(d=13)", "chain(16384)", "submit(2000)"] {
        if let Some(r) = report.speedup(param, "all-on", "all-off") {
            println!(
                "SHAPE hot-path-wins@{param}: {r:.2}x {}",
                if r >= 1.0 { "PASS" } else { "CHECK" }
            );
        }
    }
}

fn deque_ablation(opts: &BenchOptions) {
    let mut report = Report::new(
        "ABL-1 deque memory-order style",
        "per-op cost; owner = push+pop pairs, steal = cross-thread under owner churn",
    );
    const OPS: usize = 10_000;

    // Owner-only throughput.
    let (w, _s) = deque::<usize>(256);
    let summary = bench_wall(opts, || {
        for i in 0..OPS {
            w.push(i);
        }
        for _ in 0..OPS {
            w.pop().unwrap();
        }
    });
    report.push("owner push+pop", "fence-free", summary);

    let (fw, _fs) = fence_deque::<usize>(256);
    let summary = bench_wall(opts, || {
        for i in 0..OPS {
            fw.push(i);
        }
        for _ in 0..OPS {
            fw.pop().unwrap();
        }
    });
    report.push("owner push+pop", "fence-based", summary);

    // Steal throughput under concurrent owner churn. One macro per
    // deque flavor (the two have identical shapes but distinct types).
    macro_rules! churn_bench {
        ($mk:expr) => {
            bench_wall(opts, || {
                let (w, s) = $mk;
                let stop = Arc::new(AtomicBool::new(false));
                let stolen = Arc::new(AtomicUsize::new(0));
                let thief = {
                    let (s, stop, stolen) = (s.clone(), stop.clone(), stolen.clone());
                    std::thread::spawn(move || {
                        while !stop.load(Ordering::Acquire) {
                            if matches!(s.steal(), Steal::Success(_)) {
                                stolen.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                };
                for i in 0..20_000usize {
                    w.push(i);
                    if i % 2 == 0 {
                        let _ = w.pop();
                    }
                }
                stop.store(true, Ordering::Release);
                thief.join().unwrap();
            })
        };
    }

    let summary = churn_bench!(deque::<usize>(256));
    report.push("steal under churn", "fence-free", summary);

    let summary = churn_bench!(fence_deque::<usize>(256));
    report.push("steal under churn", "fence-based", summary);

    report.print();
    record_json("ablations_deque", "wall", 2, &report);
    if let Some(r) = report.speedup("owner push+pop", "fence-free", "fence-based") {
        println!("SHAPE fence-free-parity-owner: {r:.2}x {}", if (0.5..=2.0).contains(&r) { "PASS" } else { "CHECK" });
    }
}

fn injector_ablation(opts: &BenchOptions) {
    let mut report = Report::new(
        "ABL-2 injector implementation",
        "2 producers + 2 consumers, 20k items/iteration",
    );
    fn storm(q: Arc<dyn Injector<usize>>) {
        const PER: usize = 10_000;
        let done = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for p in 0..2 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    q.push(p * PER + i);
                }
            }));
        }
        for _ in 0..2 {
            let (q, done) = (q.clone(), done.clone());
            handles.push(std::thread::spawn(move || {
                while done.load(Ordering::Acquire) < 2 * PER {
                    if q.pop().is_some() {
                        done.fetch_add(1, Ordering::AcqRel);
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    let q: Arc<dyn Injector<usize>> = Arc::new(MutexInjector::new());
    let summary = bench_wall(opts, || storm(q.clone()));
    report.push("mpmc storm", "mutex-vecdeque", summary);

    let q: Arc<dyn Injector<usize>> = Arc::new(SegQueue::new());
    let summary = bench_wall(opts, || storm(q.clone()));
    report.push("mpmc storm", "lockfree-segqueue", summary);

    report.print();
    record_json("ablations_injector", "wall", 4, &report);
}

fn inline_ablation(opts: &BenchOptions) {
    let mut report = Report::new(
        "ABL-3 inline continuation (paper §2.2)",
        "same graphs, inline first ready successor vs resubmit all; 2 threads",
    );
    let pool = ThreadPool::new(2);
    for (dag, param) in [
        (Dag::linear_chain(16_384), "chain(16384)"),
        (Dag::wavefront(48), "wf(48x48)"),
        (Dag::binary_tree(12), "btree(d=12)"),
    ] {
        for (inline, label) in [(true, "inline"), (false, "resubmit-all")] {
            let (mut g, _c) = dag.to_task_graph(0);
            let summary = bench_wall(opts, || {
                g.run_with_options(&pool, RunOptions::inline(inline)).unwrap();
            });
            report.push(param, label, summary);
        }
        eprintln!("  {param} done");
    }
    report.print();
    record_json("ablations_inline", "wall", 2, &report);
    if let Some(r) = report.speedup("chain(16384)", "inline", "resubmit-all") {
        println!("SHAPE inline-wins-on-chain: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
}

fn spin_ablation(opts: &BenchOptions) {
    let mut report = Report::new(
        "ABL-4 spin rounds before parking",
        "wavefront(32) wall time at varying spin_rounds; 2 threads",
    );
    let dag = Dag::wavefront(32);
    for spin in [0u32, 2, 8, 32] {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            spin_rounds: spin,
            ..PoolConfig::default()
        });
        let (mut g, _c) = dag.to_task_graph(64);
        let summary = bench_wall(opts, || {
            g.run(&pool).unwrap();
        });
        report.push(format!("spin={spin}"), "scheduling", summary);
    }
    report.print();
    record_json("ablations_spin", "wall", 2, &report);
}
