//! CANCEL / OVERLOAD — run-lifecycle robustness benches (PR 6).
//!
//! Two reports land in the ledger (`BENCH_pr7.json` as of PR 7):
//!
//! * **CANCEL time-to-cancel (PR 6)** — a sealed 10 000-node diamond
//!   chain: run to completion, aborted at launch by a pre-cancelled
//!   token (the abort-path floor: one flag check per skipped node and
//!   the normal pending-counter cascade), and cancelled midway through
//!   an async run (launch → wait for ~¼ of the nodes → `cancel()` →
//!   harvest). The cancel series bound how long a caller waits for
//!   quiescence after giving up on a run; both must come in well under
//!   running the graph to completion.
//! * **OVERLOAD admission goodput (PR 6)** — a fleet of 4×`max`
//!   64-node graphs kept in flight per round (4× oversubmription of
//!   the admission budget): an unlimited pool vs. one with
//!   `max_inflight_runs = threads`. Admission-on paces submission (the
//!   blocking launch parks on the budget eventcount), so the series
//!   measures the throughput cost of backpressure on identical total
//!   work — plus the pool's own `shed_runs`/lifecycle counters printed
//!   for the record.
//!
//! Knobs: `RERUNS` (default 20), `THREADS` (default 2), `BENCH_FAST=1`
//! (drops RERUNS to 5).

use std::sync::atomic::Ordering;

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::{CancelToken, GraphError, RunOptions};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::workloads::{Dag, MultiRun};

fn main() {
    let opts = BenchOptions::from_env();
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let reruns: usize = std::env::var("RERUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 5 } else { 20 });
    let pool = ThreadPool::new(threads);

    // ---- CANCEL: time-to-cancel a 10k-node run ---------------------
    let nodes = 10_000usize;
    let mut report = Report::new(
        "CANCEL time-to-cancel (PR 6)",
        format!(
            "sealed 10000-node diamond chain, {reruns} runs per sample, {threads} threads; \
             complete = run to the end, cancel-at-launch = pre-cancelled token (abort floor), \
             cancel-midway = run_async, spin until ~25% of nodes executed, cancel(), harvest"
        ),
    );
    let param = format!("diamond{nodes} x{reruns}");

    let (mut g, counter) = Dag::diamond_chain(nodes / 4).to_task_graph(0);
    g.run(&pool).unwrap(); // warm: sizes queues, builds run state
    let summary = bench_wall(&opts, || {
        for _ in 0..reruns {
            g.run(&pool).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= nodes * reruns);
    report.push(param.clone(), "complete", summary);

    let token = CancelToken::new();
    token.cancel();
    let at_launch = RunOptions::new().cancel_token(token);
    let summary = bench_wall(&opts, || {
        for _ in 0..reruns {
            let r = g.run_with_options(&pool, at_launch.clone());
            assert!(matches!(r, Err(GraphError::Cancelled)));
        }
    });
    report.push(param.clone(), "cancel-at-launch", summary);

    // Midway: the handle cancels a live run. The node count at the
    // cancel point is approximate by design (workers race the flag),
    // so the run may occasionally finish first — accept both results
    // and measure launch → quiescent-harvest wall time either way.
    g.run(&pool).unwrap(); // re-warm after the aborted batch
    let summary = bench_wall(&opts, || {
        for _ in 0..reruns {
            let baseline = counter.load(Ordering::Relaxed);
            let mut handle = g.run_async(&pool).unwrap();
            while counter.load(Ordering::Relaxed) - baseline < nodes / 4 && !handle.is_done() {
                std::hint::spin_loop();
            }
            handle.cancel();
            match handle.wait() {
                Ok(()) | Err(GraphError::Cancelled) => {}
                Err(e) => panic!("unexpected cancel-midway result: {e}"),
            }
        }
    });
    report.push(param.clone(), "cancel-midway", summary);

    report.print();
    record_json("cancel_latency", "wall", threads, &report);

    for (series, shape) in
        [("cancel-at-launch", "cancel-floor-wins"), ("cancel-midway", "cancel-midway-wins")]
    {
        if let Some(r) = report.speedup(&param, series, "complete") {
            println!("SHAPE {shape}@{param}: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
        }
    }

    // ---- OVERLOAD: goodput under 4x oversubscription ---------------
    let fleet = 4 * threads.max(1);
    let rounds = (reruns * 5).max(10);
    let mut report = Report::new(
        "OVERLOAD admission goodput (PR 6)",
        format!(
            "{fleet} 64-node sealed diamond chains in flight per round ({rounds} rounds per \
             sample), {threads} threads; admission-off = unlimited pool, admission-on = \
             max_inflight_runs={threads} (blocking launches park on the budget eventcount); \
             identical total node executions per series"
        ),
    );
    let param = format!("fleet{fleet} x{rounds}");

    let mut mr = MultiRun::new(fleet, 16, 0);
    mr.run_round(&pool).unwrap(); // warm per fleet
    let summary = bench_wall(&opts, || {
        mr.run_rounds(&pool, rounds).unwrap();
    });
    assert!(mr.verify_exactly_once(), "admission-off: exactly-once violated");
    report.push(param.clone(), "admission-off", summary);

    let gated = ThreadPool::with_config(PoolConfig {
        num_threads: threads,
        max_inflight_runs: threads,
        ..PoolConfig::default()
    });
    let mut mr = MultiRun::new(fleet, 16, 0);
    mr.run_round(&gated).unwrap();
    let summary = bench_wall(&opts, || {
        mr.run_rounds(&gated, rounds).unwrap();
    });
    assert!(mr.verify_exactly_once(), "admission-on: exactly-once violated");
    report.push(param.clone(), format!("admission-on(max={threads})"), summary);
    eprintln!("  admission-on pool after sweep:\n{}", gated.metrics());

    report.print();
    record_json("overload_admission", "wall", threads, &report);

    if let Some(r) = report.speedup(&param, &format!("admission-on(max={threads})"), "admission-off")
    {
        // Backpressure trades peak goodput for bounded queues; flag
        // only a collapse, not the expected small pacing cost.
        let verdict = if r >= 0.5 { "PASS" } else { "CHECK" };
        println!("SHAPE admission-pacing-cost@{param}: {r:.2}x {verdict}");
    }
}
