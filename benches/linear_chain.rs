//! GH-LC — linear chain: N tasks in a strict dependency chain.
//!
//! The degenerate graph for a scheduler: zero parallelism, pure
//! handoff cost. The paper's §2.2 inline-continuation rule makes the
//! whole chain run as ONE pool job on our executor; baselines resubmit
//! every node. Expected shape: scheduling (inline) ≫ countdown
//! executors, gap growing linearly with chain length.
//!
//! Knobs: `CHAIN_SIZES` (default 1024,8192,65536), `THREADS`,
//! `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::RunOptions;
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let sizes = env_list("CHAIN_SIZES", &[1024, 8192, 65536]);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let opts = BenchOptions::from_env();

    let mut report = Report::new(
        "GH-LC linear chain",
        format!(
            "strict chain of empty tasks; {threads} threads; 'scheduling' = §2.2 graph executor \
             (inline continuations; PR 2 caller assist means the bench thread also executes \
             nodes), 'scheduling-noassist' = same executor with the caller condvar-blocked \
             (THREADS-fair vs the countdown baselines), others = countdown resubmission"
        ),
    );

    for &n in &sizes {
        let dag = Dag::linear_chain(n);

        // Our pool, native graph executor (default modes: sealed CSR
        // topology, reused run state, caller assist).
        let pool = ThreadPool::new(threads);
        let (mut g, counter) = dag.to_task_graph(0);
        let summary = bench_wall(&opts, || {
            g.run(&pool).unwrap();
        });
        assert!(counter.load(std::sync::atomic::Ordering::Relaxed) >= n);
        report.push(format!("chain({n})"), "scheduling", summary);

        // Caller-assist off: isolates the PR 2 waiting-mode change so
        // the comparison against the (caller-blocked) countdown
        // baselines below stays apples-to-apples.
        let (mut g, _c) = dag.to_task_graph(0);
        let summary = bench_wall(&opts, || {
            g.run_with_options(&pool, RunOptions::new().caller_assist(false)).unwrap();
        });
        report.push(format!("chain({n})"), "scheduling-noassist", summary);

        // Countdown closures on the comparators (and on our pool, to
        // separate "inline continuation" from "pool quality").
        for name in ["scheduling", "taskflow", "mutex"] {
            let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
            let summary = bench_wall(&opts, || {
                assert_eq!(dag.run_countdown(&ex, 0), n);
            });
            report.push(format!("chain({n})"), format!("{}+countdown", ex.name()), summary);
        }
        eprintln!("  chain({n}) done");
    }

    report.print();
    record_json("linear_chain", "wall", threads, &report);

    let last = format!("chain({})", sizes[sizes.len() - 1]);
    if let Some(r) = report.speedup(&last, "scheduling", "scheduling+countdown") {
        println!(
            "SHAPE inline-beats-resubmit@{last}: {r:.2}x {}",
            if r > 1.0 { "PASS" } else { "FAIL" }
        );
    }
    if let Some(r) = report.speedup(&last, "scheduling", "mutex-pool+countdown") {
        println!("SHAPE graph-beats-mutex@{last}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
}
