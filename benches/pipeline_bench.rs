//! E2E-2 — pipeline-parallel transformer-FFN inference (GPipe-style
//! schedule) on the paper's executor.
//!
//! Sweeps stage count × micro-batch count at 1/2/4 workers; every node
//! executes the `transformer_ffn_64` AOT executable. The interesting
//! shape: with microbatches ≥ stages the pipeline saturates and
//! per-node cost approaches the kernel dispatch floor; graph overhead
//! stays in the noise (the §2.2 executor's diagonal chains run inline).
//!
//! Requires `make artifacts`. Knobs: `PIPE_STAGES` (default 4),
//! `PIPE_MBS` (default 1,4,8), `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::bench_harness::{bench_wall, BenchOptions, Report};
use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, Registry, Runtime};
use scheduling::workloads::Pipeline;

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    if find_artifacts_dir().is_none() {
        eprintln!("SKIP pipeline bench: artifacts not built (run `make artifacts`)");
        return;
    }
    let stages: usize = std::env::var("PIPE_STAGES").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let mbs = env_list("PIPE_MBS", &[1, 4, 8]);
    let opts = BenchOptions::from_env();

    let runtime = Arc::new(Runtime::cpu().expect("PJRT CPU client"));
    let registry = Registry::open_default(runtime).expect("registry");
    let pipeline = Pipeline::new(&registry, stages).expect("pipeline setup");

    let mut report = Report::new(
        "E2E-2 pipeline-parallel FFN inference",
        format!(
            "{stages} stages x M microbatches of {}x{}; node = transformer_ffn_64 via PJRT; \
             output verified vs host oracle every iteration",
            Pipeline::BATCH,
            Pipeline::D
        ),
    );

    for &m in &mbs {
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let summary = bench_wall(&opts, || {
                pipeline.run(&pool, m, None).expect("pipeline run");
            });
            report.push(format!("mb={m}"), format!("graph-t{threads}"), summary);
            eprintln!("  mb={m} t={threads} done");
        }
    }

    report.print();

    // Per-node cost at saturation vs single microbatch.
    if let (Some(sat), Some(single)) = (report.mean_of("mb=8", "graph-t2"), report.mean_of("mb=1", "graph-t2")) {
        let per_node_sat = sat.as_secs_f64() / (stages as f64 * 8.0);
        let per_node_single = single.as_secs_f64() / stages as f64;
        println!(
            "SHAPE pipeline-amortizes: per-node {:.0}us (mb=8) vs {:.0}us (mb=1) {}",
            per_node_sat * 1e6,
            per_node_single * 1e6,
            if per_node_sat <= per_node_single * 1.5 { "PASS" } else { "CHECK" }
        );
    }
}
