//! FIG1 — Fig. 1 reproduction: fibonacci **wall time** per executor.
//!
//! The paper plots wall time of `fib(N)` (recursive, no memoization,
//! every call a task) for its pool vs Taskflow. We sweep N over all
//! in-crate executors. Expected shape (DESIGN.md §3): the two
//! work-stealing executors are within a small factor of each other;
//! the centralized mutex pool falls behind as task count grows;
//! thread-per-task is orders of magnitude slower (run only at small N).
//!
//! Knobs: `FIB_NS` (comma list, default 18,20,22,24), `THREADS`
//! (default 2), `BENCH_FAST=1` (fewer samples).

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::workloads::{fib_reference, fib_task_count, run_fib};

fn env_list(key: &str, default: &[u32]) -> Vec<u32> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let ns = env_list("FIB_NS", &[18, 20, 22, 24]);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let opts = BenchOptions::from_env();

    let mut report = Report::new(
        "FIG1 fibonacci wall time",
        format!(
            "recursive fib, no memoization; {threads} worker threads; 1-core container: \
             pool-vs-pool deltas measure per-task scheduling overhead (see EXPERIMENTS.md §Testbed)"
        ),
    );

    for &n in &ns {
        let expected = fib_reference(n);
        for name in ["scheduling", "taskflow", "mutex"] {
            let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
            let summary = bench_wall(&opts, || {
                assert_eq!(run_fib(&ex, n), expected);
            });
            report.push(format!("fib({n})"), ex.name(), summary);
            eprintln!("  fib({n}) {} done ({} tasks)", name, fib_task_count(n));
        }
        // Thread-per-task only at small N (it would take minutes above).
        if n <= 18 {
            let ex: Arc<dyn Executor> = executor_by_name("spawn", threads).unwrap();
            let summary = bench_wall(&opts, || {
                assert_eq!(run_fib(&ex, n), expected);
            });
            report.push(format!("fib({n})"), ex.name(), summary);
        }
    }

    report.print();
    record_json("fib_wall", "wall", threads, &report);

    // Paper-shape checks (informational, printed for EXPERIMENTS.md).
    let last = format!("fib({})", ns[ns.len() - 1]);
    if let Some(r) = report.speedup(&last, "scheduling", "mutex-pool") {
        println!("SHAPE ws-beats-mutex@{last}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
    if let Some(r) = report.speedup(&last, "scheduling", "taskflow-like") {
        println!(
            "SHAPE parity-with-taskflow@{last}: {r:.2}x {}",
            if (0.5..=2.0).contains(&r) { "PASS (within 2x)" } else { "CHECK" }
        );
    }
    if let Some(r) = report.speedup("fib(18)", "scheduling", "spawn-per-task") {
        println!("SHAPE ws-beats-spawn@fib(18): {r:.1}x {}", if r > 10.0 { "PASS (>10x)" } else { "CHECK" });
    }
}
