//! GH-WF — wavefront: G×G grid, cell (i,j) waits on (i-1,j) and
//! (i,j-1).
//!
//! The classic dependency-bound pattern (DP tables, tiled Cholesky):
//! available parallelism ramps 1..G..1 along anti-diagonals, so the
//! scheduler must exploit parallelism the instant it appears. Swept at
//! two task granularities: empty bodies (pure scheduling) and
//! `WORK_STEPS` PRNG iterations (amortized regime, where all
//! reasonable executors converge — the paper's "in simple use cases
//! performance is comparable" claim from the other side).
//!
//! Knobs: `WF_SIZES` (default 16,32,64), `WORK_STEPS` (default 0,512),
//! `THREADS`, `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

fn env_list(key: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let sizes = env_list("WF_SIZES", &[16, 32, 64]);
    let works: Vec<u32> = env_list("WORK_STEPS", &[0, 512]).into_iter().map(|x| x as u32).collect();
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let opts = BenchOptions::from_env();

    let mut report = Report::new(
        "GH-WF wavefront",
        format!("GxG grid, (i,j) <- (i-1,j),(i,j-1); threads={threads}; work = PRNG steps per node"),
    );

    for &g_size in &sizes {
        let dag = Dag::wavefront(g_size);
        let n = dag.len();
        for &work in &works {
            let param = format!("wf({g_size}x{g_size},w={work})");

            let pool = ThreadPool::new(threads);
            let (mut g, _c) = dag.to_task_graph(work);
            let summary = bench_wall(&opts, || {
                g.run(&pool).unwrap();
            });
            report.push(&param, "scheduling", summary);

            for name in ["taskflow", "mutex"] {
                let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
                let summary = bench_wall(&opts, || {
                    assert_eq!(dag.run_countdown(&ex, work), n);
                });
                report.push(&param, ex.name(), summary);
            }
            eprintln!("  {param} done");
        }
    }

    report.print();
    record_json("wavefront_bench", "wall", threads, &report);

    let last0 = format!("wf({0}x{0},w=0)", sizes[sizes.len() - 1]);
    if let Some(r) = report.speedup(&last0, "scheduling", "mutex-pool") {
        println!("SHAPE wf-ws-beats-mutex@{last0}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
}
