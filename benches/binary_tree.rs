//! GH-BT — binary tree: fan-out over a complete binary tree of depth D
//! (`2^D - 1` empty tasks, parent precedes children).
//!
//! The maximal-fan-out counterpart to the linear chain: every node
//! unlocks two successors, so the §2.2 rule keeps one child inline and
//! pushes the other to the local deque where thieves pick it up — the
//! workload that exercises steal throughput. Expected shape: the
//! work-stealing executors beat the mutex pool and the gap grows with
//! depth.
//!
//! Knobs: `TREE_DEPTHS` (default 10,13,16), `THREADS`, `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

fn env_list(key: &str, default: &[u32]) -> Vec<u32> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let depths = env_list("TREE_DEPTHS", &[10, 13, 16]);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let opts = BenchOptions::from_env();

    let mut report = Report::new(
        "GH-BT binary tree",
        format!("complete binary tree fan-out, empty task bodies; {threads} threads"),
    );

    for &d in &depths {
        let dag = Dag::binary_tree(d);
        let n = dag.len();

        let pool = ThreadPool::new(threads);
        let (mut g, _counter) = dag.to_task_graph(0);
        let summary = bench_wall(&opts, || {
            g.run(&pool).unwrap();
        });
        report.push(format!("btree(d={d})"), "scheduling", summary);
        let steal_ratio = pool.metrics().steal_ratio();
        eprintln!("  btree(d={d}) scheduling done (steal ratio {steal_ratio:.3})");

        for name in ["taskflow", "mutex"] {
            let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
            let summary = bench_wall(&opts, || {
                assert_eq!(dag.run_countdown(&ex, 0), n);
            });
            report.push(format!("btree(d={d})"), ex.name(), summary);
        }
    }

    report.print();
    record_json("binary_tree", "wall", threads, &report);

    let last = format!("btree(d={})", depths[depths.len() - 1]);
    if let Some(r) = report.speedup(&last, "scheduling", "mutex-pool") {
        println!("SHAPE tree-ws-beats-mutex@{last}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
}
