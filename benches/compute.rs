//! COMPUTE — floating-point throughput of the data-parallel layer
//! (PR 10): the first bench where the pool is measured in GFLOP/s of
//! real work, not scheduling overhead.
//!
//! 1. **Blocked matmul** (COMPUTE-MM): serial naive oracle
//!    (`matmul_ref`) vs serial cache-blocked (`matmul_blocked`) vs
//!    `parallel_for`-powered (`matmul_blocked_par`) at 1/2/4/8
//!    workers, 256²–1024² (the 1024² arm is skipped under
//!    `BENCH_FAST=1`). Every fast arm is `allclose`-checked against
//!    the oracle *inside the bench*, so CI cannot report GFLOP/s for
//!    wrong answers. SHAPE: parallel blocked at 4 workers ≥ 3× the
//!    serial naive reference on the 512² problem.
//! 2. **Tile sweep** (COMPUTE-TILE): the `MATMUL_TILE` const swept
//!    16–128 on the serial blocked kernel.
//! 3. **Stencil** (COMPUTE-ST): serial 5-point `stencil_step` vs
//!    `stencil_step_par` across 1/2/4/8 workers; the parallel result
//!    must match the serial one bit-exactly.
//! 4. **ABL-10 grain sweep**: `parallel_reduce` over a memory-bound
//!    sum with the grain knob swept from pathological (1) to coarse,
//!    measuring the per-block scheduling overhead the grain floor
//!    exists to amortize.
//!
//! Prints `GFLOPS`/`SCALE` lines per arm (scaling efficiency =
//! speedup over the 1-worker arm ÷ workers) and records wall times
//! into the `BENCH_pr10.json` ledger. Knobs: `BENCH_FAST=1`,
//! `THREADS` (ABL-10 pool size, default 4).

use std::time::Duration;

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::{parallel_reduce, ParOptions};
use scheduling::pool::ThreadPool;
use scheduling::runtime::HostTensor;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let opts = BenchOptions::from_env();
    matmul_bench(&opts);
    tile_sweep(&opts);
    stencil_bench(&opts);
    grain_sweep(&opts);
}

fn gflops(flops: f64, mean: Duration) -> f64 {
    flops / mean.as_secs_f64().max(1e-12) / 1e9
}

fn matmul_bench(opts: &BenchOptions) {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let sizes: &[usize] = if fast { &[256, 512] } else { &[256, 512, 1024] };

    let mut report = Report::new(
        "COMPUTE-MM blocked matmul GFLOP/s",
        "serial naive oracle vs cache-blocked serial vs parallel_for row-blocks; \
         flops = 2n^3; all fast arms allclose-checked against matmul_ref",
    );

    for &n in sizes {
        let a = HostTensor::random(&[n, n], 0xA0 + n as u64);
        let b = HostTensor::random(&[n, n], 0xB0 + n as u64);
        let oracle = a.matmul_ref(&b);
        let flops = 2.0 * (n as f64).powi(3);
        let param = format!("{n}x{n}");

        // Correctness gate before any timing: wrong answers must fail
        // the bench, not ship GFLOP/s numbers.
        assert!(
            a.matmul_blocked(&b).allclose(&oracle, 1e-3, 1e-4),
            "blocked matmul diverges from oracle at {n}"
        );

        // The naive oracle is quadratically painful to *time* at
        // 1024²; its point is made at the smaller sizes.
        if n <= 512 {
            let s = bench_wall(opts, || {
                std::hint::black_box(a.matmul_ref(&b));
            });
            println!("GFLOPS matmul@{param} serial-naive: {:.2}", gflops(flops, s.mean));
            report.push(&param, "serial-naive", s);
        }

        let s = bench_wall(opts, || {
            std::hint::black_box(a.matmul_blocked(&b));
        });
        println!("GFLOPS matmul@{param} serial-blocked: {:.2}", gflops(flops, s.mean));
        report.push(&param, "serial-blocked", s);

        for &w in &WORKER_COUNTS {
            let pool = ThreadPool::new(w);
            assert!(
                a.matmul_blocked_par(&b, &pool)
                    .unwrap()
                    .allclose(&oracle, 1e-3, 1e-4),
                "parallel matmul diverges from oracle at {n} with {w} workers"
            );
            let s = bench_wall(opts, || {
                std::hint::black_box(a.matmul_blocked_par(&b, &pool).unwrap());
            });
            println!(
                "GFLOPS matmul@{param} par-blocked-w{w}: {:.2}",
                gflops(flops, s.mean)
            );
            report.push(&param, format!("par-blocked-w{w}"), s);
        }

        for &w in &WORKER_COUNTS[1..] {
            if let Some(sp) = report.speedup(&param, &format!("par-blocked-w{w}"), "par-blocked-w1")
            {
                println!(
                    "SCALE matmul@{param} w{w}: speedup {sp:.2}x efficiency {:.2}",
                    sp / w as f64
                );
            }
        }
    }

    report.print();
    record_json("compute", "wall", 8, &report);

    // The PR 10 acceptance shape: parallel blocked on 4 workers beats
    // the serial naive reference ≥ 3× on the 512² problem (blocked
    // kernel win × parallel speedup compound).
    let r = report
        .speedup("512x512", "par-blocked-w4", "serial-naive")
        .unwrap_or(0.0);
    println!(
        "SHAPE matmul-par4-vs-naive@512: {r:.2}x {}",
        if r >= 3.0 { "PASS" } else { "FAIL" }
    );
}

fn tile_sweep(opts: &BenchOptions) {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n = if fast { 256 } else { 512 };
    let a = HostTensor::random(&[n, n], 1);
    let b = HostTensor::random(&[n, n], 2);
    let oracle = a.matmul_ref(&b);
    let flops = 2.0 * (n as f64).powi(3);

    let mut report = Report::new(
        "COMPUTE-TILE matmul tile-size sweep",
        format!("serial blocked matmul at {n}x{n}; MATMUL_TILE default is 64"),
    );
    for tile in [16usize, 32, 64, 128] {
        assert!(
            a.matmul_blocked_tiled(&b, tile).allclose(&oracle, 1e-3, 1e-4),
            "tile {tile} diverges"
        );
        let s = bench_wall(opts, || {
            std::hint::black_box(a.matmul_blocked_tiled(&b, tile));
        });
        println!("GFLOPS matmul-tile@{tile}: {:.2}", gflops(flops, s.mean));
        report.push(format!("{n}x{n}"), format!("tile{tile}"), s);
    }
    report.print();
    record_json("compute", "wall", 1, &report);
}

fn stencil_bench(opts: &BenchOptions) {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n = if fast { 512 } else { 1024 };
    let grid = HostTensor::random(&[n, n], 9);
    let serial_out = grid.stencil_step();
    // ~5 flops per interior cell (4 adds + 1 multiply).
    let flops = 5.0 * ((n - 2) as f64).powi(2);
    let param = format!("{n}x{n}");

    let mut report = Report::new(
        "COMPUTE-ST 5-point stencil step",
        "serial stencil_step vs stencil_step_par row-blocks; parallel must match bit-exactly",
    );

    let s = bench_wall(opts, || {
        std::hint::black_box(grid.stencil_step());
    });
    println!("GFLOPS stencil@{param} serial: {:.2}", gflops(flops, s.mean));
    report.push(&param, "serial", s);

    for &w in &WORKER_COUNTS {
        let pool = ThreadPool::new(w);
        let mut out = HostTensor::zeros(&[n, n]);
        grid.stencil_step_par(&pool, &mut out).unwrap();
        assert_eq!(
            out.data, serial_out.data,
            "parallel stencil diverges from serial at {w} workers"
        );
        let s = bench_wall(opts, || {
            grid.stencil_step_par(&pool, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        println!("GFLOPS stencil@{param} par-w{w}: {:.2}", gflops(flops, s.mean));
        report.push(&param, format!("par-w{w}"), s);
    }

    for &w in &WORKER_COUNTS[1..] {
        if let Some(sp) = report.speedup(&param, &format!("par-w{w}"), "par-w1") {
            println!(
                "SCALE stencil@{param} w{w}: speedup {sp:.2}x efficiency {:.2}",
                sp / w as f64
            );
        }
    }

    report.print();
    record_json("compute", "wall", 8, &report);
}

/// ABL-10: what does a block actually cost? A memory-bound sum where
/// the body is nearly free, so per-block scheduling overhead is the
/// whole story: grain 1 lets the splitter go to the full
/// `threads × oversubscription` block count (fine for this size), and
/// the coarse end serializes. The useful property is a wide flat
/// middle — grain only matters at the pathological extremes.
fn grain_sweep(opts: &BenchOptions) {
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let n = 1 << 20;
    let data: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
    let expected: f64 = data.iter().map(|&x| x as f64).sum();
    let pool = ThreadPool::new(threads);

    let mut report = Report::new(
        "ABL-10 parallel_for grain-size sweep",
        format!("parallel_reduce sum over {n} f32 on {threads} threads; grain = min block size"),
    );

    for grain in [1usize, 64, 1024, 16384, 262144] {
        let sum = parallel_reduce(
            &pool,
            0..n,
            grain,
            0.0f64,
            |r, acc| acc + data[r].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert!((sum - expected).abs() < 1e-3, "grain {grain}: bad sum {sum} vs {expected}");
        let s = bench_wall(opts, || {
            let sum = parallel_reduce(
                &pool,
                0..n,
                grain,
                0.0f64,
                |r, acc| acc + data[r].iter().map(|&x| x as f64).sum::<f64>(),
                |a, b| a + b,
            )
            .unwrap();
            std::hint::black_box(sum);
        });
        report.push(format!("sum({n})"), format!("grain{grain}"), s);
    }

    // A default-split arm with explicit options, for the knob table in
    // the README: oversubscription 4 at whatever grain falls out.
    let s = bench_wall(opts, || {
        let sum = scheduling::graph::parallel_reduce_with(
            &pool,
            0..n,
            &ParOptions::new(),
            0.0f64,
            |r, acc| acc + data[r].iter().map(|&x| x as f64).sum::<f64>(),
            |a, b| a + b,
        )
        .unwrap();
        std::hint::black_box(sum);
    });
    report.push(format!("sum({n})"), "default-split", s);

    report.print();
    record_json("ablations_compute", "wall", threads, &report);

    // Midpoint grains should be close to the best arm — the knob has a
    // wide plateau (informational, timing-sensitive: CHECK not FAIL).
    if let Some(r) = report.speedup(&format!("sum({n})"), "grain1024", "grain1") {
        println!("SHAPE abl10-grain-plateau@1M: {r:.2}x CHECK");
    }
}
