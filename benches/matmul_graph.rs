//! E2E — three-layer composition benchmark: blocked matmul task graph
//! whose node bodies execute AOT-compiled XLA executables (L1 Pallas
//! kernel inside an L2 jax graph, driven by the L3 pool).
//!
//! Series: task-graph execution at 1/2/4 workers vs single-threaded
//! sequential execution of the same kernel calls (the no-scheduler
//! baseline), both schedules (independent / wavefront). Numerics are
//! verified against host math every iteration.
//!
//! Requires `make artifacts`. Knobs: `MM_SIZE` (default 256),
//! `MM_TILE` (default 64), `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::bench_harness::{bench_wall, BenchOptions, Report};
use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, HostTensor, Registry, Runtime};
use scheduling::workloads::matmul_graph::{BlockedMatmul, MatmulSchedule};

fn main() {
    if find_artifacts_dir().is_none() {
        eprintln!(
            "SKIP matmul_graph bench: artifacts not built (run `make artifacts`; \
             host-kernel throughput is covered by `cargo bench --bench compute`)"
        );
        return;
    }
    let size: usize = std::env::var("MM_SIZE").ok().and_then(|v| v.parse().ok()).unwrap_or(256);
    let tile: usize = std::env::var("MM_TILE").ok().and_then(|v| v.parse().ok()).unwrap_or(64);
    let opts = BenchOptions::from_env();

    let runtime = Arc::new(Runtime::cpu().expect("PJRT CPU client"));
    let registry = Registry::open_default(runtime).expect("registry");
    let a = HostTensor::random(&[size, size], 1);
    let b = HostTensor::random(&[size, size], 2);
    let expected = a.matmul_ref(&b);
    let mm = BlockedMatmul::new(&registry, &a, &b, tile).expect("matmul setup");
    let t = size / tile;

    let mut report = Report::new(
        "E2E blocked matmul over PJRT executables",
        format!(
            "C=A@B, {size}x{size}, tile {tile} ({}x{} tiles, {} kernel calls); \
             node bodies run the Pallas matmul_acc executable; verified vs host math",
            t,
            t,
            t * t * t
        ),
    );

    // Sequential baseline: same kernel calls, no pool.
    let exe = registry.get(&format!("matmul_tile_{tile}")).unwrap();
    let summary = bench_wall(&opts, || {
        let at = scheduling::workloads::matmul_graph::split_tiles(&a, tile);
        let bt = scheduling::workloads::matmul_graph::split_tiles(&b, tile);
        let mut acc_sum = 0.0f64;
        for i in 0..t {
            for j in 0..t {
                let mut acc = HostTensor::zeros(&[tile, tile]);
                for k in 0..t {
                    acc = exe.run1(&[at[i][k].clone(), bt[k][j].clone(), acc]).unwrap();
                }
                acc_sum += acc.sum();
            }
        }
        assert!((acc_sum - expected.sum()).abs() / expected.sum().abs().max(1.0) < 1e-3);
    });
    report.push(format!("{size}/{tile}"), "sequential", summary);
    eprintln!("  sequential done");

    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);
        for (schedule, label) in [
            (MatmulSchedule::Independent, "graph-indep"),
            (MatmulSchedule::Wavefront, "graph-wavefront"),
        ] {
            let summary = bench_wall(&opts, || {
                let c = mm.run(&pool, schedule).unwrap();
                assert!(c.allclose(&expected, 1e-3, 1e-3));
            });
            report.push(format!("{size}/{tile}"), format!("{label}-t{threads}"), summary);
            eprintln!("  {label} t={threads} done");
        }
    }

    report.print();

    let param = format!("{size}/{tile}");
    if let Some(r) = report.speedup(&param, "graph-indep-t1", "sequential") {
        println!(
            "SHAPE graph-overhead-vs-sequential@t1: {r:.2}x {}",
            if r > 0.8 { "PASS (graph adds <25% overhead)" } else { "CHECK" }
        );
    }
}
