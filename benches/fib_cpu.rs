//! FIG2 — Fig. 2 reproduction: fibonacci **CPU time** per executor.
//!
//! Same workload as FIG1 but measuring process CPU time (user+sys over
//! all threads, via /proc/self/stat). This is the chart that punishes
//! busy-spinning schedulers: an executor can match on wall time while
//! burning idle workers' cycles in the steal loop. Expected shape: CPU
//! time tracks wall time × active-threads for the work-stealing pools
//! (eventcount parking keeps idle workers asleep), and the mutex pool
//! burns extra CPU in lock convoys as N grows.
//!
//! Knobs: `FIB_NS` (default 18,20,22), `THREADS` (default 2),
//! `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_cpu, record_json, BenchOptions, Report};
use scheduling::workloads::{fib_reference, run_fib};

fn env_list(key: &str, default: &[u32]) -> Vec<u32> {
    std::env::var(key)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let ns = env_list("FIB_NS", &[18, 20, 22]);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    // CPU-time resolution is 10 ms: force long samples.
    let mut opts = BenchOptions::from_env();
    opts.min_sample_time = opts.min_sample_time.max(std::time::Duration::from_millis(200));

    let mut report = Report::new(
        "FIG2 fibonacci CPU time",
        format!(
            "process CPU time (user+sys, all threads) per fib(N) run; {threads} worker threads; \
             10 ms tick resolution, samples span >=200 ms"
        ),
    );

    for &n in &ns {
        let expected = fib_reference(n);
        for name in ["scheduling", "taskflow", "mutex"] {
            let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
            let summary = bench_cpu(&opts, || {
                assert_eq!(run_fib(&ex, n), expected);
            });
            report.push(format!("fib({n})"), ex.name(), summary);
            eprintln!("  fib({n}) {name} done");
        }
    }

    report.print();
    record_json("fib_cpu", "cpu", threads, &report);

    let last = format!("fib({})", ns[ns.len() - 1]);
    if let Some(r) = report.speedup(&last, "scheduling", "mutex-pool") {
        println!("SHAPE cpu-ws-beats-mutex@{last}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
    if let Some(r) = report.speedup(&last, "scheduling", "taskflow-like") {
        println!(
            "SHAPE cpu-parity-with-taskflow@{last}: {r:.2}x {}",
            if (0.5..=2.0).contains(&r) { "PASS (within 2x)" } else { "CHECK" }
        );
    }
}
