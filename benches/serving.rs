//! SERVING — open-loop multi-tenant latency/goodput sweep (PR 7).
//!
//! One report per arrival-rate point lands in the ledger
//! (`BENCH_pr7.json`): a three-tenant mix — **gold** (weight 4, High
//! class), **silver** (weight 2, Normal), and a **storming** tenant
//! (weight 1, Low) submitting at 3× its weight share — drives a
//! [`scheduling::serve::GraphService`] with Poisson (open-loop)
//! arrivals at a sweep of offered rates around the pool's measured
//! solo capacity.
//!
//! Open-loop means latency is measured from each request's *scheduled
//! arrival time* (drawn from the exponential-gap schedule up front),
//! not from when a client thread got around to submitting it — so
//! queueing delay during saturation shows up in the tail instead of
//! silently throttling the load, the textbook coordinated-omission
//! fix. Each tenant's schedule is split across a small crew of client
//! threads that sleep until each arrival is due.
//!
//! Ledger series per rate point (`param = rate0.5x`, `rate1x`, ...):
//!
//! * `<tenant>-p50|p99|p999` — request latency percentiles (scheduled
//!   arrival → completion), recorded as single-sample rows whose
//!   `median_ns` is the percentile value;
//! * `<tenant>-goodput` — mean interval between *successful*
//!   completions over the window (ns per op; lower = more goodput);
//! * `fairness-minmax-ppm` — min/max ratio across tenants of
//!   (per-tenant goodput share ÷ DRR weight), scaled to parts-per-
//!   million and stored in `median_ns` (1 000 000 = perfectly
//!   weight-proportional service). The acceptance signal: a storm
//!   must not drive this toward 0.
//!
//! Knobs: `THREADS` (default 2), `WINDOW_MS` (per-rate window, default
//! 2500), `BENCH_FAST=1` (2 rate points, 800 ms windows), `SEED`
//! (Poisson schedule seed, default 42).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use scheduling::bench_harness::{record_json, Report, Summary};
use scheduling::graph::RunPriority;
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::serve::{GraphService, RetryPolicy, ServiceConfig, TenantSpec};
use scheduling::util::Pcg32;
use scheduling::workloads::Dag;

/// Nodes per request graph (4 diamonds) and busy-work steps per node.
const DIAMONDS: usize = 4;
const WORK_STEPS: u32 = 256;
/// Client threads per tenant — enough to keep the open loop open at
/// the sweep's top rate without a thread per request.
const CREW: usize = 8;

fn point(d: Duration) -> Summary {
    Summary { n: 1, mean: d, median: d, stddev: Duration::ZERO, min: d, max: d }
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct TenantOutcome {
    name: &'static str,
    weight: u32,
    latencies: Vec<Duration>,
    completed: u64,
    shed: u64,
    failed: u64,
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let window_ms: u64 = std::env::var("WINDOW_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 800 } else { 2500 });
    let seed: u64 = std::env::var("SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(42);
    let window = Duration::from_millis(window_ms);
    let rate_multipliers: &[f64] = if fast { &[0.5, 2.0] } else { &[0.5, 1.0, 1.5, 3.0] };

    // ---- capacity probe: solo ops/sec of one request graph ---------
    let probe_pool = ThreadPool::with_config(PoolConfig {
        num_threads: threads,
        ..PoolConfig::default()
    });
    let (mut probe, _) = Dag::diamond_chain(DIAMONDS).to_task_graph(WORK_STEPS);
    probe.run(&probe_pool).unwrap(); // warm + seal
    let probe_rounds = 200;
    let t0 = Instant::now();
    for _ in 0..probe_rounds {
        probe.run(&probe_pool).unwrap();
    }
    let per_op = t0.elapsed() / probe_rounds;
    drop(probe_pool);
    // Optimistic pool capacity: solo runs already use caller assist +
    // workers, so ops/sec_solo ~ saturation; the sweep straddles it.
    let capacity_rps = 1.0 / per_op.as_secs_f64().max(1e-9);
    eprintln!(
        "capacity probe: {per_op:?}/op solo -> ~{capacity_rps:.0} rps; \
         sweep x{rate_multipliers:?}, {window_ms} ms windows, {threads} threads"
    );

    // Tenant mix: weights 4/2/1; offered arrival shares 4/2/3 — the
    // storm submits at 3x its weight share.
    let tenant_defs: [(&'static str, u32, RunPriority, f64); 3] = [
        ("gold", 4, RunPriority::High, 4.0 / 9.0),
        ("silver", 2, RunPriority::Normal, 2.0 / 9.0),
        ("storm", 1, RunPriority::Low, 3.0 / 9.0),
    ];

    for (ri, &mult) in rate_multipliers.iter().enumerate() {
        let total_rate = capacity_rps * mult;
        let param = format!("rate{mult}x");

        let svc = Arc::new(GraphService::new(
            ThreadPool::with_config(PoolConfig {
                num_threads: threads,
                ..PoolConfig::default()
            }),
            ServiceConfig {
                max_inflight: (2 * threads).max(4),
                retry: RetryPolicy::default(),
                ..ServiceConfig::default()
            },
        ));

        let start = Instant::now() + Duration::from_millis(50); // sync'd epoch
        let mut crews = Vec::new();
        let mut tenant_handles = Vec::new();
        for (ti, &(name, weight, class, share)) in tenant_defs.iter().enumerate() {
            let id = svc.register_tenant(
                TenantSpec::new(name).weight(weight).class(class).max_inflight(threads.max(2)),
            );
            let rate = total_rate * share;
            // Pre-draw the Poisson schedule, then deal arrivals to the
            // crew round-robin (each client sees every CREW-th gap, so
            // per-client order is preserved).
            let mut rng = Pcg32::new(seed, (ri * 8 + ti) as u64);
            let mut schedule: Vec<Duration> = Vec::new();
            let mut t = 0.0f64;
            loop {
                let u = (1.0 - rng.next_f64()).max(1e-12); // (0,1]
                t += -u.ln() / rate.max(1.0);
                if t >= window.as_secs_f64() {
                    break;
                }
                schedule.push(Duration::from_secs_f64(t));
            }
            let completed = Arc::new(AtomicU64::new(0));
            let shed = Arc::new(AtomicU64::new(0));
            let failed = Arc::new(AtomicU64::new(0));
            tenant_handles.push((name, weight, completed.clone(), shed.clone(), failed.clone()));
            for c in 0..CREW {
                let svc = svc.clone();
                let mine: Vec<Duration> =
                    schedule.iter().skip(c).step_by(CREW).copied().collect();
                let (completed, shed, failed) = (completed.clone(), shed.clone(), failed.clone());
                crews.push(thread::spawn(move || -> Vec<Duration> {
                    let (mut g, _) = Dag::diamond_chain(DIAMONDS).to_task_graph(WORK_STEPS);
                    let mut latencies = Vec::with_capacity(mine.len());
                    for at in mine {
                        let due = start + at;
                        if let Some(wait) = due.checked_duration_since(Instant::now()) {
                            thread::sleep(wait);
                        }
                        match svc.run(id, &mut g) {
                            Ok(()) => {
                                latencies.push(due.elapsed());
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(scheduling::serve::ServeError::Shed(_)) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    latencies
                }));
            }
        }

        // Crews are grouped per tenant in spawn order: CREW threads per
        // tenant, tenant order matching tenant_defs/tenant_handles.
        let mut outcomes: Vec<TenantOutcome> = Vec::new();
        let mut crew_iter = crews.into_iter();
        for (name, weight, completed, shed, failed) in tenant_handles {
            let mut latencies = Vec::new();
            for _ in 0..CREW {
                latencies.extend(crew_iter.next().unwrap().join().unwrap());
            }
            latencies.sort_unstable();
            outcomes.push(TenantOutcome {
                name,
                weight,
                latencies,
                completed: completed.load(Ordering::Relaxed),
                shed: shed.load(Ordering::Relaxed),
                failed: failed.load(Ordering::Relaxed),
            });
        }

        let mut report = Report::new(
            "SERVING open-loop tenant sweep (PR 7)",
            format!(
                "Poisson arrivals at {mult}x probed capacity ({total_rate:.0} rps offered) for \
                 {window_ms} ms, {threads} threads; tenants gold(w4,High)/silver(w2,Normal)/\
                 storm(w1,Low at 3x weight share), {CREW} clients each, 16-node graphs, \
                 default retry policy; latency measured from scheduled arrival \
                 (coordinated-omission-safe); goodput = window/completions (ns per op); \
                 fairness-minmax-ppm = min/max of weight-normalized goodput shares x1e6"
            ),
        );

        // Per-tenant weight-normalized goodput shares for fairness.
        let mut norm_shares: Vec<f64> = Vec::new();
        for o in &outcomes {
            for (suffix, p) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
                report.push(
                    param.clone(),
                    format!("{}-{suffix}", o.name),
                    point(percentile(&o.latencies, p)),
                );
            }
            let goodput_ns = if o.completed > 0 {
                Duration::from_nanos((window.as_nanos() as u64) / o.completed)
            } else {
                window // zero completions: floor at one op per window
            };
            report.push(param.clone(), format!("{}-goodput", o.name), point(goodput_ns));
            norm_shares.push(o.completed as f64 / f64::from(o.weight).max(1.0));
            eprintln!(
                "  {param} {}: completed={} shed={} failed={}",
                o.name, o.completed, o.shed, o.failed
            );
        }
        let (lo, hi) = norm_shares
            .iter()
            .fold((f64::MAX, 0.0f64), |(lo, hi), &s| (lo.min(s), hi.max(s)));
        let fairness_ppm = if hi > 0.0 { (lo / hi * 1e6) as u64 } else { 0 };
        report.push(
            param.clone(),
            "fairness-minmax-ppm",
            point(Duration::from_nanos(fairness_ppm.max(1))),
        );

        report.print();
        record_json("serving", "wall", threads, &report);

        // SHAPE verdicts: under saturation the weighted split must not
        // collapse (storm starving gold would drive the ratio to ~0),
        // and gold must keep completing work at every rate point.
        println!(
            "SHAPE fairness-floor@{param}: {:.2} {}",
            fairness_ppm as f64 / 1e6,
            if fairness_ppm >= 100_000 { "PASS" } else { "CHECK" }
        );
        let gold = &outcomes[0];
        println!(
            "SHAPE gold-served@{param}: {} {}",
            gold.completed,
            if gold.completed > 0 { "PASS" } else { "CHECK" }
        );
        eprintln!("  pool after {param}:\n{}", svc.pool().metrics());
    }
}
