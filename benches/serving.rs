//! SERVING — open-loop multi-tenant latency/goodput sweep (PR 7).
//!
//! One report per arrival-rate point lands in the ledger
//! (`BENCH_pr8.json`): a three-tenant mix — **gold** (weight 4, High
//! class), **silver** (weight 2, Normal), and a **storming** tenant
//! (weight 1, Low) submitting at 3× its weight share — drives a
//! [`scheduling::serve::GraphService`] with Poisson (open-loop)
//! arrivals at a sweep of offered rates around the pool's measured
//! solo capacity.
//!
//! Open-loop means latency is measured from each request's *scheduled
//! arrival time* (drawn from the exponential-gap schedule up front),
//! not from when a client thread got around to submitting it — so
//! queueing delay during saturation shows up in the tail instead of
//! silently throttling the load, the textbook coordinated-omission
//! fix. Each tenant's schedule is split across a small crew of client
//! threads that sleep until each arrival is due.
//!
//! Ledger series per rate point (`param = rate0.5x`, `rate1x`, ...):
//!
//! * `<tenant>-p50|p99|p999` — request latency percentiles (scheduled
//!   arrival → completion), recorded as single-sample rows whose
//!   `median_ns` is the percentile value;
//! * `<tenant>-goodput` — mean interval between *successful*
//!   completions over the window (ns per op; lower = more goodput);
//! * `fairness-minmax-ppm` — min/max ratio across tenants of
//!   (per-tenant goodput share ÷ DRR weight), scaled to parts-per-
//!   million and stored in `median_ns` (1 000 000 = perfectly
//!   weight-proportional service). The acceptance signal: a storm
//!   must not drive this toward 0.
//!
//! PR 8 additions:
//!
//! * **Stale-weight makespan series** (after the sweep): a graph whose
//!   declared weights are wrong by 10× is run three ways —
//!   `static-true` (truthful weights, dynamic re-rank off),
//!   `static-wrong` (inverted weights, re-rank off), and
//!   `dynamic-rerank` (inverted weights, duration feedback on). The
//!   `SHAPE stale-weight-recovery` verdict is the fraction of the
//!   wrong→true makespan gap the dynamic variant claws back (PASS
//!   ≥ 0.8).
//! * **`WIRE=1` cross-process mode**: instead of the in-process sweep,
//!   spawn the `graph_serve` binary and measure framed round-trip
//!   latency through the TCP front-end, then scrape its counters.
//!
//! Knobs: `THREADS` (default 2), `WINDOW_MS` (per-rate window, default
//! 2500), `BENCH_FAST=1` (2 rate points, 800 ms windows), `SEED`
//! (Poisson schedule seed, default 42), `WIRE=1` (cross-process mode).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use scheduling::bench_harness::{record_json, Report, Summary};
use scheduling::graph::RunPriority;
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::serve::{GraphService, RetryPolicy, ServiceConfig, TenantSpec};
use scheduling::util::Pcg32;
use scheduling::workloads::Dag;

/// Nodes per request graph (4 diamonds) and busy-work steps per node.
const DIAMONDS: usize = 4;
const WORK_STEPS: u32 = 256;
/// Client threads per tenant — enough to keep the open loop open at
/// the sweep's top rate without a thread per request.
const CREW: usize = 8;

fn point(d: Duration) -> Summary {
    Summary { n: 1, mean: d, median: d, stddev: Duration::ZERO, min: d, max: d }
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct TenantOutcome {
    name: &'static str,
    weight: u32,
    latencies: Vec<Duration>,
    completed: u64,
    shed: u64,
    failed: u64,
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    if std::env::var("WIRE").map(|v| v == "1").unwrap_or(false) {
        wire_bench(threads, fast);
        return;
    }
    let window_ms: u64 = std::env::var("WINDOW_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 800 } else { 2500 });
    let seed: u64 = std::env::var("SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(42);
    let window = Duration::from_millis(window_ms);
    let rate_multipliers: &[f64] = if fast { &[0.5, 2.0] } else { &[0.5, 1.0, 1.5, 3.0] };

    // ---- capacity probe: solo ops/sec of one request graph ---------
    let probe_pool = ThreadPool::with_config(PoolConfig {
        num_threads: threads,
        ..PoolConfig::default()
    });
    let (mut probe, _) = Dag::diamond_chain(DIAMONDS).to_task_graph(WORK_STEPS);
    probe.run(&probe_pool).unwrap(); // warm + seal
    let probe_rounds = 200;
    let t0 = Instant::now();
    for _ in 0..probe_rounds {
        probe.run(&probe_pool).unwrap();
    }
    let per_op = t0.elapsed() / probe_rounds;
    drop(probe_pool);
    // Optimistic pool capacity: solo runs already use caller assist +
    // workers, so ops/sec_solo ~ saturation; the sweep straddles it.
    let capacity_rps = 1.0 / per_op.as_secs_f64().max(1e-9);
    eprintln!(
        "capacity probe: {per_op:?}/op solo -> ~{capacity_rps:.0} rps; \
         sweep x{rate_multipliers:?}, {window_ms} ms windows, {threads} threads"
    );

    // Tenant mix: weights 4/2/1; offered arrival shares 4/2/3 — the
    // storm submits at 3x its weight share.
    let tenant_defs: [(&'static str, u32, RunPriority, f64); 3] = [
        ("gold", 4, RunPriority::High, 4.0 / 9.0),
        ("silver", 2, RunPriority::Normal, 2.0 / 9.0),
        ("storm", 1, RunPriority::Low, 3.0 / 9.0),
    ];

    for (ri, &mult) in rate_multipliers.iter().enumerate() {
        let total_rate = capacity_rps * mult;
        let param = format!("rate{mult}x");

        let svc = Arc::new(GraphService::new(
            ThreadPool::with_config(PoolConfig {
                num_threads: threads,
                ..PoolConfig::default()
            }),
            ServiceConfig {
                max_inflight: (2 * threads).max(4),
                retry: RetryPolicy::default(),
                ..ServiceConfig::default()
            },
        ));

        let start = Instant::now() + Duration::from_millis(50); // sync'd epoch
        let mut crews = Vec::new();
        let mut tenant_handles = Vec::new();
        for (ti, &(name, weight, class, share)) in tenant_defs.iter().enumerate() {
            let id = svc.register_tenant(
                TenantSpec::new(name).weight(weight).class(class).max_inflight(threads.max(2)),
            );
            let rate = total_rate * share;
            // Pre-draw the Poisson schedule, then deal arrivals to the
            // crew round-robin (each client sees every CREW-th gap, so
            // per-client order is preserved).
            let mut rng = Pcg32::new(seed, (ri * 8 + ti) as u64);
            let mut schedule: Vec<Duration> = Vec::new();
            let mut t = 0.0f64;
            loop {
                let u = (1.0 - rng.next_f64()).max(1e-12); // (0,1]
                t += -u.ln() / rate.max(1.0);
                if t >= window.as_secs_f64() {
                    break;
                }
                schedule.push(Duration::from_secs_f64(t));
            }
            let completed = Arc::new(AtomicU64::new(0));
            let shed = Arc::new(AtomicU64::new(0));
            let failed = Arc::new(AtomicU64::new(0));
            tenant_handles.push((name, weight, completed.clone(), shed.clone(), failed.clone()));
            for c in 0..CREW {
                let svc = svc.clone();
                let mine: Vec<Duration> =
                    schedule.iter().skip(c).step_by(CREW).copied().collect();
                let (completed, shed, failed) = (completed.clone(), shed.clone(), failed.clone());
                crews.push(thread::spawn(move || -> Vec<Duration> {
                    let (mut g, _) = Dag::diamond_chain(DIAMONDS).to_task_graph(WORK_STEPS);
                    let mut latencies = Vec::with_capacity(mine.len());
                    for at in mine {
                        let due = start + at;
                        if let Some(wait) = due.checked_duration_since(Instant::now()) {
                            thread::sleep(wait);
                        }
                        match svc.run(id, &mut g) {
                            Ok(()) => {
                                latencies.push(due.elapsed());
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(scheduling::serve::ServeError::Shed(_)) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    latencies
                }));
            }
        }

        // Crews are grouped per tenant in spawn order: CREW threads per
        // tenant, tenant order matching tenant_defs/tenant_handles.
        let mut outcomes: Vec<TenantOutcome> = Vec::new();
        let mut crew_iter = crews.into_iter();
        for (name, weight, completed, shed, failed) in tenant_handles {
            let mut latencies = Vec::new();
            for _ in 0..CREW {
                latencies.extend(crew_iter.next().unwrap().join().unwrap());
            }
            latencies.sort_unstable();
            outcomes.push(TenantOutcome {
                name,
                weight,
                latencies,
                completed: completed.load(Ordering::Relaxed),
                shed: shed.load(Ordering::Relaxed),
                failed: failed.load(Ordering::Relaxed),
            });
        }

        let mut report = Report::new(
            "SERVING open-loop tenant sweep (PR 7)",
            format!(
                "Poisson arrivals at {mult}x probed capacity ({total_rate:.0} rps offered) for \
                 {window_ms} ms, {threads} threads; tenants gold(w4,High)/silver(w2,Normal)/\
                 storm(w1,Low at 3x weight share), {CREW} clients each, 16-node graphs, \
                 default retry policy; latency measured from scheduled arrival \
                 (coordinated-omission-safe); goodput = window/completions (ns per op); \
                 fairness-minmax-ppm = min/max of weight-normalized goodput shares x1e6"
            ),
        );

        // Per-tenant weight-normalized goodput shares for fairness.
        let mut norm_shares: Vec<f64> = Vec::new();
        for o in &outcomes {
            for (suffix, p) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
                report.push(
                    param.clone(),
                    format!("{}-{suffix}", o.name),
                    point(percentile(&o.latencies, p)),
                );
            }
            let goodput_ns = if o.completed > 0 {
                Duration::from_nanos((window.as_nanos() as u64) / o.completed)
            } else {
                window // zero completions: floor at one op per window
            };
            report.push(param.clone(), format!("{}-goodput", o.name), point(goodput_ns));
            norm_shares.push(o.completed as f64 / f64::from(o.weight).max(1.0));
            eprintln!(
                "  {param} {}: completed={} shed={} failed={}",
                o.name, o.completed, o.shed, o.failed
            );
        }
        let (lo, hi) = norm_shares
            .iter()
            .fold((f64::MAX, 0.0f64), |(lo, hi), &s| (lo.min(s), hi.max(s)));
        let fairness_ppm = if hi > 0.0 { (lo / hi * 1e6) as u64 } else { 0 };
        report.push(
            param.clone(),
            "fairness-minmax-ppm",
            point(Duration::from_nanos(fairness_ppm.max(1))),
        );

        report.print();
        record_json("serving", "wall", threads, &report);

        // SHAPE verdicts: under saturation the weighted split must not
        // collapse (storm starving gold would drive the ratio to ~0),
        // and gold must keep completing work at every rate point.
        println!(
            "SHAPE fairness-floor@{param}: {:.2} {}",
            fairness_ppm as f64 / 1e6,
            if fairness_ppm >= 100_000 { "PASS" } else { "CHECK" }
        );
        let gold = &outcomes[0];
        println!(
            "SHAPE gold-served@{param}: {} {}",
            gold.completed,
            if gold.completed > 0 { "PASS" } else { "CHECK" }
        );
        eprintln!("  pool after {param}:\n{}", svc.pool().metrics());
    }

    stale_weight_bench(threads, fast);
}

/// PR 8 tentpole acceptance: when declared weights are wrong by 10×,
/// duration-feedback re-ranking must recover ≥80% of the makespan gap
/// between scheduling on the wrong weights and scheduling on the true
/// ones.
///
/// The workload makes stale weights maximally harmful: a serial chain
/// carries half the total work (so starting it late directly extends
/// the makespan), while a wide layer of independent light nodes
/// carries the other half (so there is always something "attractive"
/// for a misled scheduler to run first). Truthful weights mark the
/// chain heavy; the wrong variant inverts them 10×, making every light
/// node out-rank the chain head.
fn stale_weight_bench(threads: usize, fast: bool) {
    use scheduling::graph::{RunOptions, TaskGraph};
    use scheduling::workloads::dag::busy_work;

    const CHAIN: usize = 8;
    const WIDE: usize = 16;
    const HEAVY_STEPS: u32 = 40_000;
    const LIGHT_STEPS: u32 = 20_000;

    let pool =
        ThreadPool::with_config(PoolConfig { num_threads: threads, ..PoolConfig::default() });

    let build = |truthful: bool| -> TaskGraph {
        let (chain_w, light_w) = if truthful { (10u32, 1u32) } else { (1u32, 10u32) };
        let mut g = TaskGraph::new();
        let src = g.add(|| {});
        let sink = g.add(|| {});
        let mut prev = src;
        for k in 0..CHAIN {
            let n = g.add_weighted(chain_w, move || {
                std::hint::black_box(busy_work(k as u64, HEAVY_STEPS));
            });
            g.precede(prev, &[n]);
            prev = n;
        }
        g.precede(prev, &[sink]);
        for k in 0..WIDE {
            let n = g.add_weighted(light_w, move || {
                std::hint::black_box(busy_work(100 + k as u64, LIGHT_STEPS));
            });
            g.precede(src, &[n]);
            g.precede(n, &[sink]);
        }
        g.seal().unwrap();
        g
    };

    let rounds = if fast { 7 } else { 21 };
    let mut report = Report::new(
        "SERVING stale-weight re-ranking (PR 8)",
        format!(
            "makespan of one run, median of {rounds} after 3 warmups, {threads} threads; \
             {CHAIN}-node serial chain ({HEAVY_STEPS} steps/node) + {WIDE} independent light \
             nodes ({LIGHT_STEPS} steps); static-true = truthful declared weights with \
             dynamic re-rank off, static-wrong = 10x-inverted weights with re-rank off, \
             dynamic-rerank = inverted weights with duration feedback on (the default)"
        ),
    );
    let variants: [(&str, bool, bool); 3] = [
        ("static-true", true, false),
        ("static-wrong", false, false),
        ("dynamic-rerank", false, true),
    ];
    let mut medians = Vec::new();
    for (name, truthful, dynamic) in variants {
        let mut g = build(truthful);
        let opts =
            if dynamic { RunOptions::new() } else { RunOptions::new().dynamic_rank(false) };
        for _ in 0..3 {
            g.run_with_options(&pool, opts.clone()).unwrap();
        }
        let mut samples = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let t0 = Instant::now();
            g.run_with_options(&pool, opts.clone()).unwrap();
            samples.push(t0.elapsed());
        }
        let summary = Summary::from_samples(&samples);
        if dynamic {
            eprintln!("  stale-weight: dynamic variant re-ranked {} time(s)", g.reranks());
        }
        medians.push(summary.median);
        report.push("makespan", name, summary);
    }
    report.print();
    record_json("serving_stale_weight", "wall", threads, &report);

    let (true_m, wrong_m, dyn_m) =
        (medians[0].as_secs_f64(), medians[1].as_secs_f64(), medians[2].as_secs_f64());
    let gap = wrong_m - true_m;
    let recovery = if gap > 1e-9 { (wrong_m - dyn_m) / gap } else { 1.0 };
    println!(
        "SHAPE stale-weight-recovery: {recovery:.2} {}",
        if recovery >= 0.8 { "PASS" } else { "CHECK" }
    );
}

/// `WIRE=1` cross-process mode: spawn the `graph_serve` binary, drive
/// framed round-trips through one persistent connection (so the
/// server-side template instance stays sealed), and report RTT
/// percentiles. The deltas against the in-process sweep's latencies
/// are the cost of the wire: frame codec + TCP round-trip.
fn wire_bench(threads: usize, fast: bool) {
    use scheduling::serve::{WireClient, WireStatus};
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let n = if fast { 200 } else { 2000 };
    let mut child = Command::new(env!("CARGO_BIN_EXE_graph_serve"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--metrics-addr",
            "127.0.0.1:0",
            "--threads",
            &threads.to_string(),
            "--work-steps",
            "256",
        ])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn graph_serve");
    // Readiness line: "graph_serve listening on ADDR (metrics on MADDR)".
    let mut line = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut line).unwrap();
    let addr = line
        .split_whitespace()
        .nth(3)
        .unwrap_or_else(|| panic!("bad readiness line {line:?}"))
        .to_string();
    eprintln!("wire bench against {addr}: {n} round-trips of diamond4 as gold");

    let mut c = WireClient::connect(addr.as_str()).expect("connect to spawned graph_serve");
    for _ in 0..20 {
        let (status, msg) = c.run("gold", "diamond4", None).unwrap();
        assert_eq!(status, WireStatus::Ok, "{msg}");
    }
    let mut rtts = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let (status, msg) = c.run("gold", "diamond4", None).unwrap();
        assert_eq!(status, WireStatus::Ok, "{msg}");
        rtts.push(t0.elapsed());
    }
    rtts.sort_unstable();

    let mut report = Report::new(
        "SERVING wire RTT (PR 8)",
        format!(
            "framed TCP round-trip (request frame -> run -> response frame) against a spawned \
             graph_serve with {threads} worker threads; one persistent connection, {n} \
             round-trips after 20 warmups; template diamond4 (16 nodes x 256 steps), tenant \
             gold(w4,High)"
        ),
    );
    report.push("diamond4", "rtt", Summary::from_samples(&rtts));
    report.push("diamond4", "rtt-p99", point(percentile(&rtts, 0.99)));
    report.print();
    record_json("serving_wire", "wall", threads, &report);

    let scrape = c.scrape().expect("scrape after bench");
    eprintln!("server counters after bench:\n{scrape}");
    println!(
        "SHAPE wire-all-ok: {} {}",
        rtts.len(),
        if scrape.contains(&format!("tenant_completed{{tenant=\"gold\"}} {}", n + 20)) {
            "PASS"
        } else {
            "CHECK"
        }
    );
    let _ = child.kill();
    let _ = child.wait();
}
