//! GR — graph re-run latency (PR 2): a small sealed 64-node
//! diamond-chain graph re-executed 10k times per sample.
//!
//! This is the workload the PR 2 tentpole optimizes: the paper's §4.2
//! benchmarks run the same `tasks` collection repeatedly, so the
//! steady-state cost of `run()` on an already-built graph — not graph
//! construction — is what a task-graph runtime should be judged on.
//! After sealing, a re-run is: one linear counter sweep + one source
//! burst + caller-assisted draining, with zero heap allocations
//! (asserted by `rust/tests/graph_alloc.rs`).
//!
//! Two reports land in the ledger (`BENCH_pr2.json`):
//!
//! * **GR graph re-run latency** — the default configuration on the
//!   diamond chain and on a 1024-node linear chain, tracked from this
//!   PR forward.
//! * **ABL-6 re-run mode toggles** — the new ablation axis: each of
//!   the three PR 2 pieces (CSR topology arena, run-state reuse,
//!   caller assist) switched off independently, plus all off together.
//!
//! Knobs: `RERUNS` (default 10000), `THREADS` (default 2),
//! `BENCH_FAST=1` (also drops RERUNS to 1000).

use std::sync::atomic::Ordering;

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::RunOptions;
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

fn main() {
    let opts = BenchOptions::from_env();
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let reruns: usize = std::env::var("RERUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 1_000 } else { 10_000 });
    let pool = ThreadPool::new(threads);

    // ---- GR: default-configuration re-run latency ------------------
    let mut report = Report::new(
        "GR graph re-run latency",
        format!(
            "sealed graph re-executed {reruns}x per sample; {threads} threads; \
             all PR 2 optimizations on; divide medians by {reruns} for per-run cost"
        ),
    );

    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    g.run(&pool).unwrap(); // warm: sizes queues, builds run state
    let summary = bench_wall(&opts, || {
        for _ in 0..reruns {
            g.run(&pool).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 64 * reruns);
    report.push(format!("diamond64 x{reruns}"), "scheduling", summary);

    let chain_reruns = (reruns / 10).max(1);
    let (mut g, counter) = Dag::linear_chain(1024).to_task_graph(0);
    g.run(&pool).unwrap();
    let summary = bench_wall(&opts, || {
        for _ in 0..chain_reruns {
            g.run(&pool).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 1024 * chain_reruns);
    report.push(format!("chain1024 x{chain_reruns}"), "scheduling", summary);

    report.print();
    record_json("graph_rerun", "wall", threads, &report);

    // ---- ABL-6: the three PR 2 pieces toggled independently --------
    let mut report = Report::new(
        "ABL-6 re-run mode toggles (PR 2)",
        format!(
            "diamond64 re-executed {reruns}x per sample; {threads} threads; CSR topology \
             arena / run-state reuse / caller assist each disabled against all-on"
        ),
    );
    let variants: [(&str, RunOptions); 5] = [
        ("all-on", RunOptions::new()),
        ("no-csr-topology", RunOptions::new().topology_cache(false)),
        ("no-state-reuse", RunOptions::new().state_reuse(false)),
        ("no-caller-assist", RunOptions::new().caller_assist(false)),
        (
            "all-off",
            RunOptions::new().topology_cache(false).state_reuse(false).caller_assist(false),
        ),
    ];
    let (mut g, _counter) = Dag::diamond_chain(16).to_task_graph(0);
    for (label, options) in variants {
        g.run_with_options(&pool, options.clone()).unwrap(); // warm per mode
        let summary = bench_wall(&opts, || {
            for _ in 0..reruns {
                g.run_with_options(&pool, options.clone()).unwrap();
            }
        });
        report.push(format!("diamond64 x{reruns}"), label, summary);
        eprintln!("  rerun-mode variant {label} done");
    }
    report.print();
    record_json("graph_rerun_modes", "wall", threads, &report);

    let param = format!("diamond64 x{reruns}");
    for (baseline, shape) in
        [("all-off", "rerun-opts-win"), ("no-caller-assist", "caller-assist-wins")]
    {
        if let Some(r) = report.speedup(&param, "all-on", baseline) {
            println!("SHAPE {shape}@{param}: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
        }
    }
}
