//! GR — graph re-run latency (PR 2): a small sealed 64-node
//! diamond-chain graph re-executed 10k times per sample.
//!
//! This is the workload the PR 2 tentpole optimizes: the paper's §4.2
//! benchmarks run the same `tasks` collection repeatedly, so the
//! steady-state cost of `run()` on an already-built graph — not graph
//! construction — is what a task-graph runtime should be judged on.
//! After sealing, a re-run is: one linear counter sweep + one source
//! burst + caller-assisted draining, with zero heap allocations
//! (asserted by `rust/tests/graph_alloc.rs`).
//!
//! Three reports land in the ledger (`BENCH_pr7.json` as of PR 7):
//!
//! * **GR graph re-run latency** — the default configuration on the
//!   diamond chain and on a 1024-node linear chain, tracked from PR 2
//!   forward.
//! * **ABL-6 re-run mode toggles** — the PR 2 ablation axis: each of
//!   the three re-run pieces (CSR topology arena, run-state reuse,
//!   caller assist) switched off independently, plus all off together.
//! * **GR-async in-flight pipelining (PR 3)** — the same sealed
//!   diamond-chain workload driven through `run_async` handles: one
//!   graph launched-then-waited (handle overhead vs the blocking
//!   path), and N ∈ {2, 8} graphs kept in flight from the one bench
//!   thread (`workloads::MultiRun`), where pipelining across graphs is
//!   the point of the async API.
//!
//! Knobs: `RERUNS` (default 10000), `THREADS` (default 2),
//! `BENCH_FAST=1` (also drops RERUNS to 1000).

use std::sync::atomic::Ordering;

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report};
use scheduling::graph::RunOptions;
use scheduling::pool::ThreadPool;
use scheduling::workloads::{Dag, MultiRun};

fn main() {
    let opts = BenchOptions::from_env();
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let reruns: usize = std::env::var("RERUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 1_000 } else { 10_000 });
    let pool = ThreadPool::new(threads);

    // ---- GR: default-configuration re-run latency ------------------
    let mut report = Report::new(
        "GR graph re-run latency",
        format!(
            "sealed graph re-executed {reruns}x per sample; {threads} threads; \
             all PR 2 optimizations on; divide medians by {reruns} for per-run cost"
        ),
    );

    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    g.run(&pool).unwrap(); // warm: sizes queues, builds run state
    let summary = bench_wall(&opts, || {
        for _ in 0..reruns {
            g.run(&pool).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 64 * reruns);
    report.push(format!("diamond64 x{reruns}"), "scheduling", summary);
    // Reused below as the GR-async "sync-1" baseline — same workload,
    // same configuration, so re-measuring it would only double the
    // bench time and let run-to-run noise split two identical numbers.
    let diamond_sync = summary;

    let chain_reruns = (reruns / 10).max(1);
    let (mut g, counter) = Dag::linear_chain(1024).to_task_graph(0);
    g.run(&pool).unwrap();
    let summary = bench_wall(&opts, || {
        for _ in 0..chain_reruns {
            g.run(&pool).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 1024 * chain_reruns);
    report.push(format!("chain1024 x{chain_reruns}"), "scheduling", summary);

    report.print();
    record_json("graph_rerun", "wall", threads, &report);

    // ---- ABL-6: the three PR 2 pieces toggled independently --------
    let mut report = Report::new(
        "ABL-6 re-run mode toggles (PR 2)",
        format!(
            "diamond64 re-executed {reruns}x per sample; {threads} threads; CSR topology \
             arena / run-state reuse / caller assist each disabled against all-on"
        ),
    );
    let variants: [(&str, RunOptions); 5] = [
        ("all-on", RunOptions::new()),
        ("no-csr-topology", RunOptions::new().topology_cache(false)),
        ("no-state-reuse", RunOptions::new().state_reuse(false)),
        ("no-caller-assist", RunOptions::new().caller_assist(false)),
        (
            "all-off",
            RunOptions::new().topology_cache(false).state_reuse(false).caller_assist(false),
        ),
    ];
    let (mut g, _counter) = Dag::diamond_chain(16).to_task_graph(0);
    for (label, options) in variants {
        g.run_with_options(&pool, options.clone()).unwrap(); // warm per mode
        let summary = bench_wall(&opts, || {
            for _ in 0..reruns {
                g.run_with_options(&pool, options.clone()).unwrap();
            }
        });
        report.push(format!("diamond64 x{reruns}"), label, summary);
        eprintln!("  rerun-mode variant {label} done");
    }
    report.print();
    record_json("graph_rerun_modes", "wall", threads, &report);

    let param = format!("diamond64 x{reruns}");
    for (baseline, shape) in
        [("all-off", "rerun-opts-win"), ("no-caller-assist", "caller-assist-wins")]
    {
        if let Some(r) = report.speedup(&param, "all-on", baseline) {
            println!("SHAPE {shape}@{param}: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
        }
    }

    // ---- GR-async: handles, one graph and N graphs in flight --------
    // Per-variant totals are normalized to the same number of NODE
    // executions (64 * reruns), so medians are directly comparable:
    // sync-1 re-runs one graph `reruns` times, async-N runs N graphs
    // `reruns / N` rounds.
    // Align the per-sample total to a multiple of 8 so every variant
    // (1, 2, or 8 graphs in flight) executes exactly the same number
    // of node executions; the default RERUNS values already are, so
    // this only kicks in for a hand-picked RERUNS.
    let async_reruns = (reruns / 8).max(1) * 8;
    let mut report = Report::new(
        "GR-async in-flight pipelining (PR 3)",
        format!(
            "64-node sealed diamond chains, {} node executions per sample, {threads} \
             threads; sync-1 = blocking assisted run loop (bench thread helps: \
             THREADS+1 executing threads — see the README fairness note), \
             sync-1-noassist = condvar-blocked run loop (THREADS threads, the \
             thread-fair baseline for the async rows), async-1 = run_async+wait \
             per run, async-N = N handles in flight per round (MultiRun); handle \
             waiters never assist",
            64 * async_reruns
        ),
    );
    let param = format!("diamond64x{async_reruns}-total");
    if async_reruns == reruns {
        // Same workload and configuration as the GR diamond series —
        // reuse that measurement instead of paying for it twice.
        report.push(param.clone(), "sync-1", diamond_sync);
    } else {
        let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
        g.run(&pool).unwrap();
        let summary = bench_wall(&opts, || {
            for _ in 0..async_reruns {
                g.run(&pool).unwrap();
            }
        });
        assert!(counter.load(Ordering::Relaxed) >= 64 * async_reruns);
        report.push(param.clone(), "sync-1", summary);
    }

    // Thread-fair sync baseline: the caller blocks without executing
    // nodes, exactly like an async handle waiter.
    let noassist = RunOptions::new().caller_assist(false);
    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    g.run_with_options(&pool, noassist.clone()).unwrap();
    let summary = bench_wall(&opts, || {
        for _ in 0..async_reruns {
            g.run_with_options(&pool, noassist.clone()).unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 64 * async_reruns);
    report.push(param.clone(), "sync-1-noassist", summary);

    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    g.run_async(&pool).unwrap().wait().unwrap();
    let summary = bench_wall(&opts, || {
        for _ in 0..async_reruns {
            g.run_async(&pool).unwrap().wait().unwrap();
        }
    });
    assert!(counter.load(Ordering::Relaxed) >= 64 * async_reruns);
    report.push(param.clone(), "async-1", summary);

    for in_flight in [2usize, 8] {
        let rounds = async_reruns / in_flight; // exact: async_reruns is a multiple of 8
        let mut mr = MultiRun::new(in_flight, 16, 0);
        mr.run_round(&pool).unwrap(); // warm per fleet
        let summary = bench_wall(&opts, || {
            mr.run_rounds(&pool, rounds).unwrap();
        });
        assert!(mr.verify_exactly_once(), "async-{in_flight}: exactly-once violated");
        report.push(param.clone(), format!("async-{in_flight}"), summary);
        eprintln!("  async variant async-{in_flight} done");
    }
    report.print();
    record_json("graph_rerun_async", "wall", threads, &report);

    // Both comparisons are thread-fair: every series here except
    // sync-1 runs with non-executing waiters.
    for (series, baseline, shape) in [
        ("async-8", "async-1", "async-pipelining"),
        ("async-1", "sync-1-noassist", "async-handle-overhead"),
    ] {
        if let Some(r) = report.speedup(&param, series, baseline) {
            println!("SHAPE {shape}@{param}: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
        }
    }
}
