//! GH-GT — graph traversal: layered random DAG execution.
//!
//! The irregular-dependency workload: layers × width nodes with random
//! next-layer edges (deterministic seed, recorded below). Mixed fan-in/
//! fan-out exercises the predecessor-counter protocol and victim
//! randomization together. Expected shape: work-stealing executors
//! ahead of the mutex pool, scheduling ≈ taskflow-like.
//!
//! Knobs: `GT_SIZES` ("layers:width" list, default
//! 32:32,64:64,128:64), `GT_P` (default 0.15), `SEED`, `THREADS`,
//! `BENCH_FAST=1`.

use std::sync::Arc;

use scheduling::baseline::{executor_by_name, Executor};
use scheduling::bench_harness::{bench_wall, BenchOptions, Report};
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

fn main() {
    let sizes: Vec<(usize, usize)> = std::env::var("GT_SIZES")
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| {
                    let (l, w) = s.trim().split_once(':')?;
                    Some((l.parse().ok()?, w.parse().ok()?))
                })
                .collect()
        })
        .unwrap_or_else(|| vec![(32, 32), (64, 64), (128, 64)]);
    let p: f64 = std::env::var("GT_P").ok().and_then(|v| v.parse().ok()).unwrap_or(0.15);
    let seed: u64 = std::env::var("SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(42);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let opts = BenchOptions::from_env();

    let mut report = Report::new(
        "GH-GT graph traversal (layered random DAG)",
        format!("p={p} seed={seed} threads={threads}; empty task bodies"),
    );

    for &(layers, width) in &sizes {
        let dag = Dag::layered_random(layers, width, p, seed);
        let n = dag.len();
        let param = format!("dag({layers}x{width})");

        let pool = ThreadPool::new(threads);
        let (mut g, _c) = dag.to_task_graph(0);
        let summary = bench_wall(&opts, || {
            g.run(&pool).unwrap();
        });
        report.push(&param, "scheduling", summary);

        for name in ["taskflow", "mutex"] {
            let ex: Arc<dyn Executor> = executor_by_name(name, threads).unwrap();
            let summary = bench_wall(&opts, || {
                assert_eq!(dag.run_countdown(&ex, 0), n);
            });
            report.push(&param, ex.name(), summary);
        }
        eprintln!("  {param} ({} nodes, {} edges) done", n, dag.num_edges());
    }

    report.print();

    let (l, w) = sizes[sizes.len() - 1];
    let last = format!("dag({l}x{w})");
    if let Some(r) = report.speedup(&last, "scheduling", "mutex-pool") {
        println!("SHAPE dag-ws-beats-mutex@{last}: {r:.2}x {}", if r > 1.0 { "PASS" } else { "FAIL" });
    }
}
