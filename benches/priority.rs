//! PRIO — priority scheduling (PR 4): skewed-DAG makespan with
//! critical-path-first dispatch vs the shape-oblivious FIFO rule, plus
//! a mixed-priority async fleet.
//!
//! Three reports land in the ledger (`BENCH_pr8.json` as of PR 8):
//!
//! * **PRIO skewed-DAG makespan** — a weighted `Dag::skewed_diamond`
//!   (many light branches + one heavy spine, spine head buried
//!   mid-successor-list so FIFO neither starts nor finishes it early)
//!   re-run under critical-path-first vs FIFO dispatch. The spine is
//!   the makespan lower bound; starting it late stretches the run, so
//!   `critical-path` should beat `fifo` whenever threads < branches.
//! * **ABL-7 priority toggles** — the PR 4 toggle sweep: all-on /
//!   `no_critical_path` / `no_priority_lanes` / all-off (the all-off
//!   arm is the pre-PR 4 FIFO path, scheduling-identical by design),
//!   plus the PR 8 `no-dynamic-rank` arm. This workload's declared
//!   weights are truthful (work is proportional to weight), so the
//!   all-on vs `no-dynamic-rank` delta isolates the *overhead* of
//!   duration sampling + drift checking, not any scheduling change.
//! * **PRIO mixed-priority fleet** — 9 sealed diamond-chain graphs in
//!   flight from one thread (`MultiRun` shape) tagged High/Normal/Low
//!   in thirds; per-class completion latency is measured by polling the
//!   handle fleet, showing the run-class lanes actually tier tenants.
//!
//! Knobs: `THREADS` (default 2), `RERUNS` (default 40 makespan samples
//! per bench iteration), `BENCH_FAST=1` (smoke profile, smaller
//! graphs).

use std::time::{Duration, Instant};

use scheduling::bench_harness::{bench_wall, record_json, BenchOptions, Report, Summary};
use scheduling::graph::{RunOptions, RunPriority};
use scheduling::pool::ThreadPool;
use scheduling::workloads::{Dag, MultiRun};

fn main() {
    let opts = BenchOptions::from_env();
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let threads: usize = std::env::var("THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let reruns: usize = std::env::var("RERUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 10 } else { 40 });
    let pool = ThreadPool::new(threads);

    // ---- PRIO: skewed-DAG makespan, critical-path vs FIFO ----------
    // Width light branches (weight 1) + a `spine`-long heavy chain
    // (weight 8) from one source into one sink. Serial spine work is
    // the makespan floor; FIFO discovers the spine head mid-deque, so
    // its makespan carries an O(branches / threads) startup delay that
    // critical-path-first dispatch removes.
    // Sized so the spine dominates (total work / threads < serial
    // spine) while the branch pool is wide enough that FIFO's spine
    // startup delay is a sizable slice of the makespan.
    let (width, spine, heavy, steps) = if fast { (192, 24, 8, 200) } else { (768, 64, 8, 400) };
    let dag = Dag::skewed_diamond(width, spine)
        .with_weights(|i| if (width + 1..=width + spine).contains(&i) { heavy } else { 1 });
    let spine_units = spine as u64 * heavy as u64;
    let mut report = Report::new(
        "PRIO skewed-DAG makespan (PR 4)",
        format!(
            "skewed({width}w+{spine}s) weighted DAG ({} nodes, spine {spine}x w={heavy}, \
             serial spine = {spine_units} weight-units) re-run {reruns}x per sample; \
             {threads} threads; critical-path = rank-first dispatch + priority lanes, \
             fifo = pre-PR4 first-ready-inline dispatch",
            dag.len()
        ),
    );
    let variants: [(&str, RunOptions); 2] = [
        ("critical-path", RunOptions::new()),
        ("fifo", RunOptions::new().critical_path(false).priority_lanes(false)),
    ];
    let param = format!("skewed{}x{reruns}", dag.len());
    for (label, options) in &variants {
        let (mut g, _counter) = dag.to_task_graph(steps);
        g.run_with_options(&pool, options.clone()).unwrap(); // warm + seal reuse
        let summary = bench_wall(&opts, || {
            for _ in 0..reruns {
                g.run_with_options(&pool, options.clone()).unwrap();
            }
        });
        report.push(param.clone(), *label, summary);
        eprintln!("  makespan variant {label} done");
    }
    report.print();
    record_json("priority_makespan", "wall", threads, &report);
    if let Some(r) = report.speedup(&param, "critical-path", "fifo") {
        println!("SHAPE critical-path-wins@{param}: {r:.2}x {}", if r >= 1.0 { "PASS" } else { "CHECK" });
    }

    // ---- ABL-7: the PR 4 toggles swept independently ----------------
    let mut report = Report::new(
        "ABL-7 priority toggles (PR 4)",
        format!(
            "same skewed weighted DAG, {reruns} re-runs per sample, {threads} threads; \
             critical-path dispatch and injector priority lanes disabled one at a time \
             (all-off = the pre-PR 4 FIFO scheduling path); no-dynamic-rank (PR 8) turns \
             off duration sampling + re-ranking — truthful declared weights make it an \
             overhead probe, not a scheduling change"
        ),
    );
    let ablations: [(&str, RunOptions); 5] = [
        ("all-on", RunOptions::new()),
        ("no-critical-path", RunOptions::new().critical_path(false)),
        ("no-priority-lanes", RunOptions::new().priority_lanes(false)),
        ("all-off", RunOptions::new().critical_path(false).priority_lanes(false)),
        ("no-dynamic-rank", RunOptions::new().dynamic_rank(false)),
    ];
    for (label, options) in &ablations {
        let (mut g, _counter) = dag.to_task_graph(steps);
        g.run_with_options(&pool, options.clone()).unwrap();
        let summary = bench_wall(&opts, || {
            for _ in 0..reruns {
                g.run_with_options(&pool, options.clone()).unwrap();
            }
        });
        report.push(param.clone(), *label, summary);
        eprintln!("  toggle variant {label} done");
    }
    report.print();
    record_json("priority_toggles", "wall", threads, &report);

    // ---- PRIO mixed-priority fleet: per-class completion latency ----
    // 9 diamond-chain graphs launched from one thread per round, tagged
    // High/Normal/Low in thirds. All sources funnel through the
    // injector's priority lanes, so High-class runs should complete
    // (strictly: be observed complete) earlier on average. Latency per
    // class = time from fleet launch to the last handle of that class
    // reporting done, sampled over many rounds by polling the fleet.
    let (fleet_size, diamonds, fleet_steps, rounds) =
        if fast { (9, 24, 200, 20) } else { (9, 64, 400, 60) };
    let classes = [RunPriority::High, RunPriority::Normal, RunPriority::Low];
    let mut graphs: Vec<_> = (0..fleet_size)
        .map(|_| Dag::diamond_chain(diamonds).to_task_graph(fleet_steps))
        .collect();
    // Warm every graph (seals state, sizes queues).
    for (g, _) in graphs.iter_mut() {
        g.run(&pool).unwrap();
    }
    let mut per_class: [Vec<Duration>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for _ in 0..rounds {
        let launch = Instant::now();
        let mut handles: Vec<_> = graphs
            .iter_mut()
            .enumerate()
            .map(|(i, (g, _))| {
                let class = classes[i % classes.len()];
                g.run_async_with_options(&pool, RunOptions::new().priority(class)).unwrap()
            })
            .collect();
        // Poll until each class's last handle reports done, stamping
        // the completion time per class.
        let mut class_done: [Option<Duration>; 3] = [None; 3];
        while class_done.iter().any(|d| d.is_none()) {
            for (ci, done) in class_done.iter_mut().enumerate() {
                if done.is_none()
                    && handles
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % classes.len() == ci)
                        .all(|(_, h)| h.is_done())
                {
                    *done = Some(launch.elapsed());
                }
            }
            std::hint::spin_loop();
        }
        for (ci, d) in class_done.iter().enumerate() {
            per_class[ci].push(d.unwrap());
        }
        for h in handles.drain(..) {
            h.wait().unwrap();
        }
    }
    let mut report = Report::new(
        "PRIO mixed-priority fleet (PR 4)",
        format!(
            "{fleet_size} async diamond-chain graphs ({}-node) in flight per round, \
             classes High/Normal/Low in thirds, {rounds} rounds, {threads} threads; \
             per-class latency = launch -> last handle of the class done (polled)",
            diamonds * 4
        ),
    );
    let fleet_param = format!("fleet{fleet_size}x{}", diamonds * 4);
    for (ci, class) in classes.iter().enumerate() {
        report.push(fleet_param.clone(), class.as_str(), Summary::from_samples(&per_class[ci]));
    }
    // Whole-round throughput through the MultiRun driver + wait_all
    // combinator (the same mixed-class fleet, drained by parking on the
    // run eventcount instead of polling).
    let class_options: Vec<RunOptions> =
        classes.iter().map(|&c| RunOptions::new().priority(c)).collect();
    let mut mr = MultiRun::new(fleet_size, diamonds, fleet_steps);
    mr.run_round_with_options(&pool, &class_options).unwrap(); // warm
    let summary = bench_wall(&opts, || {
        mr.run_round_with_options(&pool, &class_options).unwrap();
    });
    assert!(mr.verify_exactly_once(), "mixed-class fleet: exactly-once violated");
    report.push(fleet_param.clone(), "round-wait_all", summary);
    report.print();
    record_json("priority_fleet", "wall", threads, &report);
    if let Some(r) = report.speedup(&fleet_param, "high", "low") {
        println!(
            "SHAPE class-tiering@{fleet_param}: {r:.2}x {}",
            if r >= 1.0 { "PASS" } else { "CHECK" }
        );
    }
}
