//! MLP inference serving — batched requests through the pool + PJRT.
//!
//! A miniature serving driver: a closed-loop load generator produces
//! inference requests (batch 32, d=64 feature vectors); the pool runs
//! each request as a task whose body executes the two-layer MLP
//! executable (`mlp2_64`: L1 Pallas matmul + fused bias/GeLU kernels).
//! Reports throughput and latency percentiles, and verifies a sample
//! of responses against host math.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example mlp_inference -- [REQUESTS] [THREADS]`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, HostTensor, Registry, Runtime};

fn main() -> scheduling::util::error::Result<()> {
    let mut args = std::env::args().skip(1);
    let requests: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(200);
    let threads: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(2);

    if find_artifacts_dir().is_none() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let runtime = Arc::new(Runtime::cpu()?);
    let registry = Registry::open_default(runtime)?;
    let exe = registry.get("mlp2_64")?;

    // Fixed model weights (shared by all requests).
    let w1 = Arc::new(HostTensor::random(&[64, 128], 100));
    let b1 = Arc::new(HostTensor::random(&[128], 101));
    let w2 = Arc::new(HostTensor::random(&[128, 64], 102));
    let b2 = Arc::new(HostTensor::random(&[64], 103));

    let pool = ThreadPool::new(threads);
    let latencies: Arc<Mutex<Vec<Duration>>> = Arc::new(Mutex::new(Vec::with_capacity(requests)));
    let errors = Arc::new(AtomicUsize::new(0));
    let checked = Arc::new(AtomicUsize::new(0));

    println!("serving {requests} requests (batch 32, 64->128->64 MLP) on {threads} workers");
    let start = Instant::now();
    for req in 0..requests {
        let exe = exe.clone();
        let (w1, b1, w2, b2) = (w1.clone(), b1.clone(), w2.clone(), b2.clone());
        let (latencies, errors, checked) = (latencies.clone(), errors.clone(), checked.clone());
        pool.submit(move || {
            let t0 = Instant::now();
            let x = HostTensor::random(&[32, 64], req as u64);
            match exe.run1(&[x.clone(), (*w1).clone(), (*b1).clone(), (*w2).clone(), (*b2).clone()]) {
                Ok(y) => {
                    if y.shape != vec![32, 64] {
                        errors.fetch_add(1, Ordering::Relaxed);
                    } else if req % 50 == 0 {
                        // Spot-check numerics against host math.
                        let h = mlp2_host(&x, &w1, &b1, &w2, &b2);
                        if y.allclose(&h, 1e-3, 1e-3) {
                            checked.fetch_add(1, Ordering::Relaxed);
                        } else {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    latencies.lock().unwrap().push(t0.elapsed());
                }
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        });
    }
    pool.wait_idle();
    let took = start.elapsed();

    let mut lat = latencies.lock().unwrap().clone();
    lat.sort_unstable();
    scheduling::ensure!(errors.load(Ordering::Relaxed) == 0, "request errors");
    scheduling::ensure!(lat.len() == requests, "lost requests");
    let pct = |p: f64| lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)];
    println!(
        "throughput: {:.1} req/s ({} requests in {:.2?})",
        requests as f64 / took.as_secs_f64(),
        requests,
        took
    );
    println!(
        "latency: p50 {:.2?}  p90 {:.2?}  p99 {:.2?}  max {:.2?}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lat[lat.len() - 1]
    );
    println!(
        "verified {} sampled responses against host math; kernel executions: {}",
        checked.load(Ordering::Relaxed),
        exe.executions()
    );
    println!("mlp_inference OK");
    Ok(())
}

fn mlp2_host(
    x: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
    b2: &HostTensor,
) -> HostTensor {
    let layer = |x: &HostTensor, w: &HostTensor, b: &HostTensor| {
        let xw = x.matmul_ref(w);
        let d = w.shape[1];
        HostTensor::from_fn(&xw.shape.clone(), |idx| {
            let z = xw.data[idx] + b.data[idx % d];
            let inner = 0.797_884_6_f32 * (z + 0.044715 * z * z * z);
            0.5 * z * (1.0 + inner.tanh())
        })
    };
    layer(&layer(x, w1, b1), w2, b2)
}
