//! MLP inference serving — batched requests through the pool + PJRT.
//!
//! A miniature serving driver: a closed-loop load generator produces
//! inference requests (batch 32, d=64 feature vectors); the pool runs
//! each request as a task whose body executes the two-layer MLP
//! executable (`mlp2_64`: L1 Pallas matmul + fused bias/GeLU kernels).
//! Reports throughput and latency percentiles, and verifies a sample
//! of responses against host math.
//!
//! With `make artifacts` built, requests run on the PJRT executable
//! and are spot-checked against the host oracle; without artifacts the
//! same serving loop runs entirely on the cache-blocked host kernels
//! (`HostTensor::matmul_blocked`, PR 10) — slower, but numerically the
//! same model, so the example works out of the box. Run:
//! `cargo run --release --example mlp_inference -- [REQUESTS] [THREADS]`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, Executable, HostTensor, Registry, Runtime};

fn main() -> scheduling::util::error::Result<()> {
    let mut args = std::env::args().skip(1);
    let requests: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(200);
    let threads: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(2);

    // PJRT when artifacts exist, cache-blocked host kernels otherwise.
    let exe: Option<Arc<Executable>> = if find_artifacts_dir().is_some() {
        let runtime = Arc::new(Runtime::cpu()?);
        let registry = Registry::open_default(runtime)?;
        Some(registry.get("mlp2_64")?)
    } else {
        eprintln!("artifacts not built — serving with the cache-blocked host kernels instead");
        None
    };

    // Fixed model weights (shared by all requests).
    let w1 = Arc::new(HostTensor::random(&[64, 128], 100));
    let b1 = Arc::new(HostTensor::random(&[128], 101));
    let w2 = Arc::new(HostTensor::random(&[128, 64], 102));
    let b2 = Arc::new(HostTensor::random(&[64], 103));

    let pool = ThreadPool::new(threads);
    let latencies: Arc<Mutex<Vec<Duration>>> = Arc::new(Mutex::new(Vec::with_capacity(requests)));
    let errors = Arc::new(AtomicUsize::new(0));
    let checked = Arc::new(AtomicUsize::new(0));

    let backend = if exe.is_some() { "pjrt" } else { "host-blocked" };
    println!(
        "serving {requests} requests (batch 32, 64->128->64 MLP, {backend} kernels) on {threads} workers"
    );
    let start = Instant::now();
    for req in 0..requests {
        let exe = exe.clone();
        let (w1, b1, w2, b2) = (w1.clone(), b1.clone(), w2.clone(), b2.clone());
        let (latencies, errors, checked) = (latencies.clone(), errors.clone(), checked.clone());
        pool.submit(move || {
            let t0 = Instant::now();
            let x = HostTensor::random(&[32, 64], req as u64);
            let result = match &exe {
                Some(exe) => exe.run1(&[
                    x.clone(),
                    (*w1).clone(),
                    (*b1).clone(),
                    (*w2).clone(),
                    (*b2).clone(),
                ]),
                None => Ok(mlp2_host(&x, &w1, &b1, &w2, &b2)),
            };
            match result {
                Ok(y) => {
                    if y.shape != vec![32, 64] {
                        errors.fetch_add(1, Ordering::Relaxed);
                    } else if req % 50 == 0 {
                        // Spot-check numerics against host math (for
                        // the host backend this cross-checks the
                        // blocked kernels against the naive oracle).
                        let h = mlp2_host_ref(&x, &w1, &b1, &w2, &b2);
                        if y.allclose(&h, 1e-3, 1e-3) {
                            checked.fetch_add(1, Ordering::Relaxed);
                        } else {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    latencies.lock().unwrap().push(t0.elapsed());
                }
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        });
    }
    pool.wait_idle();
    let took = start.elapsed();

    let mut lat = latencies.lock().unwrap().clone();
    lat.sort_unstable();
    scheduling::ensure!(errors.load(Ordering::Relaxed) == 0, "request errors");
    scheduling::ensure!(lat.len() == requests, "lost requests");
    let pct = |p: f64| lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)];
    println!(
        "throughput: {:.1} req/s ({} requests in {:.2?})",
        requests as f64 / took.as_secs_f64(),
        requests,
        took
    );
    println!(
        "latency: p50 {:.2?}  p90 {:.2?}  p99 {:.2?}  max {:.2?}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lat[lat.len() - 1]
    );
    match &exe {
        Some(exe) => println!(
            "verified {} sampled responses against host math; kernel executions: {}",
            checked.load(Ordering::Relaxed),
            exe.executions()
        ),
        None => println!(
            "verified {} sampled responses against the naive host oracle",
            checked.load(Ordering::Relaxed)
        ),
    }
    println!("mlp_inference OK");
    Ok(())
}

/// Two-layer MLP on the fast host path: cache-blocked matmuls + fused
/// bias/GeLU loop.
fn mlp2_host(
    x: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
    b2: &HostTensor,
) -> HostTensor {
    let layer = |x: &HostTensor, w: &HostTensor, b: &HostTensor| {
        let mut xw = x.matmul_blocked(w);
        let d = w.shape[1];
        for (idx, z) in xw.data.iter_mut().enumerate() {
            *z = gelu(*z + b.data[idx % d]);
        }
        xw
    };
    layer(&layer(x, w1, b1), w2, b2)
}

/// The naive oracle (`matmul_ref`) used for spot checks.
fn mlp2_host_ref(
    x: &HostTensor,
    w1: &HostTensor,
    b1: &HostTensor,
    w2: &HostTensor,
    b2: &HostTensor,
) -> HostTensor {
    let layer = |x: &HostTensor, w: &HostTensor, b: &HostTensor| {
        let xw = x.matmul_ref(w);
        let d = w.shape[1];
        HostTensor::from_fn(&xw.shape.clone(), |idx| gelu(xw.data[idx] + b.data[idx % d]))
    };
    layer(&layer(x, w1, b1), w2, b2)
}

/// Tanh-approximation GeLU, matching the compiled kernel.
fn gelu(z: f32) -> f32 {
    let inner = 0.797_884_6_f32 * (z + 0.044715 * z * z * z);
    0.5 * z * (1.0 + inner.tanh())
}
