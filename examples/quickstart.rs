//! Quickstart — the paper's §4 walkthrough, verbatim in Rust.
//!
//! 1. Async tasks: create a `ThreadPool`, submit closures (§4.1).
//! 2. Task graphs: build the `(a+b)*(c+d)` graph, declare dependencies
//!    with `succeed`, submit, wait (§4.2).
//! 3. The same graph through the typed `Dataflow` extension.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::atomic::{AtomicI32, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

use scheduling::graph::{Dataflow, TaskGraph};
use scheduling::pool::ThreadPool;

fn main() {
    // ---- §4.1 async tasks -------------------------------------------
    // "In the constructor, the ThreadPool class creates several worker
    // threads that will be running in the background..."
    let thread_pool = ThreadPool::with_default_threads();

    // "When the ThreadPool instance is created, submit a task."
    thread_pool.submit(|| {
        std::thread::sleep(Duration::from_millis(100));
        println!("Completed");
    });
    thread_pool.wait_idle();

    // ---- §4.2 task graphs -------------------------------------------
    // Calculate (a + b) * (c + d); every operation takes time, so the
    // four leaf reads run in parallel, the two sums run in parallel,
    // and the product runs last.
    let a = Arc::new(AtomicI32::new(0));
    let b = Arc::new(AtomicI32::new(0));
    let c = Arc::new(AtomicI32::new(0));
    let d = Arc::new(AtomicI32::new(0));
    let sum_ab = Arc::new(AtomicI32::new(0));
    let sum_cd = Arc::new(AtomicI32::new(0));
    let product = Arc::new(AtomicI32::new(0));

    let mut tasks = TaskGraph::new();
    let slow = Duration::from_millis(100);
    let get_a = {
        let a = a.clone();
        tasks.add_named("get_a", move || {
            std::thread::sleep(slow);
            a.store(1, Relaxed);
        })
    };
    let get_b = {
        let b = b.clone();
        tasks.add_named("get_b", move || {
            std::thread::sleep(slow);
            b.store(2, Relaxed);
        })
    };
    let get_c = {
        let c = c.clone();
        tasks.add_named("get_c", move || {
            std::thread::sleep(slow);
            c.store(3, Relaxed);
        })
    };
    let get_d = {
        let d = d.clone();
        tasks.add_named("get_d", move || {
            std::thread::sleep(slow);
            d.store(4, Relaxed);
        })
    };
    let get_sum_ab = {
        let (a, b, s) = (a.clone(), b.clone(), sum_ab.clone());
        tasks.add_named("get_sum_ab", move || {
            std::thread::sleep(slow);
            s.store(a.load(Relaxed) + b.load(Relaxed), Relaxed);
        })
    };
    let get_sum_cd = {
        let (c, d, s) = (c.clone(), d.clone(), sum_cd.clone());
        tasks.add_named("get_sum_cd", move || {
            std::thread::sleep(slow);
            s.store(c.load(Relaxed) + d.load(Relaxed), Relaxed);
        })
    };
    let get_product = {
        let (x, y, p) = (sum_ab.clone(), sum_cd.clone(), product.clone());
        tasks.add_named("get_product", move || {
            std::thread::sleep(slow);
            p.store(x.load(Relaxed) * y.load(Relaxed), Relaxed);
        })
    };

    // "When all tasks are added, define task dependencies."
    tasks.succeed(get_sum_ab, &[get_a, get_b]);
    tasks.succeed(get_sum_cd, &[get_c, get_d]);
    tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);

    let start = std::time::Instant::now();
    tasks.run(&thread_pool).expect("graph run");
    let took = start.elapsed();
    println!("(a+b)*(c+d) = {} in {took:?}", product.load(Relaxed));
    assert_eq!(product.load(Relaxed), 21);
    // With >= 2 workers the three levels pipeline: ~3 sleeps, not 7.
    if thread_pool.num_threads() >= 2 {
        assert!(took < Duration::from_millis(700), "graph did not parallelize: {took:?}");
    }

    // ---- repeated runs: seal once, re-run for free ------------------
    // The paper's §4.2 benchmarks re-run the same `tasks` collection;
    // sealing freezes the topology into a CSR arena so every run after
    // the first performs zero heap allocations, and the calling thread
    // helps execute nodes instead of sleeping on a condvar.
    let runs = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let mut hot = TaskGraph::new();
    let first = {
        let r = runs.clone();
        hot.add(move || {
            r.fetch_add(1, Relaxed);
        })
    };
    let second = {
        let r = runs.clone();
        hot.add(move || {
            r.fetch_add(1, Relaxed);
        })
    };
    hot.succeed(second, &[first]);
    hot.seal().expect("seal");
    for _ in 0..10_000 {
        hot.run(&thread_pool).expect("sealed re-run");
    }
    assert_eq!(runs.load(Relaxed), 20_000);
    println!("sealed graph re-ran 10k times ({} node executions)", runs.load(Relaxed));

    // ---- same graph, typed dataflow ---------------------------------
    let mut df = Dataflow::new();
    let a = df.node("a", || 1);
    let b = df.node("b", || 2);
    let c = df.node("c", || 3);
    let d = df.node("d", || 4);
    let ab = df.node2("a+b", &a, &b, |x, y| x + y);
    let cd = df.node2("c+d", &c, &d, |x, y| x + y);
    let product = df.node2("product", &ab, &cd, |x, y| x * y);
    df.run(&thread_pool).expect("dataflow run");
    println!("dataflow (a+b)*(c+d) = {}", product.take().unwrap());

    println!("quickstart OK");
}
