//! Fibonacci — the paper's §3 benchmark workload as a runnable
//! example: compute fib(N) by spawning the full recursive call tree as
//! tasks on every executor, and print a mini comparison table.
//!
//! Run: `cargo run --release --example fibonacci -- [N] [THREADS]`

use std::sync::Arc;
use std::time::Instant;

use scheduling::baseline::all_executors;
use scheduling::util::process_cpu_time;
use scheduling::workloads::{fib_reference, fib_task_count, run_fib};

fn main() {
    let mut args = std::env::args().skip(1);
    let n: u32 = args.next().and_then(|v| v.parse().ok()).unwrap_or(22);
    let threads: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(2);
    let expected = fib_reference(n);
    println!(
        "fib({n}) = {expected} — {} tasks per run, {threads} worker threads\n",
        fib_task_count(n)
    );
    println!("{:<16} {:>12} {:>12} {:>14}", "executor", "wall", "cpu", "ns/task");

    for ex in all_executors(threads) {
        if ex.name() == "spawn-per-task" && n > 18 {
            println!("{:<16} {:>12} {:>12} {:>14}", ex.name(), "(skipped)", "-", "-");
            continue;
        }
        let ex: Arc<_> = ex;
        let wall_start = Instant::now();
        let cpu_start = process_cpu_time();
        let got = run_fib(&ex, n);
        let wall = wall_start.elapsed();
        let cpu = process_cpu_time().saturating_sub(cpu_start);
        assert_eq!(got, expected, "{} computed a wrong value", ex.name());
        let per_task = wall.as_nanos() as f64 / fib_task_count(n) as f64;
        println!(
            "{:<16} {:>12} {:>12} {:>12.0}ns",
            ex.name(),
            format!("{:.2?}", wall),
            format!("{:.2?}", cpu),
            per_task
        );
    }
    println!("\nfibonacci OK");
}
