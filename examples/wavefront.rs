//! Wavefront — end-to-end three-layer driver (the E2E deliverable).
//!
//! Solves a Laplace boundary-value problem by Jacobi relaxation where
//! **every graph node executes an AOT-compiled XLA executable** (the
//! `jacobi_64` artifact: L1 Pallas stencil kernel inside an L2 jax
//! graph), coordinated by the L3 work-stealing pool:
//!
//! * the domain is a lattice of 64×64 tiles relaxed block-Jacobi style:
//!   each sweep is a task graph with one node per tile (+ halo exchange
//!   dependencies handled between sweeps on the host);
//! * also runs a blocked matmul on the same pool to show two kernel
//!   families coexisting.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example wavefront -- [TILES] [SWEEPS] [THREADS]`

use std::sync::{Arc, Mutex};
use std::time::Instant;

use scheduling::graph::TaskGraph;
use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, HostTensor, Registry, Runtime};
use scheduling::workloads::matmul_graph::{BlockedMatmul, MatmulSchedule};

const TILE: usize = 64;

fn main() -> scheduling::util::error::Result<()> {
    let mut args = std::env::args().skip(1);
    let tiles: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(3);
    let sweeps: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(30);
    let threads: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(2);

    if find_artifacts_dir().is_none() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let runtime = Arc::new(Runtime::cpu()?);
    println!("PJRT platform: {}", runtime.platform());
    let registry = Registry::open_default(runtime)?;
    let jacobi = registry.get("jacobi_64")?;
    let pool = ThreadPool::new(threads);

    // Hot interior, cold boundary; relax until the residual decays.
    let mut grid: Vec<Vec<HostTensor>> = (0..tiles)
        .map(|_| (0..tiles).map(|_| HostTensor::full(&[TILE, TILE], 1.0)).collect())
        .collect();
    for j in 0..tiles {
        for x in 0..TILE {
            grid[0][j].data[x] = 0.0; // global top edge
            grid[tiles - 1][j].data[(TILE - 1) * TILE + x] = 0.0; // bottom
        }
    }
    for i in 0..tiles {
        for y in 0..TILE {
            grid[i][0].data[y * TILE] = 0.0; // left
            grid[i][tiles - 1].data[y * TILE + TILE - 1] = 0.0; // right
        }
    }

    println!(
        "block-Jacobi: {tiles}x{tiles} tiles of {TILE}x{TILE} ({} unknowns), {sweeps} sweeps, {threads} threads",
        tiles * tiles * TILE * TILE
    );
    let start = Instant::now();
    let mut last_residual = f32::MAX;
    for sweep in 0..sweeps {
        // One sweep = one task graph: every tile relaxes in parallel on
        // the pool, each node invoking the PJRT executable.
        let results: Arc<Vec<Vec<Mutex<Option<(HostTensor, f32)>>>>> = Arc::new(
            (0..tiles).map(|_| (0..tiles).map(|_| Mutex::new(None)).collect()).collect(),
        );
        let mut g = TaskGraph::with_capacity(tiles * tiles);
        for i in 0..tiles {
            for j in 0..tiles {
                let input = grid[i][j].clone();
                let (exe, results) = (jacobi.clone(), results.clone());
                g.add_named(format!("tile({i},{j})"), move || {
                    let outs = exe.run(&[input.clone()]).expect("jacobi kernel");
                    let residual = outs[1].data[0];
                    let out = outs.into_iter().next().unwrap();
                    *results[i][j].lock().unwrap() = Some((out, residual));
                });
            }
        }
        g.run(&pool).map_err(|e| scheduling::anyhow!("{e}"))?;

        last_residual = 0.0f32;
        for i in 0..tiles {
            for j in 0..tiles {
                let (out, r) = results[i][j].lock().unwrap().take().expect("tile result");
                grid[i][j] = out;
                last_residual = last_residual.max(r);
            }
        }
        // Halo exchange: copy neighbouring edges (host-side, cheap).
        for i in 0..tiles {
            for j in 0..tiles {
                if i + 1 < tiles {
                    for x in 0..TILE {
                        let v = grid[i + 1][j].data[TILE + x]; // their row 1
                        grid[i][j].data[(TILE - 1) * TILE + x] = v;
                        let v = grid[i][j].data[(TILE - 2) * TILE + x];
                        grid[i + 1][j].data[x] = v;
                    }
                }
                if j + 1 < tiles {
                    for y in 0..TILE {
                        let v = grid[i][j + 1].data[y * TILE + 1];
                        grid[i][j].data[y * TILE + TILE - 1] = v;
                        let v = grid[i][j].data[y * TILE + TILE - 2];
                        grid[i][j + 1].data[y * TILE] = v;
                    }
                }
            }
        }
        if sweep % 10 == 0 || sweep == sweeps - 1 {
            println!("  sweep {sweep:>3}: residual {last_residual:.5}");
        }
    }
    let took = start.elapsed();
    println!(
        "relaxation done in {took:.2?} ({} kernel executions, residual {last_residual:.5})",
        jacobi.executions()
    );
    scheduling::ensure!(last_residual < 1.0, "residual did not decay");
    println!("pool metrics after relaxation:\n{}", pool.metrics());

    // Second kernel family on the same pool: blocked matmul.
    let a = HostTensor::random(&[128, 128], 7);
    let b = HostTensor::random(&[128, 128], 8);
    let mm = BlockedMatmul::new(&registry, &a, &b, 32)?;
    let start = Instant::now();
    let c = mm.run(&pool, MatmulSchedule::Wavefront)?;
    let expected = a.matmul_ref(&b);
    let diff = c.max_abs_diff(&expected);
    scheduling::ensure!(diff < 1e-3, "matmul verification failed: {diff}");
    println!("blocked matmul 128x128/32 verified in {:.2?} (max diff {diff:.2e})", start.elapsed());

    println!("wavefront OK");
    Ok(())
}
