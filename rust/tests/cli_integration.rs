//! Binary-level integration tests: run the `scheduling` launcher the
//! way a user would and check its output contract.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_scheduling"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = run(&[]);
    assert!(ok);
    assert!(text.contains("commands:"));
    assert!(text.contains("graph-demo"));
}

#[test]
fn info_reports_executors() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("scheduling ("));
    assert!(text.contains("taskflow-like"));
    assert!(text.contains("mutex-pool"));
}

#[test]
fn unknown_command_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn run_fib_verifies_result() {
    let (ok, text) = run(&["run", "fib", "--n", "15", "--threads", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("fib(15) = 610"), "{text}");
}

#[test]
fn run_fib_on_each_executor() {
    for ex in ["scheduling", "taskflow", "mutex", "spawn"] {
        let (ok, text) = run(&["run", "fib", "--n", "10", "--executor", ex, "--threads", "2"]);
        assert!(ok, "{ex}: {text}");
        assert!(text.contains("fib(10) = 55"), "{ex}: {text}");
    }
}

#[test]
fn run_wavefront_graph_with_trace() {
    let trace_path = std::env::temp_dir().join("scheduling_cli_trace_test.json");
    let trace_str = trace_path.to_str().unwrap();
    let (ok, text) = run(&[
        "run", "wavefront", "--size", "8", "--threads", "2", "--trace", "--out", trace_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("all nodes executed"), "{text}");
    assert!(text.contains("chrome trace written"), "{text}");
    let json = std::fs::read_to_string(&trace_path).unwrap();
    assert!(json.trim_start().starts_with('['));
    assert_eq!(json.matches("\"ph\":\"X\"").count(), 64);
    let _ = std::fs::remove_file(&trace_path);
}

#[test]
fn run_chain_on_countdown_executor() {
    let (ok, text) = run(&["run", "chain", "--size", "500", "--executor", "taskflow", "--threads", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("all nodes executed"), "{text}");
}

#[test]
fn graph_demo_computes_21() {
    let (ok, text) = run(&["graph-demo"]);
    assert!(ok, "{text}");
    assert!(text.contains("(a+b)*(c+d) = 21"));
}

#[test]
fn bad_flag_value_reports_error() {
    let (ok, text) = run(&["run", "fib", "--n", "many"]);
    assert!(!ok);
    assert!(text.contains("error"), "{text}");
}

#[test]
fn config_file_provides_defaults() {
    let cfg = std::env::temp_dir().join("scheduling_cli_cfg_test.conf");
    std::fs::write(&cfg, "n = 12\nthreads = 2\n").unwrap();
    let (ok, text) = run(&["run", "fib", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("fib(12) = 144"), "{text}");
    let _ = std::fs::remove_file(&cfg);
}

#[test]
fn artifacts_listing_when_built() {
    // Only meaningful when artifacts exist; the command itself must
    // not crash either way.
    let (ok, text) = run(&["artifacts"]);
    if ok {
        assert!(text.contains("matmul_tile_64"), "{text}");
        assert!(text.contains("f32[64,64]"), "{text}");
    } else {
        assert!(text.contains("artifacts"), "{text}");
    }
}
