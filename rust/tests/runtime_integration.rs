//! Integration tests across the runtime: load AOT artifacts, execute
//! them from pool workers, verify numerics against host references.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a stderr note) when the artifacts directory is missing so
//! `cargo test` stays green on a fresh checkout.

use std::sync::Arc;

use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, HostTensor, Registry, Runtime};
use scheduling::workloads::matmul_graph::{BlockedMatmul, MatmulSchedule};

fn registry() -> Option<(Arc<Runtime>, Registry)> {
    if find_artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = Arc::new(Runtime::cpu().expect("PJRT CPU client"));
    let reg = Registry::open_default(rt.clone()).expect("registry");
    Some((rt, reg))
}

#[test]
fn axpy_smoke() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("axpy_256").unwrap();
    let alpha = HostTensor::from_vec(&[], vec![2.5]);
    let x = HostTensor::random(&[256], 1);
    let y = HostTensor::random(&[256], 2);
    let out = exe.run1(&[alpha.clone(), x.clone(), y.clone()]).unwrap();
    let expected = HostTensor::from_vec(
        &[256],
        x.data.iter().zip(&y.data).map(|(a, b)| 2.5 * a + b).collect(),
    );
    assert!(out.allclose(&expected, 1e-5, 1e-6), "diff={}", out.max_abs_diff(&expected));
    assert_eq!(exe.executions(), 1);
}

#[test]
fn matmul_tile_matches_host_reference() {
    let Some((_rt, reg)) = registry() else { return };
    for tile in [32usize, 64] {
        let exe = reg.get(&format!("matmul_tile_{tile}")).unwrap();
        let a = HostTensor::random(&[tile, tile], 10);
        let b = HostTensor::random(&[tile, tile], 11);
        let c = HostTensor::random(&[tile, tile], 12);
        let out = exe.run1(&[a.clone(), b.clone(), c.clone()]).unwrap();
        let expected = a.matmul_ref(&b).add_ref(&c);
        assert!(
            out.allclose(&expected, 1e-4, 1e-4),
            "tile={tile} diff={}",
            out.max_abs_diff(&expected)
        );
    }
}

#[test]
fn jacobi_executable_fixed_point() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("jacobi_64").unwrap();
    // Constant grid is a fixed point; residual must be 0.
    let g = HostTensor::full(&[64, 64], 3.0);
    let outs = exe.run(&[g.clone()]).unwrap();
    assert_eq!(outs.len(), 2, "jacobi returns (grid, residual)");
    assert!(outs[0].allclose(&g, 0.0, 1e-6));
    assert_eq!(outs[1].shape, Vec::<usize>::new());
    assert!(outs[1].data[0].abs() < 1e-6);
}

#[test]
fn jacobi_executable_decays_interior() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("jacobi_64").unwrap();
    let mut g = HostTensor::random(&[64, 64], 33);
    // Zero boundary.
    for i in 0..64 {
        g.data[i] = 0.0;
        g.data[63 * 64 + i] = 0.0;
        g.data[i * 64] = 0.0;
        g.data[i * 64 + 63] = 0.0;
    }
    let before: f32 = g.data.iter().map(|x| x.abs()).fold(0.0, f32::max);
    let mut cur = g;
    let mut residual = f32::MAX;
    for _ in 0..50 {
        let outs = exe.run(&[cur]).unwrap();
        residual = outs[1].data[0];
        cur = outs.into_iter().next().unwrap();
    }
    let after: f32 = cur.data.iter().map(|x| x.abs()).fold(0.0, f32::max);
    assert!(after < before, "relaxation should decay interior: {after} vs {before}");
    assert!(residual < before);
}

#[test]
fn concurrent_execution_from_pool_workers() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("matmul_tile_32").unwrap();
    let pool = ThreadPool::new(4);
    let errors = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    for seed in 0..32u64 {
        let exe = exe.clone();
        let errors = errors.clone();
        pool.submit(move || {
            let a = HostTensor::random(&[32, 32], seed);
            let b = HostTensor::random(&[32, 32], seed + 1000);
            let c = HostTensor::zeros(&[32, 32]);
            match exe.run1(&[a.clone(), b.clone(), c]) {
                Ok(out) => {
                    let expected = a.matmul_ref(&b);
                    if !out.allclose(&expected, 1e-4, 1e-4) {
                        errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
                Err(_) => {
                    errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
        });
    }
    pool.wait_idle();
    assert_eq!(errors.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(exe.executions(), 32);
}

#[test]
fn blocked_matmul_graph_end_to_end() {
    let Some((_rt, reg)) = registry() else { return };
    let a = HostTensor::random(&[128, 128], 7);
    let b = HostTensor::random(&[128, 128], 8);
    let expected = a.matmul_ref(&b);
    let pool = ThreadPool::new(3);
    for schedule in [MatmulSchedule::Independent, MatmulSchedule::Wavefront] {
        let mm = BlockedMatmul::new(&reg, &a, &b, 32).unwrap();
        assert_eq!(mm.num_tasks(), 16);
        let c = mm.run(&pool, schedule).unwrap();
        assert!(
            c.allclose(&expected, 1e-3, 1e-3),
            "schedule {schedule:?}: diff={}",
            c.max_abs_diff(&expected)
        );
    }
}

#[test]
fn mlp_layer_matches_host_math() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("mlp_layer_64x128").unwrap();
    let x = HostTensor::random(&[32, 64], 20);
    let w = HostTensor::random(&[64, 128], 21);
    let b = HostTensor::random(&[128], 22);
    let out = exe.run1(&[x.clone(), w.clone(), b.clone()]).unwrap();
    assert_eq!(out.shape, vec![32, 128]);
    // Host reference: gelu(x@w + b), tanh approximation.
    let xw = x.matmul_ref(&w);
    let expected = HostTensor::from_fn(&[32, 128], |idx| {
        let j = idx % 128;
        let z = xw.data[idx] + b.data[j];
        let inner = 0.797_884_6_f32 * (z + 0.044715 * z * z * z);
        0.5 * z * (1.0 + inner.tanh())
    });
    assert!(out.allclose(&expected, 1e-3, 1e-3), "diff={}", out.max_abs_diff(&expected));
}

#[test]
fn attention_scores_rows_sum_to_one() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("attention_scores_32x64").unwrap();
    let q = HostTensor::random(&[32, 64], 40);
    let k = HostTensor::random(&[32, 64], 41);
    let out = exe.run1(&[q, k]).unwrap();
    assert_eq!(out.shape, vec![32, 32]);
    for row in 0..32 {
        let s: f32 = out.data[row * 32..(row + 1) * 32].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {row} sums to {s}");
        assert!(out.data[row * 32..(row + 1) * 32].iter().all(|&p| p >= 0.0));
    }
}

#[test]
fn transformer_ffn_zero_weights_is_identity() {
    let Some((_rt, reg)) = registry() else { return };
    let exe = reg.get("transformer_ffn_64").unwrap();
    let x = HostTensor::random(&[32, 64], 50);
    let gamma = HostTensor::full(&[64], 1.0);
    let beta = HostTensor::zeros(&[64]);
    let w1 = HostTensor::zeros(&[64, 128]);
    let b1 = HostTensor::zeros(&[128]);
    let w2 = HostTensor::zeros(&[128, 64]);
    let b2 = HostTensor::zeros(&[64]);
    let out = exe.run1(&[x.clone(), gamma, beta, w1, b1, w2, b2]).unwrap();
    assert!(out.allclose(&x, 1e-5, 1e-5), "residual path broken: {}", out.max_abs_diff(&x));
}

#[test]
fn pipeline_end_to_end_with_trace() {
    use scheduling::graph::Tracer;
    use scheduling::workloads::Pipeline;

    let Some((_rt, reg)) = registry() else { return };
    let pipeline = Pipeline::new(&reg, 3).unwrap();
    assert_eq!(pipeline.num_stages(), 3);
    let pool = ThreadPool::new(2);
    let tracer = Arc::new(Tracer::new());
    // run() internally verifies micro-batch 0 against the host oracle.
    let outs = pipeline.run(&pool, 4, Some(tracer.clone())).unwrap();
    assert_eq!(outs.len(), 4);
    // The tracer saw all 12 nodes, named s{stage}m{microbatch}.
    assert_eq!(tracer.len(), 12);
    let names: Vec<String> = tracer.events().iter().map(|e| e.name.clone()).collect();
    assert!(names.contains(&"s0m0".to_string()));
    assert!(names.contains(&"s2m3".to_string()));
    // Chrome trace export shape.
    let json = tracer.to_chrome_trace();
    assert_eq!(json.matches("\"ph\":\"X\"").count(), 12);
    // Pipeline constraint in the recorded schedule: s0m0 starts first.
    let evs = tracer.events();
    assert_eq!(evs[0].name, "s0m0");
}

#[test]
fn registry_reports_entries_and_errors() {
    let Some((_rt, reg)) = registry() else { return };
    let names = reg.names();
    assert!(names.contains(&"matmul_tile_64"));
    assert!(names.contains(&"axpy_256"));
    let entry = reg.entry("matmul_tile_64").unwrap();
    assert_eq!(entry.inputs.len(), 3);
    assert_eq!(entry.outputs.len(), 1);
    assert_eq!(entry.inputs[0].dims, vec![64, 64]);
    assert!(reg.get("does_not_exist").is_err());
}

#[test]
fn warm_all_compiles_everything() {
    let Some((_rt, reg)) = registry() else { return };
    reg.warm_all().unwrap();
    for name in reg.names() {
        assert!(reg.get(name).is_ok(), "{name}");
    }
}
