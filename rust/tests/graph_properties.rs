//! Property-based tests over randomly generated task graphs.
//!
//! No proptest crate in the offline vendor set, so the harness is
//! explicit: a seeded PCG32 generates many random DAGs and for each
//! run the executor must uphold the §2.2 invariants:
//!
//! 1. **exactly-once** — every node runs exactly one time per run;
//! 2. **topological order** — every node observes all its
//!    predecessors' effects (checked via per-node completion stamps);
//! 3. **rerun soundness** — counters reset correctly, FnMut state
//!    persists;
//! 4. **schedule equivalence** — inline continuation on/off produce
//!    identical results;
//! 5. **panic robustness** — randomly panicking nodes never deadlock
//!    the run and are reported.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use scheduling::graph::{GraphError, RunOptions, RunPriority, TaskGraph};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::util::Pcg32;
use scheduling::workloads::Dag;

/// Random DAG: nodes 0..n, edges only i -> j with i < j (acyclic by
/// construction), edge probability `p` within a window of `w`.
fn random_dag(rng: &mut Pcg32, n: usize, w: usize, p: f64) -> Vec<Vec<usize>> {
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..(i + 1 + w).min(n) {
            if rng.next_f64() < p {
                adj[i].push(j);
            }
        }
    }
    adj
}

fn build_graph(
    adj: &[Vec<usize>],
) -> (TaskGraph, Arc<Vec<AtomicUsize>>, Arc<Vec<AtomicUsize>>, Arc<AtomicUsize>) {
    let n = adj.len();
    let runs: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
    let stamps: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
    let clock = Arc::new(AtomicUsize::new(1));
    let mut g = TaskGraph::with_capacity(n);
    let ids: Vec<_> = (0..n)
        .map(|i| {
            let (runs, stamps, clock) = (runs.clone(), stamps.clone(), clock.clone());
            g.add(move || {
                runs[i].fetch_add(1, Ordering::SeqCst);
                stamps[i].store(clock.fetch_add(1, Ordering::SeqCst), Ordering::SeqCst);
            })
        })
        .collect();
    for (i, succs) in adj.iter().enumerate() {
        for &s in succs {
            g.precede(ids[i], &[ids[s]]);
        }
    }
    (g, runs, stamps, clock)
}

#[test]
fn random_dags_exactly_once_and_topo_ordered() {
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0xDA6);
    for case in 0..25 {
        let n = 20 + rng.next_below(150) as usize;
        let w = 1 + rng.next_below(12) as usize;
        let p = 0.05 + rng.next_f64() * 0.5;
        let adj = random_dag(&mut rng, n, w, p);
        let (mut g, runs, stamps, _clock) = build_graph(&adj);
        g.run(&pool).unwrap_or_else(|e| panic!("case {case}: {e}"));

        for i in 0..n {
            assert_eq!(runs[i].load(Ordering::SeqCst), 1, "case {case}: node {i} run count");
        }
        for (i, succs) in adj.iter().enumerate() {
            let ti = stamps[i].load(Ordering::SeqCst);
            for &s in succs {
                let ts = stamps[s].load(Ordering::SeqCst);
                assert!(ti < ts, "case {case}: edge {i}->{s} violated ({ti} >= {ts})");
            }
        }
    }
}

#[test]
fn random_dags_rerun_many_times() {
    let pool = ThreadPool::new(2);
    let mut rng = Pcg32::seeded(77);
    let adj = random_dag(&mut rng, 120, 6, 0.3);
    let (mut g, runs, _stamps, _clock) = build_graph(&adj);
    for rep in 1..=10 {
        g.run(&pool).unwrap();
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), rep, "node {i} after {rep} runs");
        }
    }
}

#[test]
fn inline_and_resubmit_schedules_agree() {
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(99);
    for _ in 0..10 {
        let n = 30 + rng.next_below(100) as usize;
        let adj = random_dag(&mut rng, n, 8, 0.25);
        for inline in [true, false] {
            let (mut g, runs, stamps, _clock) = build_graph(&adj);
            g.run_with_options(&pool, RunOptions::inline(inline)).unwrap();
            for i in 0..n {
                assert_eq!(runs[i].load(Ordering::SeqCst), 1, "inline={inline} node {i}");
            }
            for (i, succs) in adj.iter().enumerate() {
                for &s in succs {
                    assert!(
                        stamps[i].load(Ordering::SeqCst) < stamps[s].load(Ordering::SeqCst),
                        "inline={inline} edge {i}->{s}"
                    );
                }
            }
        }
    }
}

#[test]
fn rerun_mode_toggles_agree_on_random_dags() {
    // The PR 2 re-run optimizations (CSR topology cache, run-state
    // reuse, caller assist) must be pure scheduling changes: every
    // combination yields exactly-once execution in topological order,
    // run after run.
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0x5EA1);
    for case in 0..6 {
        let n = 30 + rng.next_below(100) as usize;
        let adj = random_dag(&mut rng, n, 8, 0.25);
        for mask in 0..8u32 {
            let options = RunOptions {
                no_topology_cache: mask & 1 != 0,
                no_state_reuse: mask & 2 != 0,
                no_caller_assist: mask & 4 != 0,
                ..RunOptions::default()
            };
            let (mut g, runs, stamps, _clock) = build_graph(&adj);
            for rep in 1..=3 {
                g.run_with_options(&pool, options.clone()).unwrap();
                for i in 0..n {
                    assert_eq!(
                        runs[i].load(Ordering::SeqCst),
                        rep,
                        "case {case} mask {mask:#05b} node {i} after {rep} runs"
                    );
                }
                for (i, succs) in adj.iter().enumerate() {
                    for &s in succs {
                        assert!(
                            stamps[i].load(Ordering::SeqCst) < stamps[s].load(Ordering::SeqCst),
                            "case {case} mask {mask:#05b} edge {i}->{s}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sealed_topology_cache_invalidated_by_mutation() {
    // Mutating a sealed graph (add + succeed) must drop the CSR cache:
    // the next run has to honour the new nodes and the new edges, not
    // the frozen ones.
    let pool = ThreadPool::new(2);
    let log: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let mk = |i: usize, log: &Arc<Mutex<Vec<usize>>>| {
        let log = log.clone();
        move || log.lock().unwrap().push(i)
    };
    let mut g = TaskGraph::new();
    let n0 = g.add(mk(0, &log));
    let n1 = g.add(mk(1, &log));
    let n2 = g.add(mk(2, &log));
    g.succeed(n1, &[n0]);
    g.succeed(n2, &[n1]);
    g.seal().unwrap();
    assert!(g.is_sealed());
    g.run(&pool).unwrap();
    assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);

    // `add` un-seals: a brand-new node must run on the next run.
    let n3 = g.add(mk(3, &log));
    assert!(!g.is_sealed());
    // `succeed` on the re-sealed graph also un-seals it again.
    g.seal().unwrap();
    g.succeed(n3, &[n2]);
    assert!(!g.is_sealed());

    for rep in 2..=4 {
        log.lock().unwrap().clear();
        g.run(&pool).unwrap();
        assert!(g.is_sealed(), "run re-seals");
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3], "rep {rep}");
    }
}

#[test]
fn concurrent_runs_of_different_graphs_from_external_threads() {
    // One pool, several external threads, each repeatedly running its
    // OWN graph (with caller assist on by default, so helpers may even
    // execute each other's nodes). Every graph must stay exactly-once
    // and topologically ordered per run.
    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Pcg32::seeded(0xC0FFEE);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let adj = random_dag(&mut rng, 60 + t * 10, 6, 0.3);
            let pool = pool.clone();
            std::thread::spawn(move || {
                let n = adj.len();
                let (mut g, runs, stamps, _clock) = build_graph(&adj);
                for rep in 1..=8 {
                    g.run(&pool).unwrap();
                    for i in 0..n {
                        assert_eq!(runs[i].load(Ordering::SeqCst), rep, "thread {t} node {i}");
                    }
                    for (i, succs) in adj.iter().enumerate() {
                        for &s in succs {
                            assert!(
                                stamps[i].load(Ordering::SeqCst) < stamps[s].load(Ordering::SeqCst),
                                "thread {t} edge {i}->{s} rep {rep}"
                            );
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // The pool survives and is still usable.
    let ok = Arc::new(AtomicUsize::new(0));
    let o = ok.clone();
    pool.submit(move || {
        o.fetch_add(1, Ordering::SeqCst);
    });
    pool.wait_idle();
    assert_eq!(ok.load(Ordering::SeqCst), 1);
}

#[test]
fn random_panics_never_deadlock() {
    let pool = ThreadPool::new(2);
    let mut rng = Pcg32::seeded(1234);
    for case in 0..10 {
        let n = 40 + rng.next_below(60) as usize;
        let adj = random_dag(&mut rng, n, 5, 0.3);
        let panic_node = rng.next_below(n as u32) as usize;
        let executed: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let mut g = TaskGraph::with_capacity(n);
        let ids: Vec<_> = (0..n)
            .map(|i| {
                let executed = executed.clone();
                g.add(move || {
                    executed[i].fetch_add(1, Ordering::SeqCst);
                    if i == panic_node {
                        panic!("injected failure in node {i}");
                    }
                })
            })
            .collect();
        for (i, succs) in adj.iter().enumerate() {
            for &s in succs {
                g.precede(ids[i], &[ids[s]]);
            }
        }
        match g.run(&pool) {
            Err(GraphError::NodePanicked { node, payload, .. }) => {
                assert_eq!(node, panic_node, "case {case}");
                assert!(payload.contains("injected failure"));
            }
            other => panic!("case {case}: expected NodePanicked, got {other:?}"),
        }
        // Abort semantics (PR 6): the panic aborts the run, so every
        // node ran at most once, the panicking node exactly once, and
        // nodes dispatched after the abort were skipped — yet the run
        // drained to quiescence (run() returned) with exact counters.
        assert_eq!(executed[panic_node].load(Ordering::SeqCst), 1, "case {case} panic node");
        for i in 0..n {
            assert!(executed[i].load(Ordering::SeqCst) <= 1, "case {case} node {i} ran twice");
        }
        // The pool must remain usable.
        let ok = Arc::new(AtomicUsize::new(0));
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }
}

#[test]
fn dag_workload_generators_run_exactly_once_on_every_shape() {
    let pool = ThreadPool::new(2);
    for dag in [
        Dag::linear_chain(300),
        Dag::binary_tree(8),
        Dag::layered_random(8, 10, 0.4, 5),
        Dag::wavefront(10),
    ] {
        let (mut g, counter) = dag.to_task_graph(0);
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), dag.len(), "{}", dag.kind);
        // Re-run the same materialized graph.
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 2 * dag.len(), "{} rerun", dag.kind);
    }
}

#[test]
fn dataflow_diamond_under_many_seeds() {
    use scheduling::graph::Dataflow;
    let pool = ThreadPool::new(2);
    let mut rng = Pcg32::seeded(31415);
    for _ in 0..20 {
        let x0 = rng.next_below(1000) as i64;
        let mut df = Dataflow::new();
        let src = df.node("src", move || x0);
        let l = df.node1("l", &src, |x| x * 2);
        let r = df.node1("r", &src, |x| x + 10);
        let join = df.node2("join", &l, &r, |a, b| a + b);
        df.run(&pool).unwrap();
        assert_eq!(join.take().unwrap(), x0 * 2 + x0 + 10);
    }
}

#[test]
fn deep_chain_does_not_overflow_stack() {
    // Inline continuation is iterative (a loop, not recursion), so a
    // 100k-node chain must not blow the worker stack.
    let pool = ThreadPool::new(1);
    let dag = Dag::linear_chain(100_000);
    let (mut g, counter) = dag.to_task_graph(0);
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 100_000);
}

#[test]
fn graph_results_deterministic_under_scheduling_noise() {
    // A reduction over a random DAG must produce the same value no
    // matter how tasks interleave. Each node adds a node-specific
    // value to an accumulator observed by its successors via stamps.
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(2718);
    let adj = random_dag(&mut rng, 200, 10, 0.2);
    let expected: u64 = (0..200u64).map(|i| i * i).sum();
    for _ in 0..5 {
        let acc = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let ids: Vec<_> = (0..200)
            .map(|i| {
                let acc = acc.clone();
                g.add(move || {
                    acc.fetch_add((i * i) as usize, Ordering::Relaxed);
                })
            })
            .collect();
        for (i, succs) in adj.iter().enumerate() {
            for &s in succs {
                g.precede(ids[i], &[ids[s]]);
            }
        }
        g.run(&pool).unwrap();
        assert_eq!(acc.load(Ordering::SeqCst) as u64, expected);
    }
}

#[test]
fn empty_and_singleton_graphs() {
    let pool = ThreadPool::new(2);
    let mut g = TaskGraph::new();
    g.run(&pool).unwrap();

    let hit = Arc::new(AtomicUsize::new(0));
    let h = hit.clone();
    let mut g = TaskGraph::new();
    g.add(move || {
        h.fetch_add(1, Ordering::SeqCst);
    });
    g.run(&pool).unwrap();
    assert_eq!(hit.load(Ordering::SeqCst), 1);
}

#[test]
fn wide_independent_layer_all_sources() {
    // A graph with no edges: every node is a source; exercises bulk
    // injector submission + stealing.
    let pool = ThreadPool::new(4);
    let n = 5000;
    let counter = Arc::new(AtomicUsize::new(0));
    let mut g = TaskGraph::with_capacity(n);
    for _ in 0..n {
        let c = counter.clone();
        g.add(move || {
            c.fetch_add(1, Ordering::Relaxed);
        });
    }
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), n);
}

#[test]
fn property_matrix_shapes_sync_async_all_toggles() {
    // 36 random DAG shapes × {sync, async} × all 64 RunOptions toggle
    // combinations (PR 3 satellite, widened by the PR 4 priority bits),
    // with the run's priority class cycled per case. Per run the
    // executor must uphold exactly-once execution with node-count
    // conservation and topological-order visitation; the same graph
    // instance is reused across all 64 masks of a mode, so counters and
    // FnMut state also survive 64 consecutive re-arms. For async runs
    // the state-reuse and caller-assist bits are documented no-ops —
    // sweeping them anyway pins down that they stay harmless, and the
    // `no_critical_path`/`no_priority_lanes` bits must be pure
    // scheduling hints in every combination.
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0xA51C);
    for case in 0..36 {
        let n = 10 + rng.next_below(40) as usize;
        let w = 1 + rng.next_below(8) as usize;
        let p = 0.1 + rng.next_f64() * 0.4;
        let adj = random_dag(&mut rng, n, w, p);
        let class = [RunPriority::High, RunPriority::Normal, RunPriority::Low][case % 3];
        for run_async in [false, true] {
            let (mut g, runs, stamps, _clock) = build_graph(&adj);
            for mask in 0..64u32 {
                let options = RunOptions {
                    no_inline_continuation: mask & 1 != 0,
                    no_topology_cache: mask & 2 != 0,
                    no_state_reuse: mask & 4 != 0,
                    no_caller_assist: mask & 8 != 0,
                    no_critical_path: mask & 16 != 0,
                    no_priority_lanes: mask & 32 != 0,
                    priority: class,
                    ..RunOptions::default()
                };
                if run_async {
                    g.run_async_with_options(&pool, options).unwrap().wait().unwrap();
                } else {
                    g.run_with_options(&pool, options).unwrap();
                }
                let rep = mask as usize + 1;
                let mut total = 0;
                for i in 0..n {
                    let r = runs[i].load(Ordering::SeqCst);
                    assert_eq!(
                        r, rep,
                        "case {case} async={run_async} mask {mask:#08b} node {i} run count"
                    );
                    total += r;
                }
                assert_eq!(total, n * rep, "case {case} async={run_async}: node-count conservation");
                for (i, succs) in adj.iter().enumerate() {
                    let ti = stamps[i].load(Ordering::SeqCst);
                    for &s in succs {
                        assert!(
                            ti < stamps[s].load(Ordering::SeqCst),
                            "case {case} async={run_async} mask {mask:#08b} edge {i}->{s}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn property_matrix_on_sharded_pool() {
    // The PR 5 sharding bit of the matrix: the same §2.2 invariants on
    // sharded pools (2 shards of 2, and per-worker shards), sync and
    // async, over the scheduling toggle bits plus a cycled
    // RunOptions::shard pin (None / each shard / out-of-range). A
    // sharded pool changes only WHERE cross-thread submissions queue;
    // exactly-once, conservation, and topological order must be
    // untouched across consecutive re-arms on the same graph.
    for shard_size in [2usize, 1] {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 4,
            shard_size,
            ..PoolConfig::default()
        });
        assert!(pool.num_shards() > 1);
        let mut rng = Pcg32::seeded(0x5AAD ^ shard_size as u64);
        for case in 0..12 {
            let n = 10 + rng.next_below(40) as usize;
            let w = 1 + rng.next_below(8) as usize;
            let p = 0.1 + rng.next_f64() * 0.4;
            let adj = random_dag(&mut rng, n, w, p);
            for run_async in [false, true] {
                let (mut g, runs, stamps, _clock) = build_graph(&adj);
                let pins = [None, Some(0), Some(1), Some(usize::MAX)];
                for mask in 0..8u32 {
                    let mut options = RunOptions {
                        no_inline_continuation: mask & 1 != 0,
                        no_topology_cache: mask & 2 != 0,
                        no_priority_lanes: mask & 4 != 0,
                        ..RunOptions::default()
                    };
                    options.shard = pins[(mask as usize + case) % pins.len()];
                    if run_async {
                        g.run_async_with_options(&pool, options).unwrap().wait().unwrap();
                    } else {
                        g.run_with_options(&pool, options).unwrap();
                    }
                    let rep = mask as usize + 1;
                    let mut total = 0;
                    for i in 0..n {
                        let r = runs[i].load(Ordering::SeqCst);
                        assert_eq!(
                            r, rep,
                            "shard_size {shard_size} case {case} async={run_async} mask {mask:#05b} node {i}"
                        );
                        total += r;
                    }
                    assert_eq!(total, n * rep);
                    for (i, succs) in adj.iter().enumerate() {
                        let ti = stamps[i].load(Ordering::SeqCst);
                        for &s in succs {
                            assert!(
                                ti < stamps[s].load(Ordering::SeqCst),
                                "shard_size {shard_size} case {case} async={run_async} mask {mask:#05b} edge {i}->{s}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn async_handles_over_random_dags_in_flight_together() {
    // Several random graphs launched before any is waited on — the
    // async analogue of concurrent_runs_of_different_graphs, from ONE
    // thread. Exactly-once and topological order must hold per graph
    // even though their tasks interleave arbitrarily in the pool.
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0xF17);
    for round in 0..6 {
        let shapes: Vec<_> = (0..8).map(|_| random_dag(&mut rng, 40, 6, 0.3)).collect();
        let mut built: Vec<_> = shapes.iter().map(|adj| build_graph(adj)).collect();
        let handles: Vec<_> = built
            .iter_mut()
            .map(|(g, _, _, _)| g.run_async(&pool).unwrap())
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        for (t, ((_, runs, stamps, _clock), adj)) in built.iter().zip(&shapes).enumerate() {
            for (i, r) in runs.iter().enumerate() {
                assert_eq!(r.load(Ordering::SeqCst), 1, "round {round} graph {t} node {i}");
            }
            for (i, succs) in adj.iter().enumerate() {
                for &s in succs {
                    assert!(
                        stamps[i].load(Ordering::SeqCst) < stamps[s].load(Ordering::SeqCst),
                        "round {round} graph {t} edge {i}->{s}"
                    );
                }
            }
        }
    }
}

#[test]
fn weighted_random_dags_hold_invariants_under_every_priority_config() {
    // Random weights make the rank analysis non-trivial; topological
    // order, exactly-once, and node-count conservation must hold for
    // every (critical-path, lanes, class) combination, sync and async,
    // across re-runs of the same weighted graph.
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0x5E1F);
    for case in 0..6 {
        let n = 30 + rng.next_below(60) as usize;
        let adj = random_dag(&mut rng, n, 6, 0.3);
        let weights: Vec<u32> = (0..n).map(|_| 1 + rng.next_below(16)).collect();
        for run_async in [false, true] {
            // build_graph with per-node weights (`add_weighted`) plus a
            // set_weight exercise on node 0.
            let runs: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let stamps: Arc<Vec<AtomicUsize>> =
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let clock = Arc::new(AtomicUsize::new(1));
            let mut g = TaskGraph::with_capacity(n);
            let ids: Vec<_> = (0..n)
                .map(|i| {
                    let (runs, stamps, clock) = (runs.clone(), stamps.clone(), clock.clone());
                    g.add_weighted(weights[i], move || {
                        runs[i].fetch_add(1, Ordering::SeqCst);
                        stamps[i].store(clock.fetch_add(1, Ordering::SeqCst), Ordering::SeqCst);
                    })
                })
                .collect();
            for (i, succs) in adj.iter().enumerate() {
                for &s in succs {
                    g.precede(ids[i], &[ids[s]]);
                }
            }
            g.set_weight(ids[0], weights[0].max(2));
            let mut rep = 0;
            for no_critical_path in [false, true] {
                for no_priority_lanes in [false, true] {
                    for class in [RunPriority::High, RunPriority::Normal, RunPriority::Low] {
                        let options = RunOptions {
                            no_critical_path,
                            no_priority_lanes,
                            priority: class,
                            ..RunOptions::default()
                        };
                        if run_async {
                            g.run_async_with_options(&pool, options).unwrap().wait().unwrap();
                        } else {
                            g.run_with_options(&pool, options).unwrap();
                        }
                        rep += 1;
                        let mut total = 0;
                        for i in 0..n {
                            let r = runs[i].load(Ordering::SeqCst);
                            assert_eq!(
                                r, rep,
                                "case {case} async={run_async} cp-off={no_critical_path} \
                                 lanes-off={no_priority_lanes} class={class:?} node {i}"
                            );
                            total += r;
                        }
                        assert_eq!(total, n * rep, "case {case}: node-count conservation");
                        for (i, succs) in adj.iter().enumerate() {
                            let ti = stamps[i].load(Ordering::SeqCst);
                            for &s in succs {
                                assert!(
                                    ti < stamps[s].load(Ordering::SeqCst),
                                    "case {case} async={run_async} class={class:?} edge {i}->{s}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn single_worker_executes_ready_set_in_descending_rank_order() {
    // One worker, caller assist off (the calling thread only blocks),
    // so the schedule is fully deterministic: after the source, the
    // worker must drain the ready branches strictly by descending
    // critical-path rank — the highest as the inline continuation, the
    // rest via the rank-compensated deque order. Weights are chosen so
    // every rank is distinct.
    let pool = ThreadPool::new(1);
    let order: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let mut g = TaskGraph::new();
    let mk = |i: usize, order: &Arc<Mutex<Vec<usize>>>| {
        let order = order.clone();
        move || order.lock().unwrap().push(i)
    };
    let src = g.add(mk(0, &order));
    // Distinct weights, deliberately not in discovery order.
    let weights: [u32; 6] = [3, 17, 5, 13, 7, 19];
    let branches: Vec<_> = (0..6)
        .map(|b| {
            let id = g.add_weighted(weights[b], mk(1 + b, &order));
            g.succeed(id, &[src]);
            id
        })
        .collect();
    let sink = g.add(mk(7, &order));
    g.succeed(sink, &branches);
    g.seal().unwrap();

    // Expected: branches sorted by descending rank (= weight + 1),
    // ties impossible by construction.
    let mut expect: Vec<(u64, usize)> = branches
        .iter()
        .enumerate()
        .map(|(b, &id)| (g.rank(id).unwrap(), 1 + b))
        .collect();
    expect.sort_by_key(|&(rank, _)| std::cmp::Reverse(rank));
    let expect: Vec<usize> = expect.into_iter().map(|(_, i)| i).collect();

    // Dynamic re-ranking off (PR 8): this test pins the *declared*
    // weight order across re-runs, but the branch bodies are all
    // near-instant, so observed durations would legitimately erase the
    // declared skew and re-rank rep 2+ onto noise.
    let options = RunOptions::new().caller_assist(false).dynamic_rank(false);
    for rep in 0..3 {
        order.lock().unwrap().clear();
        g.run_with_options(&pool, options.clone()).unwrap();
        let seen = order.lock().unwrap().clone();
        assert_eq!(seen[0], 0, "source first (rep {rep})");
        assert_eq!(*seen.last().unwrap(), 7, "sink last (rep {rep})");
        assert_eq!(seen[1..=6], expect[..], "descending-rank branch order (rep {rep})");
    }
}

#[test]
fn mutex_protected_state_needs_no_atomics() {
    // FnMut closures may mutate captured state through a Mutex — the
    // graph edges give the happens-before; this checks the executor
    // doesn't require Sync state hacks from users.
    let pool = ThreadPool::new(2);
    let log: Arc<Mutex<String>> = Arc::new(Mutex::new(String::new()));
    let mut g = TaskGraph::new();
    let first = {
        let log = log.clone();
        g.add(move || log.lock().unwrap().push('a'))
    };
    let second = {
        let log = log.clone();
        g.add(move || log.lock().unwrap().push('b'))
    };
    let third = {
        let log = log.clone();
        g.add(move || log.lock().unwrap().push('c'))
    };
    g.succeed(second, &[first]);
    g.succeed(third, &[second]);
    g.run(&pool).unwrap();
    assert_eq!(&*log.lock().unwrap(), "abc");
}

#[test]
fn rerank_redirects_single_worker_onto_observed_critical_arm() {
    // PR 8 determinism check: equal *declared* weights give the
    // scheduler no reason to prefer any branch, but the branches'
    // actual durations are wildly skewed. After the warmup runs feed
    // the observed-duration EWMAs and a launch re-ranks, a single
    // worker (caller assist off — fully deterministic schedule) must
    // drain the ready set in descending *observed* duration order:
    // slowest branch first, exactly the makespan-optimal choice the
    // declared weights failed to encode.
    let pool = ThreadPool::new(1);
    let order: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let mut g = TaskGraph::new();
    let mk = |i: usize, sleep_ms: u64, order: &Arc<Mutex<Vec<usize>>>| {
        let order = order.clone();
        move || {
            order.lock().unwrap().push(i);
            if sleep_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
            }
        }
    };
    let src = g.add(mk(0, 0, &order));
    // Discovery order a, b, c — but c is the slow arm.
    let a = g.add(mk(1, 1, &order));
    let b = g.add(mk(2, 4, &order));
    let c = g.add(mk(3, 12, &order));
    let sink = g.add(mk(4, 0, &order));
    g.precede(src, &[a, b, c]);
    g.succeed(sink, &[a, b, c]);
    g.seal().unwrap();
    let base_rank_a = g.rank(a).unwrap();
    assert_eq!(base_rank_a, g.rank(c).unwrap(), "premise: declared ranks tie");

    let options = RunOptions::new().caller_assist(false);
    // Run 1 seeds the EWMAs; a later launch re-ranks once the drift
    // threshold trips. Three warmups leave plenty of margin.
    for _ in 0..3 {
        g.run_with_options(&pool, options.clone()).unwrap();
    }
    assert!(g.reranks() >= 1, "skewed observed durations must trigger a re-rank");
    assert!(
        g.rank(c).unwrap() > g.rank(b).unwrap() && g.rank(b).unwrap() > g.rank(a).unwrap(),
        "ranks must now follow observed durations: a={:?} b={:?} c={:?}",
        g.rank(a),
        g.rank(b),
        g.rank(c)
    );

    for rep in 0..2 {
        order.lock().unwrap().clear();
        g.run_with_options(&pool, options.clone()).unwrap();
        let seen = order.lock().unwrap().clone();
        assert_eq!(
            seen,
            vec![0, 3, 2, 1, 4],
            "rep {rep}: slowest observed arm must be scheduled first"
        );
    }
}
