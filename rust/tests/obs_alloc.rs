//! Zero-allocation guarantee for the observability record paths
//! (PR 9 acceptance criterion).
//!
//! The flight recorder and the histograms are **on by default**
//! ([`scheduling::pool::PoolConfig`]), so they live inside the PR 2
//! zero-alloc envelope: a sealed graph's steady-state re-runs — which
//! now record TaskStart/TaskEnd flight events, node-duration and
//! queue-delay histogram samples, and per-node span timestamps for
//! [`scheduling::graph::TaskGraph::last_profile`] — must still perform
//! zero heap allocations. The direct record paths
//! ([`scheduling::obs::Histogram::record`],
//! [`scheduling::obs::FlightRecorder::record`]) are additionally
//! measured in isolation, including ring wrap-around (overwrite must
//! not allocate either).
//!
//! Like `graph_alloc.rs`, this binary installs a counting global
//! allocator and therefore contains exactly ONE test: concurrent
//! neighbouring tests would pollute the process-wide counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use scheduling::obs::{EventKind, FlightRecorder, Histogram};
use scheduling::pool::ThreadPool;
use scheduling::workloads::Dag;

/// Counts every allocation (alloc / alloc_zeroed / realloc) made by
/// the process; frees are not interesting here.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
#[cfg_attr(miri, ignore = "allocation counting is not meaningful under Miri")]
fn observability_record_paths_do_not_allocate() {
    // --- direct histogram record path, in isolation ------------------
    let h = Histogram::new();
    h.record(1); // pre-touch
    let before = ALLOCS.load(Ordering::SeqCst);
    for v in 0..10_000u64 {
        h.record(v.wrapping_mul(2654435761));
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(allocs, 0, "Histogram::record must not allocate (saw {allocs})");
    assert_eq!(h.count(), 10_001);

    // --- direct flight record path, including ring wrap --------------
    // Capacity 64 with 10k records per lane forces >150 overwrite
    // cycles: the overwrite path is the same two stores as the fresh
    // path, so it must be just as silent.
    let f = FlightRecorder::new(2, 64, Instant::now());
    f.record(0, EventKind::Park, 0, 0); // pre-touch
    let before = ALLOCS.load(Ordering::SeqCst);
    for i in 0..10_000u64 {
        f.record((i % 3) as usize, EventKind::Steal, i as u32, i);
        f.record_external(EventKind::Wake, i as u32, i);
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(allocs, 0, "FlightRecorder::record must not allocate (saw {allocs})");
    let dump = f.dump();
    assert_eq!(dump.recorded, 20_001);
    assert!(dump.overwritten > 0, "premise: the ring must actually have wrapped");

    // --- the full default-config pool path ---------------------------
    // ThreadPool::new uses the default PoolConfig: flight recorder AND
    // histograms on. Sealed re-runs record flight events, histogram
    // samples, and profile spans on every node — and must still be
    // allocation-free in the steady state (all sinks are preallocated
    // atomics).
    let pool = ThreadPool::new(2);
    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    assert!(g.is_sealed());
    for _ in 0..5 {
        g.run(&pool).unwrap();
    }
    pool.wait_idle();
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        g.run(&pool).unwrap();
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        allocs, 0,
        "sealed re-runs with observability ON must not allocate (saw {allocs} in 10 runs)"
    );
    assert_eq!(counter.load(Ordering::Relaxed), 15 * 64, "node executions");

    // The observability sinks did observe those runs.
    assert!(
        pool.node_duration_histogram().is_some_and(|s| s.count >= 15 * 64),
        "node-duration histogram must hold one sample per executed node"
    );
    let dump = pool.flight_dump().expect("default config has the recorder on");
    assert!(
        dump.of_kind(EventKind::TaskStart).next().is_some()
            && dump.of_kind(EventKind::TaskEnd).next().is_some(),
        "flight dump must contain task start/end events"
    );
    assert!(g.last_profile().is_some(), "a timed run must yield a profile");

    // Sanity: the machinery is actually counting.
    let before = ALLOCS.load(Ordering::SeqCst);
    drop(std::hint::black_box(Box::new([0u8; 64])));
    assert!(ALLOCS.load(Ordering::SeqCst) > before, "allocator counter is wired up");
}
