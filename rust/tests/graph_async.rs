//! Concurrency-test tier for async run handles (PR 3).
//!
//! `TaskGraph::run_async` turns the executor's single implicit caller
//! into an explicit handle lifecycle; these tests pin down that
//! lifecycle end to end:
//!
//! * exactly-once execution through a handle, including ≥ 8 graphs in
//!   flight from one external thread (the PR's acceptance bar);
//! * handle drop-before-done blocks until quiescent;
//! * wait-after-done / try_wait / is_done agree;
//! * generation tagging: a stale handle from run *k* can never be
//!   satisfied by — nor confuse — run *k + 1* (deterministic, via a
//!   gate that holds run *k + 1* open);
//! * the `mem::forget` backstop: a forgotten handle forces the next
//!   graph use to quiesce instead of rewriting state under running
//!   tasks;
//! * the `Future` impl completes through the waker slot;
//! * blocking waits from inside a task of the same pool are rejected
//!   with `RunFromWorker`, never deadlocked.
//!
//! Sizes shrink under Miri (`cfg(miri)`), which runs this binary in CI
//! with `-Zmiri-disable-isolation -Zmiri-ignore-leaks`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use scheduling::graph::{wait_all, wait_any, GraphError, RunHandle, RunOptions, TaskGraph};
use scheduling::pool::ThreadPool;
use scheduling::workloads::{Dag, MultiRun};

/// A sealed `4 * diamonds`-node diamond-chain graph whose every node
/// bumps the returned counter once per run — the `graph_rerun` /
/// `graph_alloc` workload shape, reused so these tests cover exactly
/// the graph the benches measure.
fn counting_graph(diamonds: usize) -> (TaskGraph, Arc<AtomicUsize>) {
    Dag::diamond_chain(diamonds).to_task_graph(0)
}

/// A graph whose single node blocks until `gate` opens, then bumps
/// `counter` — for deterministic "run still in flight" windows.
fn gated_graph() -> (TaskGraph, Arc<AtomicBool>, Arc<AtomicUsize>) {
    let gate = Arc::new(AtomicBool::new(false));
    let counter = Arc::new(AtomicUsize::new(0));
    let mut g = TaskGraph::new();
    let (ga, c) = (gate.clone(), counter.clone());
    g.add(move || {
        while !ga.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        c.fetch_add(1, Ordering::SeqCst);
    });
    (g, gate, counter)
}

#[test]
fn async_run_exactly_once_and_rerunnable() {
    let pool = ThreadPool::new(2);
    let reps = if cfg!(miri) { 3 } else { 10 };
    let (mut g, counter) = counting_graph(8);
    for rep in 1..=reps {
        let h = g.run_async(&pool).unwrap();
        h.wait().unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), rep * 32, "rep {rep}");
    }
    // Sync and async runs interleave freely on the same graph.
    g.run(&pool).unwrap();
    let h = g.run_async(&pool).unwrap();
    h.wait().unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), (reps + 2) * 32);
}

#[test]
fn wait_after_done_and_try_wait_agree() {
    let pool = ThreadPool::new(2);
    let (mut g, counter) = counting_graph(4);
    let mut h = g.run_async(&pool).unwrap();
    // Spin until the run reports done, then every accessor must agree
    // (wait-after-done must not block or double-report).
    while !h.is_done() {
        std::thread::yield_now();
    }
    assert_eq!(counter.load(Ordering::Relaxed), 16);
    assert!(matches!(h.try_wait(), Some(Ok(()))));
    assert!(h.is_done());
    h.wait().unwrap();
}

#[test]
fn handle_drop_before_done_blocks_until_quiescent() {
    let pool = ThreadPool::new(2);
    let diamonds = if cfg!(miri) { 4 } else { 64 };
    let (mut g, counter) = counting_graph(diamonds);
    for rep in 1..=8 {
        let h = g.run_async(&pool).unwrap();
        drop(h);
        // Drop returned => the run is quiescent: every node of this
        // round executed, none will execute later.
        assert_eq!(counter.load(Ordering::Relaxed), rep * diamonds * 4, "rep {rep}");
    }
}

#[test]
fn eight_graphs_in_flight_from_one_thread() {
    // The acceptance bar: a single external thread sustains >= 8
    // graphs in flight via run_async with exactly-once execution.
    let pool = ThreadPool::new(3);
    let diamonds = if cfg!(miri) { 2 } else { 16 };
    let rounds = if cfg!(miri) { 2 } else { 50 };
    let n_graphs = 8;
    let mut graphs: Vec<(TaskGraph, Arc<AtomicUsize>)> =
        (0..n_graphs).map(|_| counting_graph(diamonds)).collect();
    for round in 1..=rounds {
        {
            let handles: Vec<RunHandle<'_>> = graphs
                .iter_mut()
                .map(|(g, _)| g.run_async(&pool).unwrap())
                .collect();
            // All 8 are in flight here. Wait in reverse launch order so
            // completion order differs from launch order.
            for h in handles.into_iter().rev() {
                h.wait().unwrap();
            }
        }
        for (i, (_, counter)) in graphs.iter().enumerate() {
            assert_eq!(
                counter.load(Ordering::Relaxed),
                round * diamonds * 4,
                "graph {i} after round {round}"
            );
        }
    }
}

#[test]
fn multi_run_driver_stress() {
    let pool = ThreadPool::new(2);
    let (graphs, diamonds, rounds) = if cfg!(miri) { (8, 2, 2) } else { (12, 16, 40) };
    let mut mr = MultiRun::new(graphs, diamonds, 0);
    mr.run_rounds(&pool, rounds).unwrap();
    assert_eq!(mr.rounds_done(), rounds);
    assert!(mr.verify_exactly_once(), "exactly-once violated across {rounds} rounds");
    assert_eq!(mr.total_executions(), graphs * diamonds * 4 * rounds);
}

#[test]
fn stale_handle_generation_cannot_observe_next_run() {
    // Run k completes and leaves `completed == k` in the reusable
    // state. A fresh handle for run k+1 (held open by the gate) must
    // not mistake that record for its own completion — and the
    // generation sequence must advance by exactly one per run.
    let pool = ThreadPool::new(2);
    let (mut g, gate, counter) = gated_graph();

    gate.store(true, Ordering::SeqCst); // run k: gate already open
    let h = g.run_async(&pool).unwrap();
    let gen_k = h.generation();
    h.wait().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 1);

    gate.store(false, Ordering::SeqCst);
    let mut h2 = g.run_async(&pool).unwrap();
    assert_eq!(h2.generation(), gen_k + 1);
    // Deterministic window: run k+1 cannot complete while the gate is
    // closed, so any `true` here could only come from run k's stale
    // completion record leaking through the generation check.
    for _ in 0..100 {
        assert!(!h2.is_done(), "handle for run k+1 observed run k's completion");
        assert!(h2.try_wait().is_none());
        std::thread::yield_now();
    }
    gate.store(true, Ordering::SeqCst);
    h2.wait().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 2);
}

#[test]
#[cfg_attr(miri, ignore = "deliberately leaks the forgotten handle's Arcs")]
fn forgotten_handle_forces_quiescence_on_next_use() {
    // mem::forget skips the handle's blocking Drop and releases the
    // graph borrow early; the next use of the graph (here: a new run)
    // must wait for the orphaned run instead of re-arming state under
    // its tasks.
    let pool = ThreadPool::new(2);
    let (mut g, gate, counter) = gated_graph();
    let h = g.run_async(&pool).unwrap();
    std::mem::forget(h);
    assert_eq!(counter.load(Ordering::SeqCst), 0, "gated run must still be in flight");
    // Move the graph while the orphan run is in flight: a move runs no
    // code, so this is only sound because every pointer the run holds
    // targets heap-pinned structures (Vec-backed nodes, boxed
    // topology) whose addresses survive the move.
    let mut g = Box::new(g);

    // Open the gate from a side thread after a beat, then start a new
    // run: its launch must quiesce first, so by the time it returns a
    // handle, run 1's node has executed.
    let ga = gate.clone();
    let opener = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        ga.store(true, Ordering::SeqCst);
    });
    let h2 = g.run_async(&pool).unwrap();
    assert!(counter.load(Ordering::SeqCst) >= 1, "launch returned before the orphan run drained");
    h2.wait().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 2);
    opener.join().unwrap();

    // Mutation after another forget quiesces too (invalidate_caches).
    gate.store(false, Ordering::SeqCst);
    let h = g.run_async(&pool).unwrap();
    std::mem::forget(h);
    let ga = gate.clone();
    let opener = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        ga.store(true, Ordering::SeqCst);
    });
    let c = counter.clone();
    g.add(move || {
        c.fetch_add(100, Ordering::SeqCst);
    });
    assert!(counter.load(Ordering::SeqCst) >= 3, "mutation returned before the orphan run drained");
    opener.join().unwrap();
    gate.store(true, Ordering::SeqCst);
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 104);
}

/// Minimal std-only executor for the `Future` impl: poll on the
/// current thread, park between polls, unpark from the waker.
fn block_on<F: std::future::Future + Unpin>(mut fut: F) -> F::Output {
    struct Unparker(std::thread::Thread);
    impl std::task::Wake for Unparker {
        fn wake(self: Arc<Self>) {
            self.0.unpark();
        }
    }
    let waker = std::task::Waker::from(Arc::new(Unparker(std::thread::current())));
    let mut cx = std::task::Context::from_waker(&waker);
    let mut fut = std::pin::Pin::new(&mut fut);
    loop {
        match fut.as_mut().poll(&mut cx) {
            std::task::Poll::Ready(v) => return v,
            // park_timeout rather than park: a lost wakeup then shows
            // up as a slow test instead of a hung CI job.
            std::task::Poll::Pending => std::thread::park_timeout(Duration::from_millis(100)),
        }
    }
}

#[test]
fn handle_is_a_future_completed_by_the_waker() {
    let pool = ThreadPool::new(2);
    let (diamonds, reps) = if cfg!(miri) { (4, 2) } else { (16, 5) };
    let (mut g, counter) = counting_graph(diamonds);
    for rep in 1..=reps {
        let h = g.run_async(&pool).unwrap();
        block_on(h).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), rep * diamonds * 4, "rep {rep}");
    }

    // A panicking node surfaces through the future too.
    let mut bad = TaskGraph::new();
    bad.add_named("boom", || panic!("async kaboom"));
    let h = bad.run_async(&pool).unwrap();
    match block_on(h) {
        Err(GraphError::NodePanicked { name, payload, .. }) => {
            assert_eq!(name.as_deref(), Some("boom"));
            assert!(payload.contains("async kaboom"));
        }
        other => panic!("expected NodePanicked, got {other:?}"),
    }
}

#[test]
fn async_panic_reported_once_and_not_leaked_to_next_run() {
    let pool = ThreadPool::new(2);
    let fail = Arc::new(AtomicBool::new(true));
    let mut g = TaskGraph::new();
    let f = fail.clone();
    g.add_named("flaky", move || {
        if f.load(Ordering::SeqCst) {
            panic!("first run only");
        }
    });
    let h = g.run_async(&pool).unwrap();
    assert!(matches!(h.wait(), Err(GraphError::NodePanicked { node: 0, .. })));
    // Second run succeeds and must not report the stale panic.
    fail.store(false, Ordering::SeqCst);
    g.run_async(&pool).unwrap().wait().unwrap();

    // A panic whose handle is dropped (not waited) is discarded by the
    // next launch, not misattributed to it.
    fail.store(true, Ordering::SeqCst);
    drop(g.run_async(&pool).unwrap());
    fail.store(false, Ordering::SeqCst);
    g.run_async(&pool).unwrap().wait().unwrap();
}

#[test]
fn launch_and_blocking_wait_rejected_from_worker_tasks() {
    // Launching on the task's own pool is rejected...
    let pool = Arc::new(ThreadPool::new(1));
    let p = pool.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    pool.submit(move || {
        let mut g = TaskGraph::new();
        g.add(|| {});
        tx.send(matches!(g.run_async(&p), Err(GraphError::RunFromWorker))).unwrap();
    });
    assert!(rx.recv_timeout(Duration::from_secs(10)).unwrap());
    pool.wait_idle();

    // ...and so is a blocking wait on a handle that was moved into a
    // task of the same pool: wait() errors deterministically, and the
    // handle's Drop drains the run instead of parking the worker.
    let g: &'static mut TaskGraph = Box::leak(Box::new(TaskGraph::new()));
    let hit = Arc::new(AtomicUsize::new(0));
    let h2 = hit.clone();
    g.add(move || {
        h2.fetch_add(1, Ordering::SeqCst);
    });
    let handle = g.run_async(&pool).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    pool.submit(move || {
        tx.send(matches!(handle.wait(), Err(GraphError::RunFromWorker))).unwrap();
    });
    assert!(
        rx.recv_timeout(Duration::from_secs(10)).unwrap(),
        "RunHandle::wait from a worker task must return RunFromWorker"
    );
    pool.wait_idle();
    assert_eq!(hit.load(Ordering::SeqCst), 1);
}

#[test]
fn async_honors_topology_and_inline_toggles() {
    let pool = ThreadPool::new(2);
    for mask in 0..4u32 {
        let options = RunOptions {
            no_inline_continuation: mask & 1 != 0,
            no_topology_cache: mask & 2 != 0,
            ..RunOptions::default()
        };
        let (mut g, counter) = counting_graph(8);
        for rep in 1..=3 {
            g.run_async_with_options(&pool, options.clone()).unwrap().wait().unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), rep * 32, "mask {mask} rep {rep}");
        }
    }
}

#[test]
fn concurrent_external_threads_each_with_handle_fleets() {
    // Several external threads, each keeping its own fleet of graphs
    // in flight on one shared pool — the helper/waiter machinery must
    // keep runs isolated.
    let pool = Arc::new(ThreadPool::new(3));
    let (threads, graphs, rounds) = if cfg!(miri) { (2, 2, 2) } else { (4, 4, 12) };
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let mut fleet: Vec<(TaskGraph, Arc<AtomicUsize>)> =
                    (0..graphs).map(|_| counting_graph(4)).collect();
                for round in 1..=rounds {
                    let hs: Vec<_> =
                        fleet.iter_mut().map(|(g, _)| g.run_async(&pool).unwrap()).collect();
                    for h in hs {
                        h.wait().unwrap();
                    }
                    for (i, (_, c)) in fleet.iter().enumerate() {
                        assert_eq!(
                            c.load(Ordering::Relaxed),
                            round * 16,
                            "thread {t} graph {i} round {round}"
                        );
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn wait_all_drains_a_fleet_without_polling() {
    // The PR 4 fleet combinator: 8 handles in flight from one thread,
    // drained by a single wait_all parked on the run eventcount.
    let pool = ThreadPool::new(2);
    let rounds = if cfg!(miri) { 2 } else { 6 };
    let mut fleet: Vec<(TaskGraph, Arc<AtomicUsize>)> = (0..8).map(|_| counting_graph(4)).collect();
    for round in 1..=rounds {
        let mut handles: Vec<_> =
            fleet.iter_mut().map(|(g, _)| g.run_async(&pool).unwrap()).collect();
        wait_all(&mut handles).unwrap();
        // Every handle is harvested: drop is now free and the counters
        // show exactly-once for the whole fleet.
        drop(handles);
        for (i, (_, c)) in fleet.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), round * 16, "graph {i} round {round}");
        }
    }
    // The empty fleet is trivially complete.
    let mut none: Vec<RunHandle<'_>> = Vec::new();
    wait_all(&mut none).unwrap();
}

#[test]
fn wait_all_reports_the_first_panicking_run() {
    let pool = ThreadPool::new(2);
    let (mut ok, counter) = counting_graph(2);
    let mut bad = TaskGraph::new();
    bad.add_named("boom", || panic!("fleet failure"));
    let mut handles = vec![ok.run_async(&pool).unwrap(), bad.run_async(&pool).unwrap()];
    match wait_all(&mut handles) {
        Err(GraphError::NodePanicked { name, payload, .. }) => {
            assert_eq!(name.as_deref(), Some("boom"));
            assert!(payload.contains("fleet failure"));
        }
        other => panic!("expected NodePanicked, got {other:?}"),
    }
    drop(handles);
    assert_eq!(counter.load(Ordering::Relaxed), 8, "the healthy run still completed");
}

#[test]
fn wait_any_returns_a_completed_index_first() {
    // One gated (held-open) run plus one free run: wait_any must come
    // back with the free run's index while the gated run is still in
    // flight, without executing pool tasks on this thread.
    let pool = ThreadPool::new(2);
    let (mut gated, gate, gated_counter) = gated_graph();
    let (mut free, free_counter) = counting_graph(2);
    {
        let mut handles = vec![gated.run_async(&pool).unwrap(), free.run_async(&pool).unwrap()];
        let winner = wait_any(&mut handles);
        assert_eq!(winner, 1, "the ungated run finishes first");
        assert!(handles[winner].is_done());
        assert_eq!(free_counter.load(Ordering::Relaxed), 8);
        assert_eq!(gated_counter.load(Ordering::SeqCst), 0, "gated run still in flight");
        // Harvest the winner, then release the gate and drain the rest.
        assert!(matches!(handles.remove(winner).wait(), Ok(())));
        gate.store(true, Ordering::SeqCst);
        wait_all(&mut handles).unwrap();
    }
    assert_eq!(gated_counter.load(Ordering::SeqCst), 1);
    // With everything already done, wait_any returns the lowest index.
    let mut handles = vec![gated.run_async(&pool).unwrap(), free.run_async(&pool).unwrap()];
    for h in handles.iter() {
        while !h.is_done() {
            std::thread::yield_now();
        }
    }
    assert_eq!(wait_any(&mut handles), 0);
    wait_all(&mut handles).unwrap();
}

#[test]
#[should_panic(expected = "empty handle fleet")]
fn wait_any_on_an_empty_fleet_panics() {
    let mut none: Vec<RunHandle<'_>> = Vec::new();
    let _ = wait_any(&mut none);
}
