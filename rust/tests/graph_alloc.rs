//! Zero-allocation re-run guarantee (PR 2 acceptance criterion).
//!
//! A sealed graph's second and subsequent `run()` calls must perform
//! **zero heap allocations**: the CSR topology, the source list, and
//! the `RunState` are all built on (or before) the first run and
//! reused; node tasks are `RawTask`s that store inline; and queue
//! capacity (injector `VecDeque`, worker deques) is retained from the
//! warmup runs.
//!
//! The test binary installs a counting global allocator, so this file
//! contains exactly ONE test: the libtest harness would otherwise run
//! neighbouring tests on other threads concurrently and pollute the
//! process-wide counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use scheduling::graph::{Dataflow, RunOptions, RunPriority};
use scheduling::pool::ThreadPool;
use scheduling::runtime::HostTensor;
use scheduling::workloads::Dag;

/// Counts every allocation (alloc / alloc_zeroed / realloc) made by
/// the process; frees are not interesting here.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
#[cfg_attr(miri, ignore = "allocation counting is not meaningful under Miri")]
fn sealed_rerun_makes_zero_heap_allocations() {
    let pool = ThreadPool::new(2);
    // 64-node diamond chain — the `graph_rerun` microbench workload.
    // `to_task_graph` seals the graph eagerly.
    let (mut g, counter) = Dag::diamond_chain(16).to_task_graph(0);
    assert!(g.is_sealed());

    // All three wait modes must be allocation-free on the steady
    // state; measure each after its own warmup (first runs may size
    // queue capacity, lazily init locks, etc.). The `async-handle`
    // variant covers the PR 3 path: launch through `run_async`, park
    // on the run eventcount, harvest through the handle — a handle is
    // a few words on the stack plus refcount bumps, so sealed re-runs
    // through it stay zero-allocation like the blocking modes.
    let variants = [
        ("caller-assist", Some(RunOptions::new())),
        ("condvar-wait", Some(RunOptions::new().caller_assist(false))),
        ("async-handle", None),
    ];
    let mut expected = 0usize;
    for (label, options) in variants {
        let run_once = |g: &mut scheduling::graph::TaskGraph| match &options {
            Some(options) => g.run_with_options(&pool, options.clone()).unwrap(),
            None => g.run_async(&pool).unwrap().wait().unwrap(),
        };
        for _ in 0..5 {
            run_once(&mut g);
            expected += 64;
        }
        // Quiesce so stray worker activity from the warmup cannot leak
        // into the measured window.
        pool.wait_idle();

        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..10 {
            run_once(&mut g);
            expected += 64;
        }
        let allocs = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            allocs, 0,
            "{label}: sealed re-runs must not allocate (saw {allocs} allocations in 10 runs)"
        );
        assert_eq!(counter.load(Ordering::Relaxed), expected, "{label}: node executions");
    }

    // PR 4: priority scheduling must not reintroduce allocations. A
    // *weighted* skewed graph exercises the whole rank machinery —
    // seal-time ranks/buckets, the burst sort, the lane composition —
    // under the default options (critical path + lanes on) and under a
    // High-class run; both must stay allocation-free on sealed re-runs
    // (ranks and ordered source lists are seal-time arrays, the burst
    // sort is in-place on the stack buffer).
    let (wwidth, wspine) = (24usize, 8usize);
    let wdag = Dag::skewed_diamond(wwidth, wspine)
        .with_weights(|i| if (wwidth + 1..=wwidth + wspine).contains(&i) { 8 } else { 1 });
    let wnodes = wdag.len();
    let (mut wg, wcounter) = wdag.to_task_graph(0);
    assert!(wg.is_sealed());
    let wvariants = [
        ("weighted-critical-path", RunOptions::new()),
        ("weighted-high-class", RunOptions::new().priority(RunPriority::High)),
    ];
    let mut wexpected = 0usize;
    for (label, options) in wvariants {
        for _ in 0..5 {
            wg.run_with_options(&pool, options.clone()).unwrap();
            wexpected += wnodes;
        }
        pool.wait_idle();
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..10 {
            wg.run_with_options(&pool, options.clone()).unwrap();
            wexpected += wnodes;
        }
        let allocs = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            allocs, 0,
            "{label}: weighted sealed re-runs must not allocate (saw {allocs} in 10 runs)"
        );
        assert_eq!(wcounter.load(Ordering::Relaxed), wexpected, "{label}: node executions");
    }

    // PR 6: an *aborted* run must not poison the zero-alloc guarantee
    // — and cancellation itself is allocation-free (the abort cause is
    // one atomic, skipped nodes ride the normal cascade, and the typed
    // error is a unit variant). Warm up, then measure a pre-cancelled
    // run followed by recovery re-runs in the same window.
    let token = scheduling::graph::CancelToken::new();
    token.cancel();
    let cancelled = RunOptions::new().cancel_token(token);
    for _ in 0..5 {
        g.run_with_options(&pool, RunOptions::new()).unwrap();
        expected += 64;
    }
    pool.wait_idle();
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        // The aborted run skips every node (counter unchanged).
        assert!(matches!(
            g.run_with_options(&pool, cancelled.clone()),
            Err(scheduling::graph::GraphError::Cancelled)
        ));
        // The same sealed graph's next run() succeeds — un-poisoned.
        g.run_with_options(&pool, RunOptions::new()).unwrap();
        expected += 64;
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        allocs, 0,
        "abort-recover: cancelled + recovery sealed re-runs must not allocate (saw {allocs})"
    );
    assert_eq!(counter.load(Ordering::Relaxed), expected, "abort-recover: node executions");

    // PR 8: duration-feedback re-ranking must stay inside the
    // zero-alloc envelope. Equal *declared* weights but heavily skewed
    // *actual* work force an observed-weight drift ≥ the 2x re-rank
    // threshold, so the warmup runs provably exercise the re-rank path
    // (asserted via `reranks()`); the measured window then re-runs —
    // including any further EWMA recording and drift checks — without
    // a single allocation (ranks, buckets, source order, and the
    // bucket-sort scratch are all seal-time arrays recomputed in
    // place).
    use scheduling::workloads::dag::busy_work;
    let mut rg = scheduling::graph::TaskGraph::new();
    let src = rg.add_weighted(1, || {
        std::hint::black_box(busy_work(1, 64));
    });
    let heavy = rg.add_weighted(1, || {
        std::hint::black_box(busy_work(2, 8192));
    });
    let light = rg.add_weighted(1, || {
        std::hint::black_box(busy_work(3, 64));
    });
    let sink = rg.add_weighted(1, || {
        std::hint::black_box(busy_work(4, 64));
    });
    rg.precede(src, &[heavy, light]);
    rg.precede(heavy, &[sink]);
    rg.precede(light, &[sink]);
    rg.seal().unwrap();
    for _ in 0..5 {
        rg.run_with_options(&pool, RunOptions::new()).unwrap();
    }
    assert!(
        rg.reranks() >= 1,
        "premise: skewed observed durations must have triggered a re-rank in warmup"
    );
    assert!(
        rg.observed_duration(heavy).unwrap() > rg.observed_duration(light).unwrap(),
        "premise: the heavy arm must dominate the observed EWMAs"
    );
    pool.wait_idle();
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        rg.run_with_options(&pool, RunOptions::new()).unwrap();
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        allocs, 0,
        "dynamic-rerank: sealed re-runs with duration feedback must not allocate (saw {allocs})"
    );

    // PR 10: *tensor-valued* dataflow re-runs. The inplace node forms
    // borrow upstream values (no clone) and refill retained buffers
    // (`init` allocates once, on the first run), so a sealed dataflow
    // of real compute — a cache-blocked matmul feeding a stencil —
    // re-runs without a single heap allocation, payloads included.
    let mut df = Dataflow::new();
    let mut tick = 0.0f32;
    let a = df.node_inplace(
        "a",
        || HostTensor::random(&[48, 32], 11),
        move |t: &mut HostTensor| {
            // Refill in place each run (values change, buffer doesn't).
            tick += 1.0;
            for (i, v) in t.data.iter_mut().enumerate() {
                *v = ((i % 13) as f32 - 6.0) * 0.01 * tick;
            }
        },
    );
    let b = df.node_inplace("b", || HostTensor::random(&[32, 40], 12), |_| {});
    let prod = df.node2_inplace(
        "matmul",
        &a,
        &b,
        || HostTensor::zeros(&[48, 40]),
        |a: &HostTensor, b: &HostTensor, out: &mut HostTensor| a.matmul_blocked_into(b, out),
    );
    let smooth = df.node1_inplace(
        "stencil",
        &prod,
        || HostTensor::zeros(&[48, 40]),
        |p: &HostTensor, out: &mut HostTensor| p.stencil_step_into(out),
    );
    df.graph_mut().seal().unwrap();
    for _ in 0..5 {
        df.run(&pool).unwrap();
    }
    pool.wait_idle();
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        df.run(&pool).unwrap();
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        allocs, 0,
        "tensor-dataflow: sealed inplace re-runs must not allocate (saw {allocs} in 10 runs)"
    );
    // Outside the window: the values are real (15 runs → tick == 15).
    let p = prod.get().unwrap();
    let s = smooth.get().unwrap();
    assert_eq!(s.shape, vec![48, 40]);
    assert_eq!(s.data, p.stencil_step().data, "stencil output matches its input's oracle");
    let a_now = a.get().unwrap();
    assert!(
        (a_now.data[1] - (1.0 - 6.0) * 0.01 * 15.0).abs() < 1e-5,
        "source must have refilled on every run (got {})",
        a_now.data[1]
    );

    // Sanity: the machinery is actually counting.
    let before = ALLOCS.load(Ordering::SeqCst);
    drop(std::hint::black_box(Box::new([0u8; 64])));
    assert!(ALLOCS.load(Ordering::SeqCst) > before, "allocator counter is wired up");
}
