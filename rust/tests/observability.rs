//! Observability tier (PR 9) — end-to-end behaviour of the flight
//! recorder, the pool histograms, and run profiles on real runs:
//!
//! * `RunHandle::profile()` / `TaskGraph::last_profile()` report
//!   internally-consistent numbers (busy ≤ workers × makespan, the
//!   observed critical path fits inside the makespan, per-worker busy
//!   sums to total busy);
//! * the flight recorder captures task start/end pairs for every
//!   executed node plus park/wake scheduler events, and converts to
//!   Chrome-trace JSON (with flow arrows when edges are supplied);
//! * failed runs (`NodePanicked`, `DeadlineExceeded`) stash an
//!   automatic dump on the pool and, with `FLIGHT_DUMP_DIR` set, write
//!   a Chrome-trace file — the CI chaos job's failure artifact;
//! * `PoolConfig { flight_recorder: false, histograms: false }`
//!   disables every accessor without disturbing runs — the ABL-9
//!   comparison configuration;
//! * the histograms feeding the tail-aware SLO checks accumulate one
//!   node-duration sample per executed node;
//! * a `TaskGraph::add_parallel_for` burst (PR 10) renders one
//!   profiled span per block, with block index + sub-range in the
//!   node names.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use scheduling::graph::{GraphError, RunOptions, TaskGraph};
use scheduling::obs::{EventKind, HIST_MIN_SAMPLES};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::workloads::Dag;

/// Two-node chain whose head spins until `gate` opens (same idiom as
/// `graph_cancel.rs`) — a deterministic "run in flight" window.
fn gated_chain() -> (TaskGraph, Arc<AtomicBool>) {
    let gate = Arc::new(AtomicBool::new(false));
    let mut g = TaskGraph::new();
    let ga = gate.clone();
    let head = g.add(move || {
        while !ga.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
    });
    let tail = g.add(|| {});
    g.precede(head, &[tail]);
    (g, gate)
}

#[test]
fn run_profile_numbers_are_internally_consistent() {
    let pool = ThreadPool::new(2);
    // Non-trivial per-node work so spans are comfortably measurable.
    let (mut g, counter) = Dag::diamond_chain(8).to_task_graph(2048);
    let nodes = 32; // diamond_chain(k) builds 4k nodes

    assert!(g.last_profile().is_none(), "no profile before the first run");
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), nodes);

    let p = g.last_profile().expect("a timed run must yield a profile");
    assert_eq!(p.nodes, nodes, "every node executed and was timed");
    assert_eq!(p.workers, 2);
    assert!(p.makespan > Duration::ZERO);
    assert!(p.busy > Duration::ZERO);
    // busy + idle account exactly for workers × makespan.
    assert!(p.busy <= p.makespan * (p.workers as u32 + 1), "busy bounded by worker-time");
    // Efficiency is busy ÷ (workers × makespan); the caller-assist
    // helper lane can push it slightly past 1.0, never past
    // (workers + 1) / workers.
    assert!(p.scheduling_efficiency > 0.0);
    assert!(p.scheduling_efficiency <= (p.workers as f64 + 1.0) / p.workers as f64);
    // The observed critical path is a chain of sequentially-executed
    // spans, so it fits inside the run window.
    assert!(p.critical_path > Duration::ZERO);
    assert!(p.critical_path <= p.makespan, "critical path exceeds makespan");
    assert!(!p.critical_path_nodes.is_empty() && p.critical_path_nodes.len() <= nodes);
    assert!(p.declared_critical_rank > 0, "sealed ranks back the declared estimate");
    // Per-lane busy (workers + the caller-assist helper lane) sums to
    // the total.
    assert_eq!(p.per_worker_busy.len(), p.workers + 1);
    let lane_sum: Duration = p.per_worker_busy.iter().sum();
    assert_eq!(lane_sum, p.busy, "per-worker busy must sum to total busy");

    // The async surface: profile through the handle once finished.
    let mut h = g.run_async(&pool).unwrap();
    loop {
        if let Some(r) = h.try_wait() {
            r.unwrap();
            break;
        }
        std::thread::yield_now();
    }
    let hp = h.profile().expect("finished handle must expose the run's profile");
    assert_eq!(hp.nodes, nodes);
    drop(h);
    // The profile also lands on the graph once the handle is gone.
    assert_eq!(g.last_profile().unwrap().nodes, nodes);
}

#[test]
fn flight_recorder_captures_runs_and_renders_chrome_trace() {
    let pool = ThreadPool::new(2);
    let n = 16;
    let (mut g, _) = Dag::linear_chain(n).to_task_graph(512);
    for _ in 0..3 {
        g.run(&pool).unwrap();
    }
    pool.wait_idle();
    // Give the workers a moment to run out of spin rounds and park, so
    // the dump demonstrably holds scheduler events, not just tasks.
    std::thread::sleep(Duration::from_millis(100));

    let dump = pool.flight_dump().expect("flight recorder is on by default");
    assert!(dump.recorded > 0);
    let starts = dump.of_kind(EventKind::TaskStart).count();
    let ends = dump.of_kind(EventKind::TaskEnd).count();
    assert!(starts >= 3 * n, "one TaskStart per executed node (saw {starts})");
    assert!(ends >= 3 * n, "one TaskEnd per executed node (saw {ends})");
    assert!(
        dump.of_kind(EventKind::Park).next().is_some(),
        "idle workers must have recorded Park events"
    );
    assert!(dump.events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "dump is time-sorted");

    let trace = dump.to_chrome_trace();
    assert!(trace.starts_with("{\"traceEvents\":["), "{trace}");
    assert!(trace.contains("\"cat\":\"task\""), "task spans must render as ph:X events");
    assert!(trace.contains("\"overwritten\""), "loss accounting must be in otherData");

    // Flow arrows appear only when edges are supplied and both
    // endpoints completed in the same generation.
    let with_edges = dump.to_chrome_trace_with_edges(&[(0, 1), (1, 2)]);
    assert!(with_edges.contains("\"ph\":\"s\""), "edge flow-start events");
    assert!(with_edges.contains("\"ph\":\"f\""), "edge flow-finish events");
    assert!(!trace.contains("\"ph\":\"s\""), "no arrows without edges");
}

#[test]
fn failed_runs_stash_an_automatic_dump() {
    let dump_dir = std::env::temp_dir().join(format!("flight-dumps-{}", std::process::id()));
    std::fs::create_dir_all(&dump_dir).unwrap();
    std::env::set_var("FLIGHT_DUMP_DIR", &dump_dir);

    let pool = ThreadPool::new(2);
    assert!(pool.last_flight_dump().is_none(), "no auto dump before any failure");

    // Panic path.
    let mut g = TaskGraph::new();
    let a = g.add(|| {});
    let b = g.add(|| panic!("observability test panic"));
    g.precede(a, &[b]);
    g.seal().unwrap();
    assert!(matches!(g.run(&pool), Err(GraphError::NodePanicked { .. })));
    let dump = pool.last_flight_dump().expect("panic must stash a flight dump");
    assert!(dump.of_kind(EventKind::Abort).next().is_some(), "the abort is on the record");
    assert!(pool.last_flight_dump().is_none(), "the stash is take-once");

    // Deadline path.
    let (mut gated, gate) = gated_chain();
    let h = gated
        .run_async_with_options(&pool, RunOptions::new().deadline(Duration::from_millis(15)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(50));
    gate.store(true, Ordering::SeqCst);
    assert!(matches!(h.wait(), Err(GraphError::DeadlineExceeded)));
    assert!(
        pool.last_flight_dump().is_some(),
        "an exceeded deadline must stash a flight dump"
    );

    // Both failures also wrote Chrome-trace files for the CI artifact.
    std::env::remove_var("FLIGHT_DUMP_DIR");
    let files: Vec<_> = std::fs::read_dir(&dump_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().starts_with("flight-"))
        .collect();
    assert!(!files.is_empty(), "FLIGHT_DUMP_DIR must receive trace files");
    let body = std::fs::read_to_string(files[0].path()).unwrap();
    assert!(body.starts_with("{\"traceEvents\":["), "dump files are Chrome traces");
    let _ = std::fs::remove_dir_all(&dump_dir);
}

#[test]
fn disabling_observability_disables_accessors_not_runs() {
    let pool = ThreadPool::with_config(PoolConfig {
        num_threads: 2,
        flight_recorder: false,
        histograms: false,
        ..PoolConfig::default()
    });
    assert!(pool.flight_dump().is_none());
    assert!(pool.flight_recorder().is_none());
    assert!(pool.queue_delay_histogram().is_none());
    assert!(pool.node_duration_histogram().is_none());

    let (mut g, counter) = Dag::diamond_chain(4).to_task_graph(64);
    for _ in 0..3 {
        g.run(&pool).unwrap();
    }
    assert_eq!(counter.load(Ordering::Relaxed), 3 * 16);
    assert!(pool.last_flight_dump().is_none(), "no recorder, no auto dumps");
    // Profiles ride the dynamic-rank span sampling, which stays on.
    assert!(g.last_profile().is_some(), "profiles survive obs-off pools");
}

/// PR 10: a `parallel_for` burst is legible in the observability
/// surfaces — the run profile counts every block node individually,
/// the graph names carry each block's index and sub-range, and the
/// Chrome trace renders one task span per block.
#[test]
fn parallel_for_burst_renders_in_profile_and_trace() {
    let pool = ThreadPool::new(2);
    let blocks = 8usize;
    let mut g = TaskGraph::new();
    let (_start, _join) = g.add_parallel_for("burst", 0..4096, blocks, |r| {
        std::hint::black_box(r.map(|i| i as u64).sum::<u64>());
    });
    g.seal().unwrap();
    g.run(&pool).unwrap();

    let p = g.last_profile().expect("a timed run must yield a profile");
    assert_eq!(p.nodes, blocks + 2, "start + join + one profiled span per block");

    // Each block is a named node carrying its index and sub-range
    // (4096 / 8 = 512-wide blocks), so profiles and dot renderings can
    // attribute time to individual sub-ranges.
    let dot = g.to_dot();
    for i in 0..blocks {
        let label = format!("burst/b{i}[{}..{})", i * 512, (i + 1) * 512);
        assert!(dot.contains(&label), "missing {label} in {dot}");
    }

    pool.wait_idle();
    let dump = pool.flight_dump().expect("flight recorder is on by default");
    let starts = dump.of_kind(EventKind::TaskStart).count();
    assert!(starts >= blocks + 2, "one TaskStart per executed node (saw {starts})");
    let trace = dump.to_chrome_trace();
    assert!(trace.starts_with("{\"traceEvents\":["), "{trace}");
    let spans = trace.matches("\"ph\":\"X\"").count();
    assert!(spans >= blocks + 2, "one task span per node (saw {spans})");
    // `add_parallel_for` adds start and join first, so the block nodes
    // are ids 2..blocks+2 — every one of them must have completed a
    // span in the trace.
    for node in 2..blocks + 2 {
        assert!(
            trace.contains(&format!("\"args\":{{\"node\":{node},\"gen\"")),
            "block node {node} missing from the trace"
        );
    }
}

#[test]
fn node_duration_histogram_counts_every_executed_node() {
    let pool = ThreadPool::new(2);
    let (mut g, _) = Dag::linear_chain(24).to_task_graph(256);
    // Enough runs to cross the warm-up threshold the SLO checks use.
    let runs = (HIST_MIN_SAMPLES as usize).div_ceil(24) + 1;
    for _ in 0..runs {
        g.run(&pool).unwrap();
    }
    let snap = pool.node_duration_histogram().expect("histograms on by default");
    assert_eq!(snap.count, (runs * 24) as u64, "one sample per executed node");
    assert!(snap.quantile(0.99) >= snap.quantile(0.5), "quantiles are monotone");
    assert!(snap.mean() > 0, "busy-work nodes take measurable time");
}
