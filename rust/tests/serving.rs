//! Serving-tier integration tests (PR 7): the `serve/` subsystem's
//! contract under adversarial traffic.
//!
//! * tenant fairness — a storming tenant cannot starve a quiet tenant,
//!   and DRR weights divide throughput roughly proportionally;
//! * retry budget — under *permanent* overload, total retry attempts
//!   are capped by the initial allowance (no amplification);
//! * brownout — hysteretic escalation/recovery, and end-to-end Low
//!   shedding through the service gate;
//! * deadline feasibility — infeasible requests are rejected with
//!   `WouldMissDeadline` before consuming any slot, at both the
//!   service gate and the pool-EWMA admission seam;
//! * exactly-once — a request that is retried after pool-budget
//!   rejections executes its graph exactly once on success.
//!
//! The `chaos_storms` module at the bottom only builds with
//! `--features chaos`: it storms the service with injected `Overloaded`
//! and latency spikes, then stops injection and asserts goodput
//! converges back to clean.

use scheduling::graph::{GraphError, RunPriority, TaskGraph};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::serve::{
    BrownoutConfig, BrownoutController, BrownoutLevel, GraphService, RetryPolicy, ServeError,
    ServiceConfig, TenantSpec,
};
use scheduling::workloads::Dag;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn small_pool(workers: usize) -> ThreadPool {
    ThreadPool::with_config(PoolConfig { num_threads: workers, ..PoolConfig::default() })
}

/// A storming tenant saturating the gate must not starve a quiet
/// heavier-weight tenant: every one of the quiet tenant's requests
/// completes while the storm is still running.
#[test]
fn storm_cannot_starve_quiet_tenant() {
    let svc = Arc::new(GraphService::new(
        small_pool(2),
        ServiceConfig {
            max_inflight: 2,
            retry: RetryPolicy::disabled(),
            ..ServiceConfig::default()
        },
    ));
    let gold = svc.register_tenant(TenantSpec::new("gold").weight(4).max_inflight(1));
    let storm = svc.register_tenant(TenantSpec::new("storm").weight(1).max_inflight(2));

    let stop = Arc::new(AtomicBool::new(false));
    let stormers: Vec<_> = (0..4)
        .map(|_| {
            let svc = svc.clone();
            let stop = stop.clone();
            thread::spawn(move || {
                let (mut g, _) = Dag::diamond_chain(2).to_task_graph(256);
                while !stop.load(Ordering::Relaxed) {
                    let _ = svc.run(storm, &mut g);
                }
            })
        })
        .collect();

    // Quiet tenant: 50 sequential requests while the storm rages.
    let (mut g, counter) = Dag::diamond_chain(2).to_task_graph(256);
    for _ in 0..50 {
        svc.run(gold, &mut g).expect("quiet tenant must be served during the storm");
    }
    stop.store(true, Ordering::Relaxed);
    for s in stormers {
        s.join().unwrap();
    }
    assert_eq!(counter.load(Ordering::Relaxed), 50 * 8, "all quiet-tenant work ran");
    let snaps = svc.tenant_snapshots();
    assert_eq!(snaps[gold.index()].completed, 50);
    assert!(
        snaps[storm.index()].completed > 0,
        "the storm must actually have contended for the gate"
    );
}

/// With both tenants permanently backlogged, DRR weights divide grants
/// proportionally: a weight-3 tenant completes clearly more than a
/// weight-1 tenant (loose 1.5x bound to absorb scheduler noise).
#[test]
fn drr_weights_divide_throughput() {
    let svc = Arc::new(GraphService::new(
        small_pool(2),
        ServiceConfig {
            max_inflight: 2,
            retry: RetryPolicy::disabled(),
            ..ServiceConfig::default()
        },
    ));
    let heavy = svc.register_tenant(TenantSpec::new("heavy").weight(3).max_inflight(2));
    let light = svc.register_tenant(TenantSpec::new("light").weight(1).max_inflight(2));

    // 4 closed-loop clients per tenant against 2 tenant slots keep
    // both queues backlogged, so DRR deficits (not client pacing)
    // decide the split.
    let total = Arc::new(AtomicUsize::new(0));
    let mut clients = Vec::new();
    for (tenant, _) in [(heavy, "heavy"), (light, "light")] {
        for _ in 0..4 {
            let svc = svc.clone();
            let total = total.clone();
            clients.push(thread::spawn(move || {
                let (mut g, _) = Dag::diamond_chain(2).to_task_graph(512);
                while total.load(Ordering::Relaxed) < 400 {
                    if svc.run(tenant, &mut g).is_ok() {
                        total.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
    }
    for c in clients {
        c.join().unwrap();
    }
    let snaps = svc.tenant_snapshots();
    let (h, l) = (snaps[heavy.index()].completed, snaps[light.index()].completed);
    assert!(
        h as f64 >= l as f64 * 1.5,
        "weight-3 tenant should out-complete weight-1 by ~3x, got {h} vs {l}"
    );
}

/// Under *permanent* overload (the pool's single run slot held by a
/// parked run), the retry budget caps total retries at the initial
/// allowance — retry traffic cannot amplify the overload — and the
/// service recovers once the blocker finishes.
#[test]
fn retry_budget_caps_amplification_under_permanent_overload() {
    let pool = ThreadPool::with_config(PoolConfig {
        num_threads: 2,
        max_inflight_runs: 1,
        ..PoolConfig::default()
    });
    const INITIAL_BUDGET: u32 = 5;
    let svc = GraphService::new(
        pool,
        ServiceConfig {
            max_inflight: 8,
            retry: RetryPolicy {
                max_attempts: 100,
                base_backoff: Duration::from_micros(100),
                max_backoff: Duration::from_micros(400),
                jitter: 0.0,
                budget_ratio: 0.0, // no refill: the allowance is all there is
                initial_budget: INITIAL_BUDGET,
            },
            ..ServiceConfig::default()
        },
    );
    let t = svc.register_tenant(TenantSpec::new("victim").max_inflight(4));

    // Occupy the pool's only admission slot with a flag-blocked run.
    let release = Arc::new(AtomicBool::new(false));
    let r = release.clone();
    let mut blocker = TaskGraph::new();
    blocker.add(move || {
        while !r.load(Ordering::Relaxed) {
            thread::sleep(Duration::from_micros(50));
        }
    });
    let handle = blocker.run_async(svc.pool()).unwrap();

    let (mut g, counter) = Dag::diamond_chain(1).to_task_graph(64);
    let mut failures = 0;
    for _ in 0..10 {
        match svc.run(t, &mut g) {
            Err(ServeError::RetriesExhausted { last: GraphError::Overloaded, .. }) => {
                failures += 1
            }
            other => panic!("expected overload exhaustion, got {other:?}"),
        }
    }
    let snap = &svc.tenant_snapshots()[t.index()];
    assert_eq!(failures, 10);
    assert_eq!(counter.load(Ordering::Relaxed), 0, "nothing may execute while blocked");
    assert!(
        snap.retries <= u64::from(INITIAL_BUDGET),
        "10 overloaded requests made {} retries; the budget allows at most {}",
        snap.retries,
        INITIAL_BUDGET
    );
    assert_eq!(svc.retry_tokens(), 0, "permanent overload must drain the budget");

    release.store(true, Ordering::Relaxed);
    handle.wait().unwrap();
    svc.run(t, &mut g).expect("service must recover once the blocker finishes");
    assert_eq!(counter.load(Ordering::Relaxed), 4);
}

/// The brownout controller escalates only on sustained overload and
/// recovers one level per quiet hold — never all at once.
#[test]
fn brownout_escalates_and_recovers_hysteretically() {
    let ctl = BrownoutController::new(BrownoutConfig {
        enter: Duration::from_millis(1),
        enter_after: 4,
        exit_hold: Duration::from_millis(30),
    });
    // 3 high observations: below enter_after, still Normal.
    for _ in 0..3 {
        ctl.observe(Duration::from_millis(40));
    }
    assert_eq!(ctl.level(), BrownoutLevel::Normal);
    // Sustained overload: one level per full streak.
    ctl.observe(Duration::from_millis(40));
    assert_eq!(ctl.level(), BrownoutLevel::ShedLow);
    for _ in 0..4 {
        ctl.observe(Duration::from_millis(40));
    }
    assert_eq!(ctl.level(), BrownoutLevel::ShedOverQuota);
    // Recovery: one step per quiet exit_hold.
    thread::sleep(Duration::from_millis(40));
    assert_eq!(ctl.level(), BrownoutLevel::ShedLow, "first hold unwinds one level only");
    thread::sleep(Duration::from_millis(40));
    assert_eq!(ctl.level(), BrownoutLevel::Normal, "second hold completes recovery");
}

/// End-to-end brownout through the service gate: with a hair-trigger
/// threshold, real grant delays push the gate into `ShedLow`, Low-class
/// requests are shed at admission (their graphs never run), and
/// Normal-class requests keep being served.
#[test]
fn brownout_sheds_low_tenants_at_the_gate() {
    let svc = GraphService::new(
        small_pool(2),
        ServiceConfig {
            retry: RetryPolicy::disabled(),
            brownout: BrownoutConfig {
                enter: Duration::from_nanos(1), // any real grant delay trips it
                enter_after: 3,
                exit_hold: Duration::from_secs(3600),
            },
            ..ServiceConfig::default()
        },
    );
    let normal = svc.register_tenant(TenantSpec::new("normal"));
    let low = svc.register_tenant(TenantSpec::new("batch").class(RunPriority::Low));

    let (mut g, _) = Dag::diamond_chain(2).to_task_graph(64);
    for _ in 0..4 {
        svc.run(normal, &mut g).unwrap(); // each grant observes delay > 1ns
    }
    assert!(svc.brownout_level() >= BrownoutLevel::ShedLow);

    let (mut lg, low_counter) = Dag::diamond_chain(2).to_task_graph(64);
    for _ in 0..3 {
        match svc.run(low, &mut lg) {
            Err(ServeError::Shed(_)) => {}
            other => panic!("low-class request must be shed in brownout, got {other:?}"),
        }
    }
    assert_eq!(low_counter.load(Ordering::Relaxed), 0, "shed graphs must never launch");
    svc.run(normal, &mut g).expect("normal-class tenants keep being served");
    let snaps = svc.tenant_snapshots();
    assert_eq!(snaps[low.index()].shed_low, 3);
    assert_eq!(snaps[normal.index()].completed, 5);
}

/// A request whose deadline is already infeasible (≤ the queue-delay
/// EWMA) is rejected with `WouldMissDeadline` at the gate, before it
/// consumes a service slot, a pool budget slot, or any execution.
#[test]
fn infeasible_deadline_rejected_before_consuming_budget() {
    let svc = GraphService::new(
        small_pool(2),
        ServiceConfig { retry: RetryPolicy::disabled(), ..ServiceConfig::default() },
    );
    let t = svc.register_tenant(TenantSpec::new("dl"));
    let (mut g, counter) = Dag::diamond_chain(2).to_task_graph(64);
    svc.run(t, &mut g).unwrap(); // warm-up grant seeds the gate's EWMA
    assert!(svc.queue_delay_ewma() > Duration::ZERO);

    let err = svc.run_with(t, &mut g, Some(Duration::from_nanos(1))).unwrap_err();
    assert!(
        matches!(err, ServeError::Failed(GraphError::WouldMissDeadline)),
        "got {err:?}"
    );
    let snap = &svc.tenant_snapshots()[t.index()];
    assert_eq!(snap.shed_deadline, 1);
    assert_eq!(snap.inflight, 0, "rejection must not hold a slot");
    assert_eq!(counter.load(Ordering::Relaxed), 8, "only the warm-up ran");
}

/// The same feasibility seam exists one layer down, at the pool-EWMA
/// admission check in the graph executor: a heated pool EWMA rejects a
/// short-deadline run before the PR 6 budget is consulted.
#[test]
fn pool_ewma_seam_rejects_infeasible_runs() {
    use scheduling::graph::RunOptions;
    let pool = small_pool(2);
    for _ in 0..8 {
        pool.note_queue_delay(Duration::from_millis(50));
    }
    assert!(pool.queue_delay_ewma() >= Duration::from_millis(40));
    let (mut g, counter) = Dag::diamond_chain(2).to_task_graph(64);
    let err = g
        .try_run_with_options(&pool, RunOptions::new().deadline(Duration::from_millis(1)))
        .unwrap_err();
    assert!(matches!(err, GraphError::WouldMissDeadline), "got {err:?}");
    assert_eq!(counter.load(Ordering::Relaxed), 0);
    // A feasible (no-deadline) run on the same pool still works.
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), 8);
}

/// A request that retries after pool-budget rejections runs its graph
/// exactly once when it finally succeeds: rejected attempts never
/// execute any node.
#[test]
fn retried_request_executes_exactly_once() {
    let pool = ThreadPool::with_config(PoolConfig {
        num_threads: 2,
        max_inflight_runs: 1,
        ..PoolConfig::default()
    });
    let svc = Arc::new(GraphService::new(
        pool,
        ServiceConfig {
            retry: RetryPolicy {
                max_attempts: 1000,
                base_backoff: Duration::from_micros(200),
                max_backoff: Duration::from_millis(2),
                jitter: 0.5,
                budget_ratio: 1.0,
                initial_budget: 1000,
            },
            ..ServiceConfig::default()
        },
    ));
    let t = svc.register_tenant(TenantSpec::new("persistent"));

    let release = Arc::new(AtomicBool::new(false));
    let r = release.clone();
    let mut blocker = TaskGraph::new();
    blocker.add(move || {
        while !r.load(Ordering::Relaxed) {
            thread::sleep(Duration::from_micros(50));
        }
    });
    let handle = blocker.run_async(svc.pool()).unwrap();

    let client = {
        let svc = svc.clone();
        thread::spawn(move || {
            let (mut g, counter) = Dag::diamond_chain(3).to_task_graph(64);
            svc.run(t, &mut g).unwrap();
            counter.load(Ordering::Relaxed)
        })
    };
    // Hold the pool shut long enough for at least one rejected attempt,
    // then release and let the client's retry land.
    thread::sleep(Duration::from_millis(10));
    release.store(true, Ordering::Relaxed);
    handle.wait().unwrap();
    let executed = client.join().unwrap();
    assert_eq!(executed, 12, "exactly one execution of the 12-node graph");
    let snap = &svc.tenant_snapshots()[t.index()];
    assert_eq!(snap.completed, 1);
    assert!(snap.retries >= 1, "the blocker must have forced at least one retry");
}

/// Requests queued and backing off concurrently still each execute
/// exactly once — M clients × one graph each == M×n node executions.
#[test]
fn fleet_of_retrying_clients_each_execute_once() {
    let svc = Arc::new(GraphService::new(
        small_pool(2),
        ServiceConfig { max_inflight: 3, ..ServiceConfig::default() },
    ));
    let t = svc.register_tenant(TenantSpec::new("fleet").max_inflight(3));
    const CLIENTS: usize = 8;
    const ROUNDS: usize = 5;
    let counter = Arc::new(AtomicUsize::new(0));
    let clients: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let svc = svc.clone();
            let counter = counter.clone();
            thread::spawn(move || {
                let c = counter.clone();
                let mut g = TaskGraph::new();
                let a = g.add({
                    let c = c.clone();
                    move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                });
                let b = g.add(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
                g.precede(a, &[b]);
                for _ in 0..ROUNDS {
                    svc.run(t, &mut g).unwrap();
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    assert_eq!(counter.load(Ordering::Relaxed), CLIENTS * ROUNDS * 2);
    assert_eq!(svc.tenant_snapshots()[t.index()].completed, (CLIENTS * ROUNDS) as u64);
}

/// PR 8 regression (cold-gate deadline hole): an already-expired
/// deadline must be rejected even when the service is *cold* — no
/// completions yet, queue-delay EWMA still zero. Before the fix the
/// feasibility check only ran once the EWMA was nonzero, so the very
/// first requests could sail past their deadlines into the pool.
#[test]
fn cold_gate_rejects_already_expired_deadline() {
    let svc = GraphService::new(
        small_pool(2),
        ServiceConfig { retry: RetryPolicy::disabled(), ..ServiceConfig::default() },
    );
    let t = svc.register_tenant(TenantSpec::new("cold"));
    assert_eq!(svc.queue_delay_ewma(), Duration::ZERO, "premise: gate is cold");

    let (mut g, counter) = Dag::diamond_chain(2).to_task_graph(64);
    let err = svc.run_with(t, &mut g, Some(Duration::ZERO)).unwrap_err();
    assert!(
        matches!(err, ServeError::Failed(GraphError::WouldMissDeadline)),
        "got {err:?}"
    );
    let snap = &svc.tenant_snapshots()[t.index()];
    assert_eq!(snap.shed_deadline, 1);
    assert_eq!(snap.retries, 0, "infeasible is terminal, not retryable");
    assert_eq!(snap.inflight, 0);
    assert_eq!(counter.load(Ordering::Relaxed), 0, "expired request must never launch");
}

/// SLO feedback (PR 8): a `High`-class tenant whose observed service
/// time blows through `demote_slow_after` gets its launches demoted to
/// `Normal` — the express lanes are earned by behavior, not just
/// declared. The declared spec is untouched and completions keep
/// flowing.
#[test]
fn slow_tenant_stops_being_high() {
    let svc = GraphService::new(
        small_pool(2),
        ServiceConfig {
            retry: RetryPolicy::disabled(),
            demote_slow_after: Some(Duration::from_millis(1)),
            ..ServiceConfig::default()
        },
    );
    let hog = svc.register_tenant(TenantSpec::new("hog").class(RunPriority::High));

    let mut g = TaskGraph::new();
    g.add(|| thread::sleep(Duration::from_millis(4)));
    const RUNS: u64 = 4;
    for _ in 0..RUNS {
        svc.run(hog, &mut g).unwrap();
    }
    let snap = &svc.tenant_snapshots()[hog.index()];
    assert_eq!(snap.completed, RUNS, "demotion must not drop work");
    assert!(
        snap.service_ewma_ns > 1_000_000,
        "premise: observed service time above the 1ms threshold, got {}ns",
        snap.service_ewma_ns
    );
    // Run 1 launches with a cold (zero) EWMA at its declared class;
    // every later run sees the blown EWMA and is demoted.
    assert!(
        snap.demotions >= RUNS - 1,
        "expected ≥{} demotions, got {}",
        RUNS - 1,
        snap.demotions
    );
}

/// End-to-end wire front-end, cross-process: spawn the `graph_serve`
/// binary, speak the framed protocol to it from this process, and
/// scrape its counters. This is the satellite guarding the whole
/// PR 8 wire stack (bin arg parsing, template registry, framing,
/// service integration) rather than the in-process loopback the unit
/// tests cover.
#[test]
fn wire_round_trip_against_spawned_server() {
    use scheduling::serve::{WireClient, WireStatus};
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_graph_serve"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--metrics-addr",
            "127.0.0.1:0",
            "--threads",
            "2",
            "--work-steps",
            "64",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn graph_serve");

    // Readiness line: "graph_serve listening on ADDR (metrics on MADDR)".
    let mut line = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut line).unwrap();
    let parts: Vec<&str> = line.split_whitespace().collect();
    let addr = parts.get(3).copied().unwrap_or_else(|| panic!("bad readiness line {line:?}"));

    let outcome = std::panic::catch_unwind(|| {
        let mut c = WireClient::connect(addr).expect("connect to spawned server");
        for _ in 0..3 {
            let (status, msg) = c.run("gold", "diamond4", None).unwrap();
            assert_eq!(status, WireStatus::Ok, "{msg}");
        }
        let (status, _) = c.run("storm", "no-such-template", None).unwrap();
        assert_eq!(status, WireStatus::UnknownTemplate);
        let stats = c.scrape().unwrap();
        assert!(stats.contains("tenant_completed{tenant=\"gold\"} 3"), "{stats}");
        assert!(stats.contains("pool_threads 2"), "{stats}");
        // PR 9: the scrape is a real Prometheus exposition now — hold
        // it to the strict validator, cross-process.
        scheduling::obs::validate(&stats).expect("cross-process STATS must validate");
        let v2 = c.scrape_v2().unwrap();
        scheduling::obs::validate(&v2).expect("cross-process STATS2 must validate");
        assert!(v2.contains("tenant_latency_ns_quantile{tenant=\"gold\",q=\"0.99\"}"), "{v2}");
        let trace = c.dump().expect("default server pool has the flight recorder on");
        assert!(trace.starts_with("{\"traceEvents\":["), "{trace}");

        // And the `validate` subcommand agrees (the CI smoke step runs
        // exactly this against the live server).
        let out = Command::new(env!("CARGO_BIN_EXE_graph_serve"))
            .args(["validate", "--addr", addr])
            .output()
            .expect("run graph_serve validate");
        let text = format!(
            "{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(out.status.success(), "graph_serve validate failed:\n{text}");
        assert!(text.contains("STATS: valid exposition"), "{text}");
        assert!(text.contains("STATS2: valid exposition"), "{text}");
    });
    let _ = child.kill();
    let _ = child.wait();
    if let Err(p) = outcome {
        std::panic::resume_unwind(p);
    }
}

/// Chaos soak (only with `--features chaos`): storm the serving
/// boundary with injected `Overloaded` and node-latency spikes, then
/// stop injection and assert goodput converges back to 100% clean.
#[cfg(feature = "chaos")]
mod chaos_storms {
    use super::*;
    use scheduling::graph::chaos_set_serving_rates;

    #[test]
    fn chaos_storm_goodput_converges_after_injection_stops() {
        let svc = Arc::new(GraphService::new(
            small_pool(2),
            ServiceConfig {
                max_inflight: 4,
                retry: RetryPolicy {
                    max_attempts: 6,
                    base_backoff: Duration::from_micros(100),
                    max_backoff: Duration::from_millis(1),
                    jitter: 0.5,
                    budget_ratio: 0.5,
                    initial_budget: 32,
                    // generous budget: the storm is transient by design
                },
                ..ServiceConfig::default()
            },
        ));
        let t = svc.register_tenant(TenantSpec::new("soak").weight(2).max_inflight(4));

        // Storm: 15% of launches rejected Overloaded, 10% of nodes
        // spiked by ~200us.
        chaos_set_serving_rates(150, 100, 200);
        let deadline = Instant::now() + Duration::from_secs(2);
        let (mut ok, mut total) = (0u64, 0u64);
        let (mut g, _) = Dag::diamond_chain(2).to_task_graph(64);
        while Instant::now() < deadline && total < 400 {
            total += 1;
            if svc.run(t, &mut g).is_ok() {
                ok += 1;
            }
        }
        assert!(total > 50, "soak must actually run requests");
        assert!(
            ok * 2 >= total,
            "retries should absorb most of the storm: {ok}/{total} succeeded"
        );

        // Injection off: goodput must converge back to 100%.
        chaos_set_serving_rates(0, 0, 0);
        for _ in 0..50 {
            svc.run(t, &mut g).expect("post-storm requests must all succeed");
        }
        let snap = &svc.tenant_snapshots()[t.index()];
        assert!(snap.retries > 0, "the storm must have exercised the retry path");
        assert_eq!(svc.brownout_level(), BrownoutLevel::Normal, "gate recovers post-storm");
    }

    /// PR 8 regression (grant-slot leak): a panic between GRANTED and
    /// release — injected here on the launch path itself — must still
    /// release the tenant's and the service's inflight slots (the
    /// `GrantGuard` RAII fix). Before the fix each panic leaked one
    /// slot, and with `max_inflight: 1` the service wedged after the
    /// first one. Runs under `--test-threads=1` in CI because the
    /// chaos rates are process-global (shared with the soak above).
    #[test]
    fn chaos_launch_panic_does_not_leak_grant_slots() {
        use scheduling::graph::chaos_set_launch_panic_rate;
        use std::panic::{catch_unwind, AssertUnwindSafe};

        let svc = Arc::new(GraphService::new(
            small_pool(2),
            ServiceConfig {
                max_inflight: 1,
                retry: RetryPolicy::disabled(),
                ..ServiceConfig::default()
            },
        ));
        let t = svc.register_tenant(TenantSpec::new("unlucky").max_inflight(1));
        chaos_set_serving_rates(0, 0, 0); // isolate: launch panics only
        chaos_set_launch_panic_rate(1000);

        let (mut g, counter) = Dag::diamond_chain(1).to_task_graph(64);
        for i in 0..4 {
            let r = catch_unwind(AssertUnwindSafe(|| svc.run(t, &mut g)));
            assert!(r.is_err(), "attempt {i}: injected launch panic must unwind out");
        }
        assert_eq!(counter.load(Ordering::Relaxed), 0, "panicked launches ran nothing");
        assert_eq!(
            svc.tenant_snapshots()[t.index()].inflight,
            0,
            "every panicked grant must have been released"
        );

        // Injection off: with max_inflight 1, any leaked slot would
        // wedge this run forever — do it on a watchdog'd thread.
        chaos_set_launch_panic_rate(0);
        let (tx, rx) = std::sync::mpsc::channel();
        let svc2 = svc.clone();
        thread::spawn(move || {
            let (mut g, _) = Dag::diamond_chain(1).to_task_graph(64);
            tx.send(svc2.run(t, &mut g)).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(10))
            .expect("post-panic run must be granted (no leaked slots)")
            .expect("post-panic run must succeed");
        assert_eq!(svc.tenant_snapshots()[t.index()].completed, 1);
    }
}
