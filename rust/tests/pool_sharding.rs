//! PR 5 sharding tier: sharded submission and locality-aware stealing.
//!
//! Covers the three properties the shard layer promises:
//!
//! 1. **Exactly-once delivery across shards** — a many-producer storm
//!    on a sharded pool (striped round-robin routing, per-shard
//!    injectors, two-level sweep) observes every task exactly once.
//! 2. **Sweep order** — a worker prefers its home shard's injector but
//!    reaches remote shards' work (locality first, starvation never).
//! 3. **No stranding** — work pinned to a shard whose workers are all
//!    busy is executed by other shards' workers; workers never park
//!    for good while any shard's queues are non-empty.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use scheduling::graph::RunOptions;
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::workloads::Dag;

fn sharded_pool(num_threads: usize, shard_size: usize) -> ThreadPool {
    ThreadPool::with_config(PoolConfig {
        num_threads,
        shard_size,
        ..PoolConfig::default()
    })
}

/// A task that blocks its worker until released, reporting when it
/// started. Used to wedge workers deterministically.
struct Gate {
    started: Arc<AtomicUsize>,
    release: Arc<AtomicUsize>,
}

impl Gate {
    fn new() -> Self {
        Gate {
            started: Arc::new(AtomicUsize::new(0)),
            release: Arc::new(AtomicUsize::new(0)),
        }
    }

    fn task(&self) -> impl FnOnce() + Send + 'static {
        let (s, r) = (self.started.clone(), self.release.clone());
        move || {
            s.fetch_add(1, Ordering::SeqCst);
            while r.load(Ordering::SeqCst) == 0 {
                std::thread::yield_now();
            }
        }
    }

    fn wait_started(&self, n: usize) {
        while self.started.load(Ordering::SeqCst) < n {
            std::thread::yield_now();
        }
    }

    fn open(&self) {
        self.release.store(1, Ordering::SeqCst);
    }
}

#[test]
fn many_producer_storm_exactly_once_on_sharded_pool() {
    // The tentpole stress: shard_size=2 on an 8-worker pool, 8 external
    // producers, every task observed exactly once. Producers route
    // through per-thread striped cursors, so the storm spreads over all
    // 4 shards' injectors with zero shared routing state.
    const PRODUCERS: usize = 8;
    const PER: usize = 2_000;
    let pool = Arc::new(sharded_pool(8, 2));
    assert_eq!(pool.num_shards(), 4);
    let seen = Arc::new((0..PRODUCERS * PER).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let (pool, seen) = (pool.clone(), seen.clone());
        producers.push(std::thread::spawn(move || {
            for i in 0..PER {
                let seen = seen.clone();
                let id = p * PER + i;
                pool.submit(move || {
                    seen[id].fetch_add(1, Ordering::Relaxed);
                });
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    pool.wait_idle();
    for (id, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "task {id}");
    }
    assert_eq!(pool.pending(), 0);
    // The storm actually exercised more than one shard's injector.
    let total = pool.metrics().total();
    assert!(total.injector_pops > 0);
}

#[test]
fn storm_exactly_once_with_pinned_shards() {
    // Same storm, but every producer pins all its tasks to one shard
    // via submit_to_shard — the worst-case imbalance the two-level
    // sweep must still drain exactly once.
    const PRODUCERS: usize = 4;
    const PER: usize = 2_000;
    let pool = Arc::new(sharded_pool(8, 2));
    let seen = Arc::new((0..PRODUCERS * PER).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let (pool, seen) = (pool.clone(), seen.clone());
        producers.push(std::thread::spawn(move || {
            for i in 0..PER {
                let seen = seen.clone();
                let id = p * PER + i;
                // Everyone hammers shard 1.
                pool.submit_to_shard(1, move || {
                    seen[id].fetch_add(1, Ordering::Relaxed);
                });
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    pool.wait_idle();
    for (id, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "task {id}");
    }
}

#[test]
fn sweep_prefers_home_shard_before_remote() {
    // Deterministic sweep-order probe: wedge both workers of a
    // 2-worker / 2-shard pool, stage one task in each shard's
    // injector, release exactly one worker, and observe which task it
    // runs first. The freed worker's sweep must hit its HOME shard's
    // injector before the remote one — and still reach the remote one
    // afterwards (locality preferred, starvation impossible).
    for _ in 0..8 {
        let pool = Arc::new(ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            shard_size: 1,
            spin_rounds: 0,
            ..PoolConfig::default()
        }));
        // Two distinct gates; learn which worker runs which gate.
        let gates = [Gate::new(), Gate::new()];
        let worker_of_gate: Arc<[AtomicUsize; 2]> =
            Arc::new([AtomicUsize::new(usize::MAX), AtomicUsize::new(usize::MAX)]);
        for (g, gate) in gates.iter().enumerate() {
            let task = gate.task();
            let w = worker_of_gate.clone();
            let p = pool.clone();
            pool.submit(move || {
                w[g].store(p.current_worker().expect("gate runs on a worker"), Ordering::SeqCst);
                task();
            });
        }
        gates[0].wait_started(1);
        gates[1].wait_started(1);
        // Both workers are wedged; worker indices are now known.
        let w0 = worker_of_gate[0].load(Ordering::SeqCst);
        let free = w0; // we will release gate 0; its worker becomes free
        let home_shard = free; // shard_size=1 ⇒ shard == worker index
        let remote_shard = 1 - home_shard;
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        // Stage the REMOTE task first so FIFO arrival order cannot be
        // mistaken for the locality preference we assert.
        let o = order.clone();
        pool.submit_to_shard(remote_shard, move || o.lock().unwrap().push("remote"));
        let o = order.clone();
        pool.submit_to_shard(home_shard, move || o.lock().unwrap().push("home"));
        gates[0].open();
        // The free worker drains both; the wedged one can't interfere.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while order.lock().unwrap().len() < 2 {
            assert!(std::time::Instant::now() < deadline, "staged tasks starved");
            std::thread::yield_now();
        }
        assert_eq!(
            *order.lock().unwrap(),
            vec!["home", "remote"],
            "home-shard injector must be swept before remote shards"
        );
        gates[1].open();
        pool.wait_idle();
    }
}

#[test]
fn pinned_shard_work_is_not_starved_by_busy_shard() {
    // All tasks pinned to the shards of a wedged worker: the other
    // worker (a different shard) must steal across and execute
    // everything — workers never idle while any shard's injector is
    // non-empty.
    let pool = Arc::new(ThreadPool::with_config(PoolConfig {
        num_threads: 2,
        shard_size: 1,
        spin_rounds: 0,
        ..PoolConfig::default()
    }));
    let gate = Gate::new();
    pool.submit(gate.task());
    gate.wait_started(1);
    // One worker is wedged; pin work to BOTH shards so whichever shard
    // the wedged worker calls home is loaded too.
    let count = Arc::new(AtomicUsize::new(0));
    for shard in 0..2 {
        for _ in 0..100 {
            let c = count.clone();
            pool.submit_to_shard(shard, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while count.load(Ordering::SeqCst) < 200 {
        assert!(
            std::time::Instant::now() < deadline,
            "cross-shard work starved: {}/200 after 10s",
            count.load(Ordering::SeqCst)
        );
        std::thread::yield_now();
    }
    gate.open();
    pool.wait_idle();
    // The free worker necessarily crossed shards for half the tasks.
    assert!(pool.metrics().total().remote_injector_pops > 0);
}

#[test]
fn graph_runs_on_sharded_pool_with_and_without_pin() {
    // Graph execution end to end on a sharded pool: default routing,
    // then pinned to each shard via RunOptions::shard (including an
    // out-of-range pin, which clamps).
    let pool = sharded_pool(4, 2);
    let (mut g, counter) = Dag::binary_tree(8).to_task_graph(0);
    g.run(&pool).unwrap();
    let n = counter.load(Ordering::SeqCst); // per-run node count
    assert!(n > 0);
    let mut expected = n;
    for pin in [0usize, 1, 99] {
        g.run_with_options(&pool, RunOptions::new().on_shard(pin)).unwrap();
        expected += n;
        assert_eq!(counter.load(Ordering::SeqCst), expected, "pin={pin}");
    }
    // Async handles on a sharded pool, pinned to different shards.
    let (mut g2, c2) = Dag::diamond_chain(32).to_task_graph(0);
    {
        let h = g2.run_async_with_options(&pool, RunOptions::new().on_shard(1)).unwrap();
        h.wait().unwrap();
    }
    assert!(c2.load(Ordering::SeqCst) > 0);
}

#[test]
fn sharded_rerun_agrees_with_flat_rerun() {
    // The same graph re-run on a flat pool and a sharded pool must
    // produce identical counter trajectories — sharding is a routing
    // change, never a semantics change.
    let flat = ThreadPool::with_config(PoolConfig {
        num_threads: 4,
        shard_size: 64, // >= num_threads ⇒ single shard
        ..PoolConfig::default()
    });
    assert_eq!(flat.num_shards(), 1);
    let sharded = sharded_pool(4, 1);
    assert_eq!(sharded.num_shards(), 4);
    let (mut ga, ca) = Dag::wavefront(12).to_task_graph(0);
    let (mut gb, cb) = Dag::wavefront(12).to_task_graph(0);
    for rep in 1..=5usize {
        ga.run(&flat).unwrap();
        gb.run(&sharded).unwrap();
        assert_eq!(ca.load(Ordering::SeqCst), cb.load(Ordering::SeqCst), "rep {rep}");
    }
}

#[test]
fn shard_depth_metrics_expose_staged_work() {
    // Wedge all workers, stage work, and read the per-shard depth
    // snapshot the storm bench uses for its imbalance line.
    let pool = sharded_pool(2, 1);
    let gate = Gate::new();
    pool.submit(gate.task());
    pool.submit(gate.task());
    gate.wait_started(2);
    for _ in 0..6 {
        pool.submit_to_shard(0, || {});
    }
    for _ in 0..2 {
        pool.submit_to_shard(1, || {});
    }
    let snap = pool.metrics();
    assert_eq!(snap.shards.len(), 2);
    assert_eq!(snap.shards[0].injector_depth, 6);
    assert_eq!(snap.shards[1].injector_depth, 2);
    assert_eq!(snap.shards[0].lane_depths.iter().sum::<usize>(), 6);
    // depths 6,2 ⇒ mean 4, max 6 ⇒ imbalance 1.5.
    assert!((snap.shard_imbalance() - 1.5).abs() < 1e-9);
    gate.open();
    pool.wait_idle();
    let snap = pool.metrics();
    assert_eq!(snap.shards.iter().map(|s| s.queued()).sum::<usize>(), 0);
}

#[test]
fn tracer_samples_shard_depths() {
    use scheduling::graph::Tracer;
    let pool = sharded_pool(2, 1);
    let gate = Gate::new();
    pool.submit(gate.task());
    pool.submit(gate.task());
    gate.wait_started(2);
    pool.submit_to_shard(1, || {});
    let tracer = Tracer::new();
    tracer.sample_shard_depths(&pool.metrics());
    let samples = tracer.shard_depth_samples();
    assert_eq!(samples.len(), 2);
    assert_eq!(samples[1].injector_depth, 1);
    assert!(tracer.to_chrome_trace().contains("shard1 depth"));
    gate.open();
    pool.wait_idle();
}
