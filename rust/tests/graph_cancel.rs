//! Run-lifecycle robustness tier (PR 6).
//!
//! Pins down the cancellation / deadline / panic-quarantine /
//! admission surfaces end to end:
//!
//! * cancel before launch, mid-run, and after completion (idempotent);
//! * a cancelled 10k-node run stops without running remaining nodes
//!   and reports the typed error from **every** wait surface —
//!   blocking `run`, `RunHandle::wait`, `try_wait`, and
//!   `Future::poll` — leaving the pool quiescent;
//! * deadlines: an expired deadline aborts (never early), a generous
//!   one never fires, and `wait_timeout` returns `None` on timeout
//!   without consuming the handle;
//! * generation counters stay monotone across aborted runs and the
//!   same sealed graph un-poisons on its next run;
//! * a panicking node aborts its run with `NodePanicked` while the
//!   pool keeps its full worker complement — on flat and sharded
//!   (shard_size=2) pools, sync and async (the catch_unwind coverage
//!   matrix);
//! * admission control: `try_run` beyond `max_inflight_runs` fails
//!   with `Overloaded`, Low-class runs are shed first, blocking `run`
//!   waits for a released slot;
//! * 64 option-mask property rows with cancellation injected at a
//!   random node of a random DAG;
//! * `chaos_*` tests (feature `chaos`, rates via `CHAOS_PANIC_RATE` /
//!   `CHAOS_CANCEL_RATE`) — injection-tolerant storms asserting
//!   no-deadlock, typed errors, and a usable pool, never exact counts.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use scheduling::graph::{CancelToken, GraphError, RunOptions, RunPriority, TaskGraph};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::util::Pcg32;
use scheduling::workloads::Dag;

/// Blocks on a `RunHandle`'s `Future` impl with a thread-parking
/// waker (same idiom as `graph_async.rs`) — the fourth wait surface.
fn block_on<F: std::future::Future>(fut: F) -> F::Output {
    struct Unparker(std::thread::Thread);
    impl std::task::Wake for Unparker {
        fn wake(self: Arc<Self>) {
            self.0.unpark();
        }
    }
    let waker = std::task::Waker::from(Arc::new(Unparker(std::thread::current())));
    let mut cx = std::task::Context::from_waker(&waker);
    let mut fut = std::pin::pin!(fut);
    loop {
        match fut.as_mut().poll(&mut cx) {
            std::task::Poll::Ready(v) => return v,
            // park_timeout rather than park: a lost wakeup then shows
            // up as a slow test instead of a hung CI job.
            std::task::Poll::Pending => std::thread::park_timeout(Duration::from_millis(100)),
        }
    }
}

/// `n`-node linear chain counting total executions.
fn chain(n: usize) -> (TaskGraph, Arc<AtomicUsize>) {
    Dag::linear_chain(n).to_task_graph(0)
}

/// Two-node chain whose head spins until `gate` opens — a
/// deterministic "run in flight" window; the tail bumps `tail_runs`.
fn gated_chain() -> (TaskGraph, Arc<AtomicBool>, Arc<AtomicUsize>) {
    let gate = Arc::new(AtomicBool::new(false));
    let tail_runs = Arc::new(AtomicUsize::new(0));
    let mut g = TaskGraph::new();
    let ga = gate.clone();
    let head = g.add(move || {
        while !ga.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
    });
    let t = tail_runs.clone();
    let tail = g.add(move || {
        t.fetch_add(1, Ordering::SeqCst);
    });
    g.precede(head, &[tail]);
    (g, gate, tail_runs)
}

#[test]
fn cancel_before_mid_after_is_idempotent() {
    let pool = ThreadPool::new(2);

    // Before: a pre-cancelled token skips every node.
    let (mut g, counter) = chain(64);
    let token = CancelToken::new();
    token.cancel();
    token.cancel(); // idempotent on the token itself
    let r = g.run_with_options(&pool, RunOptions::new().cancel_token(token));
    assert!(matches!(r, Err(GraphError::Cancelled)));
    assert_eq!(counter.load(Ordering::Relaxed), 0);

    // Mid-run: cancel while the head node is blocked; the tail (its
    // successor) must be skipped once the head finishes.
    let (mut gg, gate, tail_runs) = gated_chain();
    let h = gg.run_async(&pool).unwrap();
    h.cancel();
    h.cancel(); // idempotent on the handle
    gate.store(true, Ordering::SeqCst);
    assert!(matches!(h.wait(), Err(GraphError::Cancelled)));
    assert_eq!(tail_runs.load(Ordering::SeqCst), 0, "successor ran after cancel");

    // After: cancelling a completed run is a no-op and the harvest
    // stays Ok.
    gate.store(true, Ordering::SeqCst);
    let mut h = gg.run_async(&pool).unwrap();
    while !h.is_done() {
        std::thread::yield_now();
    }
    h.cancel();
    assert!(matches!(h.try_wait(), Some(Ok(()))));
    assert_eq!(tail_runs.load(Ordering::SeqCst), 1);

    // The graph itself is un-poisoned: a plain re-run succeeds.
    gg.run(&pool).unwrap();
    assert_eq!(tail_runs.load(Ordering::SeqCst), 2);
    pool.wait_idle();
}

#[test]
fn cancelled_10k_run_reports_from_every_wait_surface() {
    let pool = ThreadPool::new(4);
    let n = 10_000;
    let (mut g, counter) = chain(n);

    // Surface 1: blocking run().
    let pre = CancelToken::new();
    pre.cancel();
    let r = g.run_with_options(&pool, RunOptions::new().cancel_token(pre.clone()));
    assert!(matches!(r, Err(GraphError::Cancelled)), "blocking run surface");
    // Surface 2: RunHandle::wait.
    let h = g.run_async_with_options(&pool, RunOptions::new().cancel_token(pre.clone())).unwrap();
    assert!(matches!(h.wait(), Err(GraphError::Cancelled)), "wait surface");
    // Surface 3: try_wait (poll until resolved).
    let mut h = g.run_async_with_options(&pool, RunOptions::new().cancel_token(pre.clone())).unwrap();
    let r = loop {
        if let Some(r) = h.try_wait() {
            break r;
        }
        std::thread::yield_now();
    };
    assert!(matches!(r, Err(GraphError::Cancelled)), "try_wait surface");
    // Surface 4: Future::poll.
    let h = g.run_async_with_options(&pool, RunOptions::new().cancel_token(pre)).unwrap();
    assert!(matches!(block_on(h), Err(GraphError::Cancelled)), "future surface");

    // No node of the 10k chain ever ran, and the pool is quiescent
    // with balanced metrics.
    assert_eq!(counter.load(Ordering::Relaxed), 0, "cancelled nodes must not run");
    pool.wait_idle();
    assert_eq!(pool.pending(), 0);
    let m = pool.metrics();
    assert_eq!(m.alive_workers, 4);
    assert_eq!(m.worker_revivals, 0);

    // Same sealed graph, fresh run: every node executes.
    g.run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), n);
}

#[test]
fn deadline_expiry_aborts_and_generous_deadline_does_not() {
    let pool = ThreadPool::new(2);

    // Hold the run open past a short deadline: the tail must be
    // skipped and the error is DeadlineExceeded, never early.
    let (mut g, gate, tail_runs) = gated_chain();
    let deadline = Duration::from_millis(20);
    let started = Instant::now();
    let h = g
        .run_async_with_options(&pool, RunOptions::new().deadline(deadline))
        .unwrap();
    std::thread::sleep(Duration::from_millis(60));
    gate.store(true, Ordering::SeqCst);
    match h.wait() {
        Err(GraphError::DeadlineExceeded) => {
            assert!(started.elapsed() >= deadline, "deadline fired early");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(tail_runs.load(Ordering::SeqCst), 0);

    // A generous deadline never aborts a fast run.
    let (mut fast, counter) = chain(128);
    fast.run_with_options(&pool, RunOptions::new().deadline(Duration::from_secs(60))).unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), 128);
    pool.wait_idle();
}

#[test]
fn wait_timeout_returns_none_then_some() {
    let pool = ThreadPool::new(2);
    let (mut g, gate, tail_runs) = gated_chain();
    let mut h = g.run_async(&pool).unwrap();
    // Still in flight: a bounded wait times out without consuming the
    // handle or the run.
    assert!(h.wait_timeout(Duration::from_millis(30)).is_none());
    assert!(!h.is_done());
    gate.store(true, Ordering::SeqCst);
    // Now it completes well within the bound.
    match h.wait_timeout(Duration::from_secs(30)) {
        Some(Ok(())) => {}
        other => panic!("expected Some(Ok), got {other:?}"),
    }
    // After done: immediate.
    assert!(matches!(h.wait_timeout(Duration::from_millis(1)), Some(Ok(()))));
    assert_eq!(tail_runs.load(Ordering::SeqCst), 1);
}

#[test]
fn generations_stay_monotone_across_aborted_runs() {
    let pool = ThreadPool::new(2);
    let (mut g, counter) = chain(32);
    let h = g.run_async(&pool).unwrap();
    let g1 = h.generation();
    h.wait().unwrap();

    // An aborted run still consumes exactly one generation.
    let token = CancelToken::new();
    token.cancel();
    let h = g.run_async_with_options(&pool, RunOptions::new().cancel_token(token)).unwrap();
    assert_eq!(h.generation(), g1 + 1);
    assert!(matches!(h.wait(), Err(GraphError::Cancelled)));

    let h = g.run_async(&pool).unwrap();
    assert_eq!(h.generation(), g1 + 2);
    h.wait().unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), 64, "two clean runs of 32 nodes");
}

/// Builds a pool with an admission budget.
fn budget_pool(threads: usize, max_inflight: usize) -> ThreadPool {
    ThreadPool::with_config(PoolConfig {
        num_threads: threads,
        max_inflight_runs: max_inflight,
        ..PoolConfig::default()
    })
}

#[test]
fn try_run_overloads_then_recovers_when_slot_releases() {
    let pool = budget_pool(2, 1);
    let (mut gated, gate, _tail) = gated_chain();
    let h = gated.run_async(&pool).unwrap(); // holds the only slot

    let (mut g, counter) = chain(16);
    assert!(matches!(g.try_run(&pool), Err(GraphError::Overloaded)));
    assert_eq!(counter.load(Ordering::Relaxed), 0, "rejected run must not submit");

    gate.store(true, Ordering::SeqCst);
    h.wait().unwrap(); // releases the slot
    g.try_run(&pool).unwrap();
    assert_eq!(counter.load(Ordering::Relaxed), 16);
    pool.wait_idle();
}

#[test]
fn blocking_run_waits_for_admission_instead_of_failing() {
    let pool = Arc::new(budget_pool(2, 1));
    let (mut gated, gate, _tail) = gated_chain();
    let h = gated.run_async(&pool).unwrap(); // holds the only slot

    // A blocking run from another thread parks on the budget
    // eventcount and completes once the slot frees.
    let p = pool.clone();
    let blocked = std::thread::spawn(move || {
        let (mut g, counter) = chain(16);
        g.run(&p).unwrap();
        counter.load(Ordering::Relaxed)
    });
    // Give the blocked thread time to reach admission, then release.
    std::thread::sleep(Duration::from_millis(50));
    gate.store(true, Ordering::SeqCst);
    h.wait().unwrap();
    assert_eq!(blocked.join().unwrap(), 16);
    pool.wait_idle();
}

#[test]
fn low_class_runs_are_shed_first() {
    // max_inflight_runs = 4 → Low's effective limit is 3: with three
    // slots held, a Low try_run is shed while a Normal one still fits.
    let pool = budget_pool(4, 4);
    let gate = Arc::new(AtomicBool::new(false));
    let mut holders: Vec<TaskGraph> = (0..3)
        .map(|_| {
            let mut g = TaskGraph::new();
            let ga = gate.clone();
            g.add(move || {
                while !ga.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            });
            g
        })
        .collect();
    let handles: Vec<_> = holders.iter_mut().map(|g| g.run_async(&pool).unwrap()).collect();

    let (mut low, low_counter) = chain(8);
    let shed_before = pool.metrics().shed_runs;
    assert!(matches!(
        low.try_run_with_options(&pool, RunOptions::new().priority(RunPriority::Low)),
        Err(GraphError::Overloaded)
    ));
    assert_eq!(low_counter.load(Ordering::Relaxed), 0);
    assert_eq!(pool.metrics().shed_runs, shed_before + 1, "shed counter records the Low reject");

    // The fourth slot is reserved for Normal/High: it still runs.
    let (mut normal, normal_counter) = chain(8);
    normal.try_run(&pool).unwrap();
    assert_eq!(normal_counter.load(Ordering::Relaxed), 8);

    gate.store(true, Ordering::SeqCst);
    for h in handles {
        h.wait().unwrap();
    }
    // With the slots released, Low is admitted again.
    low.try_run_with_options(&pool, RunOptions::new().priority(RunPriority::Low)).unwrap();
    assert_eq!(low_counter.load(Ordering::Relaxed), 8);
    pool.wait_idle();
}

/// The catch_unwind coverage matrix: a panicking node must abort its
/// run with `NodePanicked` — and the pool must keep its full worker
/// complement — on flat and sharded pools, through the sync and async
/// surfaces alike.
#[test]
fn panic_quarantine_matrix_flat_and_sharded_sync_and_async() {
    let pools = [
        ("flat", ThreadPool::with_config(PoolConfig { num_threads: 4, shard_size: 64, ..PoolConfig::default() })),
        ("sharded", ThreadPool::with_config(PoolConfig { num_threads: 4, shard_size: 2, ..PoolConfig::default() })),
    ];
    for (label, pool) in pools {
        for mode in ["sync", "async"] {
            // A fan-out behind the panicking node: its successors are
            // skipped (abort semantics), so the after-counter stays 0.
            let after = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            let boom = g.add_named("boom", || panic!("quarantine me"));
            let succs: Vec<_> = (0..8)
                .map(|_| {
                    let a = after.clone();
                    g.add(move || {
                        a.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            g.precede(boom, &succs);

            let result = match mode {
                "sync" => g.run(&pool),
                _ => g.run_async(&pool).unwrap().wait(),
            };
            match result {
                Err(GraphError::NodePanicked { node, name, payload }) => {
                    assert_eq!(node, 0, "{label}/{mode}");
                    assert_eq!(name.as_deref(), Some("boom"), "{label}/{mode}");
                    assert!(payload.contains("quarantine me"), "{label}/{mode}: {payload}");
                }
                other => panic!("{label}/{mode}: expected NodePanicked, got {other:?}"),
            }
            assert_eq!(after.load(Ordering::SeqCst), 0, "{label}/{mode}: successors ran");
            pool.wait_idle();
            let m = pool.metrics();
            assert_eq!(m.alive_workers, 4, "{label}/{mode}: pool silently shrank");
            assert_eq!(m.worker_revivals, 0, "{label}/{mode}: containment regressed");

            // The pool stays fully usable.
            let (mut ok, counter) = chain(32);
            ok.run(&pool).unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), 32, "{label}/{mode}");
        }
    }
}

/// Random DAG: nodes 0..n, edges only i -> j with i < j (acyclic by
/// construction), edge probability `p` within a window of `w` — the
/// `graph_properties.rs` generator.
fn random_dag(rng: &mut Pcg32, n: usize, w: usize, p: f64) -> Vec<Vec<usize>> {
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..(i + 1 + w).min(n) {
            if rng.next_f64() < p {
                adj[i].push(j);
            }
        }
    }
    adj
}

#[test]
fn sixty_four_option_masks_with_cancellation_at_a_random_node() {
    // 6 toggle bits → 64 rows: every RunOptions combination runs a
    // random DAG in which one randomly chosen node fires a
    // CancelToken *from inside the run*. Whatever the interleaving,
    // the invariants hold: at-most-once per node, the cancelling node
    // ran, the run drains to a typed result, and the graph re-runs
    // cleanly afterwards (exactly-once, Ok).
    let pool = ThreadPool::new(3);
    let mut rng = Pcg32::seeded(0xCA_7CE1);
    for mask in 0..64u32 {
        let n = 30 + rng.next_below(50) as usize;
        let w = 1 + rng.next_below(6) as usize;
        let adj = random_dag(&mut rng, n, w, 0.35);
        let cancel_node = rng.next_below(n as u32) as usize;
        let token = CancelToken::new();

        let runs: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let mut g = TaskGraph::with_capacity(n);
        let ids: Vec<_> = (0..n)
            .map(|i| {
                let (runs, token) = (runs.clone(), token.clone());
                g.add(move || {
                    runs[i].fetch_add(1, Ordering::SeqCst);
                    if i == cancel_node {
                        token.cancel();
                    }
                })
            })
            .collect();
        for (i, succs) in adj.iter().enumerate() {
            for &s in succs {
                g.precede(ids[i], &[ids[s]]);
            }
        }

        let options = RunOptions::inline(mask & 1 == 0)
            .topology_cache(mask & 2 == 0)
            .state_reuse(mask & 4 == 0)
            .caller_assist(mask & 8 == 0)
            .critical_path(mask & 16 == 0)
            .priority_lanes(mask & 32 == 0)
            .cancel_token(token.clone());
        match g.run_with_options(&pool, options) {
            // The token may win before or after the last dispatch.
            Ok(()) | Err(GraphError::Cancelled) => {}
            other => panic!("mask {mask}: unexpected result {other:?}"),
        }
        assert_eq!(runs[cancel_node].load(Ordering::SeqCst), 1, "mask {mask}: cancel node");
        for i in 0..n {
            assert!(runs[i].load(Ordering::SeqCst) <= 1, "mask {mask}: node {i} ran twice");
        }

        // Sticky token: a re-run with it aborts immediately...
        let before: usize = (0..n).map(|i| runs[i].load(Ordering::SeqCst)).sum();
        assert!(matches!(
            g.run_with_options(&pool, RunOptions::new().cancel_token(token)),
            Err(GraphError::Cancelled)
        ));
        let after: usize = (0..n).map(|i| runs[i].load(Ordering::SeqCst)).sum();
        assert_eq!(before, after, "mask {mask}: sticky-token re-run executed nodes");
        // ...while a token-free re-run is exactly-once for every node.
        g.run(&pool).unwrap_or_else(|e| panic!("mask {mask}: clean re-run failed: {e}"));
        let total: usize = (0..n).map(|i| runs[i].load(Ordering::SeqCst)).sum();
        assert_eq!(total, before + n, "mask {mask}: clean re-run not exactly-once");
    }
    pool.wait_idle();
}

/// Chaos-feature storms: with `--features chaos` and nonzero
/// `CHAOS_PANIC_RATE` / `CHAOS_CANCEL_RATE`, the executor injects
/// random node panics and forced cancellations. These tests are
/// **injection-tolerant**: they assert liveness (no deadlock), typed
/// errors, and a healthy pool — never exact execution counts. With
/// the feature off (or rates 0) they degrade to plain soak tests.
#[cfg(feature = "chaos")]
mod chaos_storms {
    use super::*;

    #[test]
    fn chaos_storm_sync_runs_never_deadlock() {
        let pool = ThreadPool::new(4);
        let (mut g, _counter) = Dag::layered_random(6, 8, 0.4, 7).to_task_graph(0);
        for round in 0..200 {
            match g.run(&pool) {
                Ok(())
                | Err(GraphError::Cancelled)
                | Err(GraphError::NodePanicked { .. }) => {}
                other => panic!("round {round}: unexpected result {other:?}"),
            }
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.metrics().alive_workers, 4);
    }

    #[test]
    fn chaos_storm_async_fleet_stays_harvestable() {
        let pool = ThreadPool::new(4);
        let mut fleet: Vec<TaskGraph> =
            (0..8).map(|_| Dag::diamond_chain(8).to_task_graph(0).0).collect();
        for round in 0..50 {
            let handles: Vec<_> = fleet.iter_mut().map(|g| g.run_async(&pool).unwrap()).collect();
            for (i, h) in handles.into_iter().enumerate() {
                match h.wait() {
                    Ok(())
                    | Err(GraphError::Cancelled)
                    | Err(GraphError::NodePanicked { .. }) => {}
                    other => panic!("round {round} graph {i}: unexpected {other:?}"),
                }
            }
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.metrics().alive_workers, 4);
    }
}
