//! Stress tests for the pool core: deque linearizability under many
//! thieves, submission storms, park/wake churn, executor cross-checks,
//! and failure injection. These are the tests a lock-free structure
//! earns its keep with.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use scheduling::baseline::{all_executors, Executor};
use scheduling::pool::{deque, fence_deque, PoolConfig, Steal, ThreadPool};
use scheduling::util::Pcg32;
use scheduling::workloads::{fib_reference, run_fib};

/// Multi-thief exactly-once check, parameterized over both deque
/// flavors and several thief counts.
fn deque_exactly_once(thieves: usize, items: usize, fence: bool) {
    let seen: Arc<Vec<AtomicUsize>> = Arc::new((0..items).map(|_| AtomicUsize::new(0)).collect());
    let done = Arc::new(AtomicBool::new(false));
    let (popped, stolen);

    macro_rules! drive {
        ($w:expr, $s:expr) => {{
            let handles: Vec<_> = (0..thieves)
                .map(|_| {
                    let s = $s.clone();
                    let (seen, done) = (seen.clone(), done.clone());
                    std::thread::spawn(move || {
                        let mut count = 0usize;
                        loop {
                            match s.steal() {
                                Steal::Success(v) => {
                                    seen[v].fetch_add(1, Ordering::Relaxed);
                                    count += 1;
                                }
                                Steal::Empty => {
                                    if done.load(Ordering::Acquire) {
                                        break;
                                    }
                                    std::thread::yield_now();
                                }
                                Steal::Retry => std::hint::spin_loop(),
                            }
                        }
                        count
                    })
                })
                .collect();
            let mut rng = Pcg32::seeded(7);
            let mut pop_count = 0usize;
            for i in 0..items {
                $w.push(i);
                // Pop with random density to vary contention windows.
                if rng.next_below(3) == 0 {
                    if let Some(v) = $w.pop() {
                        seen[v].fetch_add(1, Ordering::Relaxed);
                        pop_count += 1;
                    }
                }
            }
            while let Some(v) = $w.pop() {
                seen[v].fetch_add(1, Ordering::Relaxed);
                pop_count += 1;
            }
            done.store(true, Ordering::Release);
            (pop_count, handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>())
        }};
    }

    if fence {
        let (w, s) = fence_deque::<usize>(4);
        (popped, stolen) = drive!(w, s);
    } else {
        let (w, s) = deque::<usize>(4);
        (popped, stolen) = drive!(w, s);
    }

    assert_eq!(popped + stolen, items, "thieves={thieves} fence={fence}");
    for (i, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} thieves={thieves} fence={fence}");
    }
}

#[test]
fn deque_exactly_once_fencefree_multi_thief() {
    for thieves in [1, 2, 4] {
        deque_exactly_once(thieves, 30_000, false);
    }
}

#[test]
fn deque_exactly_once_fence_multi_thief() {
    for thieves in [1, 2, 4] {
        deque_exactly_once(thieves, 30_000, true);
    }
}

/// Batched stealing must preserve the exactly-once guarantee: several
/// thieves drain a churning victim via `steal_batch_and_pop`, each
/// moving extras into its own deque and consuming them locally.
fn steal_batch_exactly_once(thieves: usize, items: usize, fence: bool) {
    let seen: Arc<Vec<AtomicUsize>> = Arc::new((0..items).map(|_| AtomicUsize::new(0)).collect());
    let done = Arc::new(AtomicBool::new(false));
    let (popped, stolen);

    macro_rules! drive {
        ($w:expr, $s:expr, $mk_mine:expr) => {{
            let handles: Vec<_> = (0..thieves)
                .map(|_| {
                    let s = $s.clone();
                    let (seen, done) = (seen.clone(), done.clone());
                    std::thread::spawn(move || {
                        // Each thief owns a destination deque, exactly
                        // like a pool worker.
                        let (mine, _ms) = $mk_mine;
                        let mut count = 0usize;
                        loop {
                            match s.steal_batch_and_pop(&mine) {
                                Steal::Success(v) => {
                                    seen[v].fetch_add(1, Ordering::Relaxed);
                                    count += 1;
                                    // Drain everything the batch moved.
                                    while let Some(v) = mine.pop() {
                                        seen[v].fetch_add(1, Ordering::Relaxed);
                                        count += 1;
                                    }
                                }
                                Steal::Empty => {
                                    if done.load(Ordering::Acquire) {
                                        break;
                                    }
                                    std::thread::yield_now();
                                }
                                Steal::Retry => std::hint::spin_loop(),
                            }
                        }
                        assert!(mine.is_empty());
                        count
                    })
                })
                .collect();
            let mut rng = Pcg32::seeded(13);
            let mut pop_count = 0usize;
            for i in 0..items {
                $w.push(i);
                if rng.next_below(3) == 0 {
                    if let Some(v) = $w.pop() {
                        seen[v].fetch_add(1, Ordering::Relaxed);
                        pop_count += 1;
                    }
                }
            }
            while let Some(v) = $w.pop() {
                seen[v].fetch_add(1, Ordering::Relaxed);
                pop_count += 1;
            }
            done.store(true, Ordering::Release);
            (pop_count, handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>())
        }};
    }

    if fence {
        let (w, s) = fence_deque::<usize>(4);
        (popped, stolen) = drive!(w, s, fence_deque::<usize>(8));
    } else {
        let (w, s) = deque::<usize>(4);
        (popped, stolen) = drive!(w, s, deque::<usize>(8));
    }

    assert_eq!(popped + stolen, items, "thieves={thieves} fence={fence}");
    for (i, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} thieves={thieves} fence={fence}");
    }
}

#[test]
fn steal_batch_exactly_once_fencefree_multi_thief() {
    for thieves in [1, 2, 4] {
        steal_batch_exactly_once(thieves, 30_000, false);
    }
}

#[test]
fn steal_batch_exactly_once_fence_multi_thief() {
    for thieves in [1, 2, 4] {
        steal_batch_exactly_once(thieves, 30_000, true);
    }
}

#[test]
fn deque_growth_under_contention() {
    // Start tiny (cap 2) and push 50k with thieves active: exercises
    // grow() racing steals across many retired buffers.
    let (w, s) = deque::<usize>(2);
    let total = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicBool::new(false));
    let thief = {
        let (s, total, done) = (s.clone(), total.clone(), done.clone());
        std::thread::spawn(move || loop {
            match s.steal() {
                Steal::Success(_) => {
                    total.fetch_add(1, Ordering::Relaxed);
                }
                Steal::Empty if done.load(Ordering::Acquire) => break,
                _ => {}
            }
        })
    };
    for i in 0..50_000 {
        w.push(i);
    }
    while w.pop().is_some() {
        total.fetch_add(1, Ordering::Relaxed);
    }
    done.store(true, Ordering::Release);
    thief.join().unwrap();
    assert_eq!(total.load(Ordering::Relaxed), 50_000);
}

#[test]
fn submission_storm_from_many_external_threads() {
    // 4 external producers hammer the injector while 2 workers drain.
    let pool = Arc::new(ThreadPool::new(2));
    let count = Arc::new(AtomicUsize::new(0));
    const PER: usize = 10_000;
    let producers: Vec<_> = (0..4)
        .map(|_| {
            let (pool, count) = (pool.clone(), count.clone());
            std::thread::spawn(move || {
                for _ in 0..PER {
                    let c = count.clone();
                    pool.submit(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    pool.wait_idle();
    assert_eq!(count.load(Ordering::Relaxed), 4 * PER);
}

#[test]
fn park_wake_churn() {
    // Tiny bursts separated by idle gaps: every burst must wake a
    // parked worker (missed-wakeup detector).
    let pool = ThreadPool::new(2);
    let count = Arc::new(AtomicUsize::new(0));
    for burst in 0..200 {
        let c = count.clone();
        pool.submit(move || {
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), burst + 1);
        if burst % 10 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let parks = pool.metrics().total().parks;
    assert!(parks > 0, "workers never parked — test not exercising wakeups");
}

#[test]
fn fib_agreement_across_executors_and_threads() {
    for threads in [1, 2, 4] {
        for ex in all_executors(threads) {
            if ex.name() == "spawn-per-task" {
                continue; // covered at smaller sizes elsewhere
            }
            let got = run_fib(&ex, 14);
            assert_eq!(got, fib_reference(14), "{} @ {threads}", ex.name());
        }
    }
}

#[test]
fn many_pools_in_one_process() {
    // TLS registration must not cross-talk between pool instances.
    let pools: Vec<_> = (0..4).map(|_| ThreadPool::new(1)).collect();
    let count = Arc::new(AtomicUsize::new(0));
    for p in &pools {
        for _ in 0..100 {
            let c = count.clone();
            p.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
    }
    for p in &pools {
        p.wait_idle();
    }
    assert_eq!(count.load(Ordering::Relaxed), 400);
}

#[test]
fn cross_pool_submission_goes_through_injector() {
    // A task on pool A submitting to pool B must route via B's
    // injector (the TLS check is per-pool), and both must drain.
    let a = Arc::new(ThreadPool::new(1));
    let b = Arc::new(ThreadPool::new(1));
    let count = Arc::new(AtomicUsize::new(0));
    let (b2, c2) = (b.clone(), count.clone());
    a.submit(move || {
        for _ in 0..100 {
            let c = c2.clone();
            b2.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    a.wait_idle();
    b.wait_idle();
    assert_eq!(count.load(Ordering::Relaxed), 100);
    assert!(b.metrics().total().injector_pops >= 100);
}

#[test]
fn panic_storm_leaves_pool_functional() {
    let pool = ThreadPool::new(2);
    for _ in 0..500 {
        pool.submit(|| panic!("chaos"));
    }
    pool.wait_idle();
    assert_eq!(pool.panic_count(), 500);
    let ok = Arc::new(AtomicUsize::new(0));
    for _ in 0..100 {
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
    }
    pool.wait_idle();
    assert_eq!(ok.load(Ordering::Relaxed), 100);
}

#[test]
fn recursive_fanout_storm_with_tiny_spin() {
    // spin_rounds = 0 forces maximal park/wake traffic.
    let pool = Arc::new(ThreadPool::with_config(PoolConfig {
        num_threads: 3,
        spin_rounds: 0,
        ..PoolConfig::default()
    }));
    let count = Arc::new(AtomicUsize::new(0));
    fn fanout(pool: &Arc<ThreadPool>, count: &Arc<AtomicUsize>, depth: u32) {
        count.fetch_add(1, Ordering::Relaxed);
        if depth == 0 {
            return;
        }
        for _ in 0..3 {
            let (p, c) = (pool.clone(), count.clone());
            pool.submit(move || fanout(&p, &c, depth - 1));
        }
    }
    let (p, c) = (pool.clone(), count.clone());
    pool.submit(move || fanout(&p, &c, 8));
    pool.wait_idle();
    // 3-ary tree of depth 8: (3^9 - 1) / 2 nodes.
    assert_eq!(count.load(Ordering::Relaxed), (3usize.pow(9) - 1) / 2);
}

#[test]
fn steal_ratio_sane_on_fanout_workload() {
    let pool = Arc::new(ThreadPool::new(4));
    let ex: Arc<dyn Executor> = pool.clone();
    run_fib(&ex, 18);
    let snap = pool.metrics();
    let total = snap.total();
    assert!(total.executed() > 0);
    // Every fib task was accounted for by exactly one acquisition path.
    assert_eq!(
        total.executed(),
        scheduling::workloads::fib_task_count(18),
        "acquisition counters must cover every executed task"
    );
    // Steal ratio is a ratio.
    assert!((0.0..=1.0).contains(&snap.steal_ratio()));
}

#[test]
fn many_producers_many_stealers_high_contention() {
    // 4 external producers hammer the injector while 4 workers steal
    // from each other; every task respawns a child once, so half the
    // load is produced *inside* workers where batched stealing and the
    // sharded pending counters are on the hottest path.
    let pool = Arc::new(ThreadPool::with_config(PoolConfig {
        num_threads: 4,
        spin_rounds: 1,
        ..PoolConfig::default()
    }));
    let count = Arc::new(AtomicUsize::new(0));
    const PER: usize = 5_000;
    const PRODUCERS: usize = 4;
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|_| {
            let (pool, count) = (pool.clone(), count.clone());
            std::thread::spawn(move || {
                for _ in 0..PER {
                    let (p, c) = (pool.clone(), count.clone());
                    pool.submit(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                        let c2 = c.clone();
                        p.submit(move || {
                            c2.fetch_add(1, Ordering::Relaxed);
                        });
                    });
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    pool.wait_idle();
    assert_eq!(count.load(Ordering::Relaxed), 2 * PRODUCERS * PER);

    // Accounting invariant survives batched stealing: every executed
    // task was acquired by exactly one of pop/steal/injector-pop.
    let total = pool.metrics().total();
    assert_eq!(total.executed(), (2 * PRODUCERS * PER) as u64);
    // Batch metrics are internally consistent (each batch moved >= 1).
    assert!(total.steal_batch_tasks >= total.steal_batches);
}

#[test]
fn park_wake_race_with_batched_wakeups() {
    // Tiny graph bursts separated by idle gaps with spin_rounds = 0:
    // every burst goes through submit_job_batch's single notify_all
    // against workers that are parked or mid-park — the throttled-
    // notify race window. Repeat enough times to hit interleavings.
    use scheduling::graph::TaskGraph;
    for batched in [true, false] {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 3,
            spin_rounds: 0,
            batched_wakeups: batched,
            ..PoolConfig::default()
        });
        let count = Arc::new(AtomicUsize::new(0));
        // Fan-out graph: source -> 8 successors -> sink.
        let mut g = TaskGraph::new();
        let src = g.add(|| {});
        let sink = {
            let c = count.clone();
            g.add(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
        };
        for _ in 0..8 {
            let c = count.clone();
            let mid = g.add(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
            g.succeed(mid, &[src]);
            g.succeed(sink, &[mid]);
        }
        for round in 1..=150usize {
            g.run(&pool).unwrap();
            assert_eq!(count.load(Ordering::Relaxed), 9 * round, "batched={batched}");
            if round % 25 == 0 {
                // Let every worker park so the next burst must wake
                // from a cold (committed-wait) state.
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        let parks = pool.metrics().total().parks;
        assert!(parks > 0, "batched={batched}: workers never parked — race not exercised");
    }
}

#[test]
fn submission_bursts_against_parked_workers() {
    // Plain-closure variant of the park/wake race: alternate between
    // a burst of external submissions and full quiescence.
    let pool = ThreadPool::with_config(PoolConfig {
        num_threads: 2,
        spin_rounds: 0,
        ..PoolConfig::default()
    });
    let count = Arc::new(AtomicUsize::new(0));
    for burst in 1..=200usize {
        for _ in 0..4 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 4 * burst);
    }
}

#[test]
fn every_config_variant_agrees_on_fib_and_graphs() {
    use scheduling::graph::RunOptions;
    use scheduling::workloads::Dag;

    let variants: [(&str, PoolConfig); 5] = [
        ("all-on", PoolConfig::default()),
        ("boxed-tasks", PoolConfig { inline_tasks: false, ..PoolConfig::default() }),
        ("single-steal", PoolConfig { steal_batch: false, ..PoolConfig::default() }),
        ("per-task-wake", PoolConfig { batched_wakeups: false, ..PoolConfig::default() }),
        (
            "all-off",
            PoolConfig {
                inline_tasks: false,
                steal_batch: false,
                batched_wakeups: false,
                ..PoolConfig::default()
            },
        ),
    ];
    for (name, config) in variants {
        let pool = Arc::new(ThreadPool::with_config(PoolConfig {
            num_threads: 3,
            ..config
        }));
        // Recursive fan-out closures.
        let ex: Arc<dyn Executor> = pool.clone();
        assert_eq!(run_fib(&ex, 14), fib_reference(14), "{name}");
        // Graph executor, inline continuations on and off.
        for inline in [true, false] {
            let (mut g, counter) = Dag::wavefront(12).to_task_graph(0);
            g.run_with_options(&pool, RunOptions::inline(inline)).unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), 144, "{name} inline={inline}");
        }
    }
}

#[test]
fn drop_mid_flight_never_loses_submitted_tasks() {
    for _ in 0..10 {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..1000 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Immediate drop: drain-on-shutdown must execute all 1000.
        }
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }
}
