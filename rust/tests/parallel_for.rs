//! Data-parallel tier (PR 10) — behavioural properties of
//! `parallel_for` / `parallel_reduce` on real pools:
//!
//! * **exactly-once coverage**: every index in the range is visited
//!   exactly once, for randomized range/grain/oversubscription
//!   combinations, on flat and sharded pools;
//! * **nesting**: calling the primitives from *inside* a pool task is
//!   deadlock-free (the caller claims blocks itself), down to a
//!   one-thread pool;
//! * **abort machinery**: a mid-loop cancellation surfaces
//!   `GraphError::Cancelled`, a panicking body surfaces
//!   `GraphError::NodePanicked` with the first panic's payload, and in
//!   both cases the pool keeps running later work;
//! * **graph form**: `TaskGraph::add_parallel_for` expands to a sealed
//!   fan-out/fan-in whose re-runs cover the range once per run.

use std::ops::Range;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;

use scheduling::graph::{
    parallel_for, parallel_for_with, parallel_reduce, CancelToken, GraphError, ParOptions,
    TaskGraph,
};
use scheduling::pool::{PoolConfig, ThreadPool};
use scheduling::util::Pcg32;

fn sharded_pool(num_threads: usize, shard_size: usize) -> ThreadPool {
    ThreadPool::with_config(PoolConfig {
        num_threads,
        shard_size,
        ..PoolConfig::default()
    })
}

/// Runs one coverage trial: every index in `range` must be hit exactly
/// once, whatever the split.
fn coverage_trial(pool: &ThreadPool, range: Range<usize>, opts: &ParOptions) {
    let n = range.end - range.start;
    let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let base = range.start;
    parallel_for_with(pool, range.clone(), opts, |r: Range<usize>| {
        assert!(r.start >= base && r.end <= range.end, "block {r:?} outside {range:?}");
        for i in r {
            hits[i - base].fetch_add(1, Ordering::Relaxed);
        }
    })
    .unwrap();
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(
            h.load(Ordering::Relaxed),
            1,
            "index {} covered wrong number of times (grain {}, oversub {})",
            base + i,
            opts.grain,
            opts.oversubscription
        );
    }
}

#[test]
fn exactly_once_coverage_randomized() {
    let mut rng = Pcg32::seeded(0xC0FFEE);
    let flat = ThreadPool::new(4);
    let sharded = sharded_pool(4, 2);
    for trial in 0..40 {
        let start = (rng.next_u32() % 1000) as usize;
        let len = (rng.next_u32() % 5000) as usize;
        let grain = 1 + (rng.next_u32() % 600) as usize;
        let oversub = 1 + (rng.next_u32() % 8) as usize;
        let opts = ParOptions::new().grain(grain).oversubscription(oversub);
        let pool = if trial % 2 == 0 { &flat } else { &sharded };
        coverage_trial(pool, start..start + len, &opts);
    }
}

#[test]
fn coverage_on_one_thread_pool_and_shard_pins() {
    let single = ThreadPool::new(1);
    coverage_trial(&single, 0..1000, &ParOptions::new());
    // Shard-pinned burst on a sharded pool (2 shards of 2).
    let sharded = sharded_pool(4, 2);
    for shard in 0..sharded.num_shards() {
        coverage_trial(&sharded, 0..2048, &ParOptions::new().shard(shard));
    }
}

#[test]
fn degenerate_ranges() {
    let pool = ThreadPool::new(2);
    // Empty: body never runs.
    parallel_for(&pool, 5..5, 1, |_| panic!("empty range ran a block")).unwrap();
    // Single index.
    let hits = AtomicU32::new(0);
    parallel_for(&pool, 7..8, 100, |r| {
        assert_eq!(r, 7..8);
        hits.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(hits.load(Ordering::Relaxed), 1);
    // Grain far larger than the range: one block.
    let blocks = AtomicU32::new(0);
    parallel_for(&pool, 0..10, 1_000_000, |r| {
        assert_eq!(r, 0..10);
        blocks.fetch_add(1, Ordering::Relaxed);
    })
    .unwrap();
    assert_eq!(blocks.load(Ordering::Relaxed), 1);
}

#[test]
fn nested_from_worker_does_not_deadlock() {
    // The caller of the inner loop is a pool worker; with every other
    // worker busy (or nonexistent) it must claim all blocks itself.
    for threads in [1, 2, 4] {
        let pool = Arc::new(ThreadPool::new(threads));
        let covered = Arc::new(AtomicUsize::new(0));
        let (p, c) = (pool.clone(), covered.clone());
        pool.submit(move || {
            let inner_hits = AtomicUsize::new(0);
            parallel_for(&p, 0..512, 16, |r: Range<usize>| {
                inner_hits.fetch_add(r.len(), Ordering::Relaxed);
            })
            .unwrap();
            c.store(inner_hits.load(Ordering::Relaxed), Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(covered.load(Ordering::SeqCst), 512, "threads={threads}");
    }
}

#[test]
fn parallel_reduce_matches_serial_fold() {
    let pool = ThreadPool::new(4);
    let data: Vec<u64> = (0..10_000).map(|i| (i * 7 + 3) % 101).collect();
    let expected: u64 = data.iter().sum();
    for grain in [1, 33, 1000, 100_000] {
        let sum = parallel_reduce(
            &pool,
            0..data.len(),
            grain,
            0u64,
            |r, acc| acc + data[r].iter().sum::<u64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(sum, expected, "grain {grain}");
    }
    // Max via reduce: join is commutative+associative but not addition.
    let max = parallel_reduce(
        &pool,
        0..data.len(),
        64,
        u64::MIN,
        |r, acc| data[r].iter().copied().fold(acc, u64::max),
        u64::max,
    )
    .unwrap();
    assert_eq!(max, *data.iter().max().unwrap());
}

#[test]
fn midloop_cancellation_stops_remaining_blocks() {
    let pool = ThreadPool::new(2);
    let token = CancelToken::new();
    let ran = Arc::new(AtomicUsize::new(0));
    let opts = ParOptions::new().grain(1).oversubscription(64).cancel_token(token.clone());
    let r = ran.clone();
    let t = token.clone();
    // Cancel from inside the first few blocks; many blocks (grain 1,
    // high oversubscription) guarantee plenty were still pending.
    let err = parallel_for_with(&pool, 0..100_000, &opts, move |range: Range<usize>| {
        r.fetch_add(range.len(), Ordering::Relaxed);
        t.cancel();
    })
    .unwrap_err();
    assert!(matches!(err, GraphError::Cancelled));
    assert!(
        ran.load(Ordering::Relaxed) < 100_000,
        "cancellation should have skipped some blocks"
    );
    // The pool is not poisoned: a fresh loop runs fine.
    parallel_for(&pool, 0..1000, 10, |_| {}).unwrap();
}

#[test]
fn panic_quarantines_with_first_payload() {
    let pool = ThreadPool::new(4);
    let err = parallel_for(&pool, 0..1000, 10, |r: Range<usize>| {
        if r.start == 0 {
            panic!("block zero exploded");
        }
    })
    .unwrap_err();
    match err {
        GraphError::NodePanicked { payload, .. } => {
            assert!(payload.contains("exploded"), "payload: {payload}");
        }
        other => panic!("expected NodePanicked, got {other:?}"),
    }
    // Workers survive body panics; both primitives still work.
    let sum = parallel_reduce(&pool, 0..100, 1, 0usize, |r, acc| acc + r.len(), |a, b| a + b)
        .unwrap();
    assert_eq!(sum, 100);
}

#[test]
fn graph_parallel_for_reruns_cover_range_each_time() {
    let pool = ThreadPool::new(4);
    let n = 10_007; // prime: exercises ragged final blocks
    let hits: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
    let order = Arc::new(AtomicUsize::new(0));

    let mut g = TaskGraph::new();
    let oo = order.clone();
    let before = g.add_named("before", move || {
        // Runs strictly before every block of the loop.
        oo.store(1, Ordering::SeqCst);
    });
    let h = hits.clone();
    let o = order.clone();
    let (start, join) = g.add_parallel_for("sweep", 0..n, 32, move |r: Range<usize>| {
        assert_eq!(o.load(Ordering::SeqCst), 1, "block ran before its predecessor");
        for i in r {
            h[i].fetch_add(1, Ordering::Relaxed);
        }
    });
    let o2 = order.clone();
    let after = g.add_named("after", move || o2.store(2, Ordering::SeqCst));
    g.precede(before, &[start]);
    g.succeed(after, &[join]);
    g.seal().unwrap();

    // Block nodes are individually named with their index and span
    // (the PR 9 profile/trace surfaces render these labels).
    assert_eq!(g.name(start), Some("sweep/start"));
    assert_eq!(g.name(join), Some("sweep/join"));
    let dot = g.to_dot();
    assert!(dot.contains("sweep/b0[0.."), "block labels missing from graph: {dot}");

    for pass in 1..=3u32 {
        order.store(0, Ordering::SeqCst);
        g.run(&pool).unwrap();
        assert_eq!(order.load(Ordering::SeqCst), 2, "join must precede the after-node");
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), pass, "index {i} on pass {pass}");
        }
    }
}

#[test]
fn graph_parallel_for_empty_range_still_orders() {
    let pool = ThreadPool::new(2);
    let mut g = TaskGraph::new();
    let ran = Arc::new(AtomicUsize::new(0));
    let (start, join) = g.add_parallel_for("empty", 3..3, 4, |_| panic!("no blocks expected"));
    let r = ran.clone();
    let tail = g.add(move || {
        r.store(1, Ordering::SeqCst);
    });
    g.succeed(tail, &[join]);
    let _ = start;
    g.run(&pool).unwrap();
    assert_eq!(ran.load(Ordering::SeqCst), 1);
}
