//! Loom model checks for the two unsafe arguments the graph executor
//! rests on (PR 3 satellite; the arguments themselves shipped with
//! PR 2 "on paper only"):
//!
//! 1. the **`RunHeader` rewrite / quiescence protocol** — every header
//!    read a task performs happens-before the next run's header
//!    rewrite, through the `AcqRel` remaining-counter decrements and
//!    the SeqCst monotone `completed` store;
//! 2. the **completion → waker / eventcount handshake** — the
//!    store-buffering pairs (`completed` store vs waker-flag /
//!    waiter-count loads, both SeqCst) lose no wakeup;
//! 3. the **priority-lane push/steal protocol** (PR 4) — a task pushed
//!    into any injector lane (per-lane emptiness flag, Release store)
//!    is never lost by a consumer scanning the lanes and parking on
//!    the eventcount;
//! 4. the **two-level sweep / sharded park protocol** (PR 5) — with
//!    one injector and one eventcount *per shard*, a task pushed into
//!    a remote shard is never lost by a worker that re-checks all
//!    shards and parks on its home shard's eventcount, against a
//!    producer that scans waiter counts and wakes the first shard
//!    with a sleeper;
//! 5. the **batched-steal claim protocol** (PR 1 deque, modeled here
//!    per the ROADMAP's "deques under loom" item) — the hand-rolled
//!    Chase–Lev top/bottom index protocol delivers every element
//!    exactly once when a `steal_batch_and_pop` loop races the
//!    owner's LIFO pops;
//! 6. the **grow/retire (buffer reclamation) protocol** (PR 6,
//!    closing ROADMAP loom debt (2)) — an owner push that outgrows
//!    the buffer copies into a double-size buffer, publishes it with
//!    a Release store, and *retires* (does not free) the old one;
//!    a thief that read the stale buffer pointer still delivers its
//!    element exactly once, because the copy preserved `[top, bottom)`
//!    and the SeqCst CAS on `top` validates the claim;
//! 7. the **cancel-flag vs. completion-handshake race** (PR 6) — the
//!    per-run abort cause raced against the dispatch-boundary check
//!    and the final `remaining` decrement: the run always drains to
//!    `completed = gen` exactly once, skipped nodes imply the cause
//!    was set, and a cancel that observed completion (the
//!    `RunHandle::cancel` guard) never aborts anything.
//!
//! These are *models*: each test re-states the protocol in miniature
//! with loom types (the production code uses `std` atomics and real
//! OS parking, which loom cannot instrument), mirroring the exact
//! fields, orderings, and program order of `graph/executor.rs` and
//! `pool/event_count.rs`. Loom then exhausts the interleavings: the
//! `UnsafeCell` access tracking fails the first model if any schedule
//! lets a task's header read overlap the rewrite, and the asserts /
//! deadlock detection fail the second if a wakeup can be lost.
//!
//! This file is compiled only with `RUSTFLAGS="--cfg loom"` and the
//! `loom` dev-dependency added (the CI `loom` job does both; the
//! offline build sees an empty test binary).

#![cfg(loom)]

use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// Model 1: the RunHeader rewrite/quiescence protocol.
///
/// Mirrors executor.rs: tasks read the header (`UnsafeCell`), the
/// final `remaining` decrement (AcqRel) stores `completed = gen`
/// (SeqCst) and notifies; the launcher waits for `completed >= gen`
/// under the condvar (the `wait_sync` path — the eventcount path is
/// model 3) and only then rewrites the header for the next run. Loom's
/// UnsafeCell fails the test if any interleaving lets a task's read
/// overlap the rewrite.
#[test]
fn header_rewrite_waits_for_task_quiescence() {
    loom::model(|| {
        struct State {
            header: UnsafeCell<u64>,
            remaining: AtomicUsize,
            completed: AtomicU64,
            sync_waiters: AtomicUsize,
            done_mutex: Mutex<()>,
            done_cv: Condvar,
        }
        let st = Arc::new(State {
            header: UnsafeCell::new(1),
            remaining: AtomicUsize::new(2),
            completed: AtomicU64::new(0),
            sync_waiters: AtomicUsize::new(0),
            done_mutex: Mutex::new(()),
            done_cv: Condvar::new(),
        });

        // Two tasks of run 1 (generation 1).
        let tasks: Vec<_> = (0..2)
            .map(|_| {
                let st = st.clone();
                thread::spawn(move || {
                    // The task's header read, as in execute_node.
                    st.header.with(|p| assert_eq!(unsafe { *p }, 1));
                    if st.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        // finish(): completed store, then flag-gated
                        // condvar notify — exact order of executor.rs.
                        st.completed.store(1, Ordering::SeqCst);
                        if st.sync_waiters.load(Ordering::SeqCst) != 0 {
                            drop(st.done_mutex.lock().unwrap());
                            st.done_cv.notify_all();
                        }
                    }
                })
            })
            .collect();

        // The launcher's wait_sync(1), verbatim.
        st.sync_waiters.fetch_add(1, Ordering::SeqCst);
        {
            let mut guard = st.done_mutex.lock().unwrap();
            while st.completed.load(Ordering::SeqCst) < 1 {
                guard = st.done_cv.wait(guard).unwrap();
            }
        }
        st.sync_waiters.fetch_sub(1, Ordering::SeqCst);

        // Quiescent: re-arm the header for run 2. Any schedule in
        // which a task could still read it is a loom failure.
        st.header.with_mut(|p| unsafe { *p = 2 });
        st.remaining.store(1, Ordering::Relaxed);

        for t in tasks {
            t.join().unwrap();
        }
    });
}

/// Model 2: the completion → waker handshake (Future path).
///
/// Poller: publish waker, `has_waker.store(true, SeqCst)`, then
/// re-check `completed` (SeqCst). Completer: `completed.store(SeqCst)`,
/// then check `has_waker` (SeqCst). Store-buffering: at least one side
/// must observe the other, so either the poll returns ready or the
/// waker fires — never neither.
#[test]
fn done_flag_waker_handshake_loses_no_wakeup() {
    loom::model(|| {
        struct State {
            completed: AtomicU64,
            has_waker: AtomicBool,
            waker: Mutex<Option<u32>>, // stand-in for the Waker
            woken: AtomicBool,
        }
        let st = Arc::new(State {
            completed: AtomicU64::new(0),
            has_waker: AtomicBool::new(false),
            waker: Mutex::new(None),
            woken: AtomicBool::new(false),
        });

        // Completer (the finishing task).
        let completer = {
            let st = st.clone();
            thread::spawn(move || {
                st.completed.store(1, Ordering::SeqCst);
                if st.has_waker.load(Ordering::SeqCst) {
                    let waker = st.waker.lock().unwrap().take();
                    st.has_waker.store(false, Ordering::SeqCst);
                    if waker.is_some() {
                        st.woken.store(true, Ordering::SeqCst);
                    }
                }
            })
        };

        // Poller (RunHandle::poll): register, then re-check.
        *st.waker.lock().unwrap() = Some(7);
        st.has_waker.store(true, Ordering::SeqCst);
        let observed_done = st.completed.load(Ordering::SeqCst) >= 1;

        completer.join().unwrap();
        assert!(
            observed_done || st.woken.load(Ordering::SeqCst),
            "pending future with no wakeup: the task would sleep forever"
        );
    });
}

/// Model 4: the priority-lane push/steal protocol (PR 4).
///
/// A miniature of `pool/injector.rs`'s `LaneInjector<MutexInjector>`
/// (two lanes, each a mutex'd slot plus a `maybe_nonempty` flag with
/// the exact Release/Acquire orderings of `MutexInjector`) combined
/// with the worker park protocol of `thread_pool.rs`: the consumer
/// scans all lanes, prepares a wait, re-checks (`any_work`, i.e. the
/// lane flags), and only then commits the park. The producer pushes
/// into the *low* lane — the one a priority-ordered scan reaches last —
/// and then notifies. Loom exhausts the interleavings: if the flag
/// protocol or the prepare/re-check ordering could let the push slip
/// between scan and park, the consumer would sleep with a task queued
/// and deadlock detection fails the test.
#[test]
fn priority_lane_push_is_never_lost_by_a_parking_consumer() {
    loom::model(|| {
        struct Lane {
            queue: Mutex<Option<u32>>,
            maybe_nonempty: AtomicBool,
        }
        impl Lane {
            fn push(&self, v: u32) {
                let mut q = self.queue.lock().unwrap();
                *q = Some(v);
                // MutexInjector::push: flag store under the lock,
                // Release.
                self.maybe_nonempty.store(true, Ordering::Release);
            }
            fn pop(&self) -> Option<u32> {
                // MutexInjector::pop: flag fast path (Acquire), then
                // the lock.
                if !self.maybe_nonempty.load(Ordering::Acquire) {
                    return None;
                }
                let mut q = self.queue.lock().unwrap();
                let v = q.take();
                if q.is_none() {
                    self.maybe_nonempty.store(false, Ordering::Release);
                }
                v
            }
            fn is_empty(&self) -> bool {
                !self.maybe_nonempty.load(Ordering::Acquire)
            }
        }
        struct Ec {
            epoch: AtomicU64,
            waiters: AtomicUsize,
            mutex: Mutex<()>,
            cv: Condvar,
        }
        impl Ec {
            fn prepare_wait(&self) -> u64 {
                self.waiters.fetch_add(1, Ordering::SeqCst);
                self.epoch.load(Ordering::SeqCst)
            }
            fn cancel_wait(&self) {
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn commit_wait(&self, epoch: u64) {
                let mut guard = self.mutex.lock().unwrap();
                while self.epoch.load(Ordering::SeqCst) == epoch {
                    guard = self.cv.wait(guard).unwrap();
                }
                drop(guard);
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn notify_all(&self) {
                if self.waiters.load(Ordering::SeqCst) == 0 {
                    return;
                }
                self.epoch.fetch_add(1, Ordering::SeqCst);
                drop(self.mutex.lock().unwrap());
                self.cv.notify_all();
            }
        }
        struct State {
            lanes: [Lane; 2],
            ec: Ec,
        }
        let mk_lane = || Lane {
            queue: Mutex::new(None),
            maybe_nonempty: AtomicBool::new(false),
        };
        let st = Arc::new(State {
            lanes: [mk_lane(), mk_lane()],
            ec: Ec {
                epoch: AtomicU64::new(0),
                waiters: AtomicUsize::new(0),
                mutex: Mutex::new(()),
                cv: Condvar::new(),
            },
        });

        // Producer: push into the LOW lane (scanned last), then wake —
        // submit_job_to's order (push before notify).
        let producer = {
            let st = st.clone();
            thread::spawn(move || {
                st.lanes[1].push(7);
                st.ec.notify_all();
            })
        };

        // Consumer: the worker loop in miniature — scan, prepare,
        // re-check the lane flags, commit; repeat until the task is
        // taken. The model must be live without any timeout backstop.
        let scan = |st: &State| st.lanes.iter().find_map(|l| l.pop());
        let mut got = None;
        while got.is_none() {
            if let Some(v) = scan(&st) {
                got = Some(v);
                break;
            }
            let epoch = st.ec.prepare_wait();
            // any_work() re-check before parking.
            if !st.lanes.iter().all(|l| l.is_empty()) {
                st.ec.cancel_wait();
                continue;
            }
            if let Some(v) = scan(&st) {
                st.ec.cancel_wait();
                got = Some(v);
                break;
            }
            st.ec.commit_wait(epoch);
        }
        assert_eq!(got, Some(7), "the pushed task must be consumed");

        producer.join().unwrap();
    });
}

/// Model 5: the two-level sweep / sharded park protocol (PR 5).
///
/// Two shards, each a miniature of `thread_pool.rs`'s `ShardState`:
/// one injector lane (`MutexInjector`'s flag protocol, as in model 4)
/// plus one eventcount (all SeqCst, as in `event_count.rs`). The
/// producer is `submit_job_to`'s cross-thread path in miniature: push
/// into the REMOTE shard's lane, then `notify_shard` — scan the waiter
/// counts starting at the target shard and `notify_one` the first
/// eventcount with a registered sleeper (no-op if none). The consumer
/// is a worker of shard 0: sweep home lane then remote lane (the
/// two-level sweep), `prepare_wait` on the HOME eventcount, re-check
/// **both** shards (`any_work`), and only then commit — with no
/// timeout backstop, so a lost wakeup deadlocks the model and fails
/// the test. This is the cross-eventcount extension of model 3's
/// two-sided argument: either the producer's SeqCst waiter-count scan
/// observes the consumer's registration (and pokes that eventcount),
/// or the consumer's registration came later in the SeqCst order and
/// its all-shards re-check observes the push.
#[test]
fn sharded_push_is_never_lost_by_home_shard_parker() {
    loom::model(|| {
        struct Lane {
            queue: Mutex<Option<u32>>,
            maybe_nonempty: AtomicBool,
        }
        impl Lane {
            fn push(&self, v: u32) {
                let mut q = self.queue.lock().unwrap();
                *q = Some(v);
                self.maybe_nonempty.store(true, Ordering::Release);
            }
            fn pop(&self) -> Option<u32> {
                if !self.maybe_nonempty.load(Ordering::Acquire) {
                    return None;
                }
                let mut q = self.queue.lock().unwrap();
                let v = q.take();
                if q.is_none() {
                    self.maybe_nonempty.store(false, Ordering::Release);
                }
                v
            }
            fn is_empty(&self) -> bool {
                !self.maybe_nonempty.load(Ordering::Acquire)
            }
        }
        struct Ec {
            epoch: AtomicU64,
            waiters: AtomicUsize,
            mutex: Mutex<()>,
            cv: Condvar,
        }
        impl Ec {
            fn new() -> Self {
                Ec {
                    epoch: AtomicU64::new(0),
                    waiters: AtomicUsize::new(0),
                    mutex: Mutex::new(()),
                    cv: Condvar::new(),
                }
            }
            fn prepare_wait(&self) -> u64 {
                self.waiters.fetch_add(1, Ordering::SeqCst);
                self.epoch.load(Ordering::SeqCst)
            }
            fn cancel_wait(&self) {
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn commit_wait(&self, epoch: u64) {
                let mut guard = self.mutex.lock().unwrap();
                while self.epoch.load(Ordering::SeqCst) == epoch {
                    guard = self.cv.wait(guard).unwrap();
                }
                drop(guard);
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn notify_one(&self) {
                if self.waiters.load(Ordering::SeqCst) == 0 {
                    return;
                }
                self.epoch.fetch_add(1, Ordering::SeqCst);
                drop(self.mutex.lock().unwrap());
                self.cv.notify_one();
            }
        }
        struct Shard {
            lane: Lane,
            ec: Ec,
        }
        let mk_shard = || Shard {
            lane: Lane {
                queue: Mutex::new(None),
                maybe_nonempty: AtomicBool::new(false),
            },
            ec: Ec::new(),
        };
        let st = Arc::new([mk_shard(), mk_shard()]);

        // Producer: push into shard 1 (remote for the consumer), then
        // notify_shard(1) — waiter-count scan from the target shard.
        let producer = {
            let st = st.clone();
            thread::spawn(move || {
                st[1].lane.push(7);
                for k in 0..2 {
                    let s = (1 + k) % 2;
                    if st[s].ec.waiters.load(Ordering::SeqCst) > 0 {
                        st[s].ec.notify_one();
                        break;
                    }
                }
            })
        };

        // Consumer: worker of shard 0 — two-level sweep, park on the
        // home eventcount after re-checking ALL shards.
        let sweep = |st: &[Shard; 2]| st[0].lane.pop().or_else(|| st[1].lane.pop());
        let mut got = None;
        while got.is_none() {
            if let Some(v) = sweep(&st) {
                got = Some(v);
                break;
            }
            let epoch = st[0].ec.prepare_wait();
            // any_work(): every shard's queues, not just home.
            if !st[0].lane.is_empty() || !st[1].lane.is_empty() {
                st[0].ec.cancel_wait();
                continue;
            }
            st[0].ec.commit_wait(epoch);
        }
        assert_eq!(got, Some(7), "the remote-shard push must be consumed");

        producer.join().unwrap();
    });
}

/// Model 6: the batched-steal claim protocol on the hand-rolled deque
/// (PR 5 satellite; ROADMAP's "the deques under loom").
///
/// A miniature of `pool/deque.rs` with the exact index protocol and
/// memory orders of the production code — `top`/`bottom` `AtomicI64`,
/// owner `pop` reserving `bottom - 1` with a SeqCst `fetch_sub`
/// (the fence-free store-load trick) and racing thieves with a CAS on
/// `top` for the last element; thief `steal` validating a speculative
/// slot read with a SeqCst CAS on `top`; and
/// `steal_batch_and_pop_counted`'s loop of single steals sized from a
/// pre-steal snapshot. Slots are atomics rather than raw memory (the
/// claim protocol, not the buffer reclamation, is what the batch loop
/// composes — and what this model checks): the assertion is
/// exactly-once delivery of every element across owner pops and the
/// thief's batch, under every interleaving.
#[test]
fn steal_batch_and_pop_claims_each_element_exactly_once() {
    loom::model(|| {
        const CAP: usize = 4; // power of two ≥ N
        const N: i64 = 3;
        struct Deque {
            top: AtomicI64,
            bottom: AtomicI64,
            slots: [AtomicU64; CAP],
        }
        impl Deque {
            fn new() -> Self {
                Deque {
                    top: AtomicI64::new(0),
                    bottom: AtomicI64::new(0),
                    slots: [
                        AtomicU64::new(0),
                        AtomicU64::new(0),
                        AtomicU64::new(0),
                        AtomicU64::new(0),
                    ],
                }
            }
            // Worker::push (no grow: CAP > N).
            fn push(&self, b: i64, v: u64) {
                self.slots[b as usize & (CAP - 1)].store(v, Ordering::Relaxed);
                self.bottom.store(b + 1, Ordering::Release);
            }
            // Worker::pop, owner-only (`b` = cached bottom).
            fn pop(&self, bottom_cache: &mut i64) -> Option<u64> {
                let b = *bottom_cache;
                let t_approx = self.top.load(Ordering::Relaxed);
                if t_approx >= b {
                    return None;
                }
                let b = self.bottom.fetch_sub(1, Ordering::SeqCst) - 1;
                *bottom_cache = b;
                let t = self.top.load(Ordering::SeqCst);
                let result = if t < b {
                    Some(self.slots[b as usize & (CAP - 1)].load(Ordering::Relaxed))
                } else if t == b {
                    let value = self.slots[b as usize & (CAP - 1)].load(Ordering::Relaxed);
                    if self
                        .top
                        .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                        .is_ok()
                    {
                        Some(value)
                    } else {
                        None
                    }
                } else {
                    None
                };
                self.bottom.store(b + 1, Ordering::SeqCst);
                *bottom_cache = b + 1;
                result
            }
            // Stealer::steal.
            fn steal(&self) -> Result<Option<u64>, ()> {
                let t = self.top.load(Ordering::SeqCst);
                let b = self.bottom.load(Ordering::SeqCst);
                if t >= b {
                    return Ok(None); // Empty
                }
                let value = self.slots[t as usize & (CAP - 1)].load(Ordering::Acquire);
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok()
                {
                    Ok(Some(value))
                } else {
                    Err(()) // Retry
                }
            }
        }

        let dq = Arc::new(Deque::new());
        // Owner pre-fills N elements (values 1..=N; 0 = empty slot).
        {
            let mut b = 0i64;
            for v in 1..=N {
                dq.push(b, v as u64);
                b += 1;
            }
        }

        // Thief: steal_batch_and_pop_counted in miniature — size the
        // batch from a pre-steal snapshot, first steal returns for
        // execution, the loop moves up to `want` extras; Empty or a
        // lost race ends the batch (the production early-outs).
        let thief = {
            let dq = dq.clone();
            thread::spawn(move || {
                let t = dq.top.load(Ordering::SeqCst);
                let b = dq.bottom.load(Ordering::SeqCst);
                let available = b - t;
                if available <= 0 {
                    return Vec::new();
                }
                let mut taken = Vec::new();
                match dq.steal() {
                    Ok(Some(v)) => taken.push(v),
                    _ => return taken,
                }
                let want = ((available as usize + 1) / 2).saturating_sub(1);
                while taken.len() - 1 < want {
                    match dq.steal() {
                        Ok(Some(v)) => taken.push(v),
                        _ => break,
                    }
                }
                taken
            })
        };

        // Owner: LIFO pops until its side observes empty.
        let mut popped = Vec::new();
        let mut bottom_cache = N;
        loop {
            match dq.pop(&mut bottom_cache) {
                Some(v) => popped.push(v),
                None => {
                    // Production pop returns None for both "lost the
                    // last-element race" and "empty"; the owner's loop
                    // re-checks via the cached bottom. Model the
                    // terminal empty check directly.
                    if dq.top.load(Ordering::SeqCst) >= bottom_cache {
                        break;
                    }
                }
            }
        }

        let stolen = thief.join().unwrap();
        let mut all: Vec<u64> = popped.into_iter().chain(stolen).collect();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3], "every element exactly once");
    });
}

/// Model 3: the completion → eventcount handshake (wait_run path, and
/// the same protocol workers/assist helpers use).
///
/// A miniature of `pool/event_count.rs` (epoch + waiter count + mutex
/// + condvar, all SeqCst) driven by wait_run's loop: check done,
/// prepare_wait, re-check done, commit. The producer stores `done`
/// then calls notify_all. If the producer reads `waiters == 0`, the
/// sleeper's registration came later in the SeqCst total order, so its
/// re-check observes `done`; otherwise the epoch bump + mutex
/// serialization delivers the notification. Loom's deadlock detection
/// fails the test if any schedule strands the waiter.
#[test]
fn done_flag_eventcount_handshake_loses_no_wakeup() {
    loom::model(|| {
        struct Ec {
            epoch: AtomicU64,
            waiters: AtomicUsize,
            mutex: Mutex<()>,
            cv: Condvar,
        }
        impl Ec {
            fn prepare_wait(&self) -> u64 {
                self.waiters.fetch_add(1, Ordering::SeqCst);
                self.epoch.load(Ordering::SeqCst)
            }
            fn cancel_wait(&self) {
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn commit_wait(&self, epoch: u64) {
                let mut guard = self.mutex.lock().unwrap();
                while self.epoch.load(Ordering::SeqCst) == epoch {
                    guard = self.cv.wait(guard).unwrap();
                }
                drop(guard);
                self.waiters.fetch_sub(1, Ordering::SeqCst);
            }
            fn notify_all(&self) {
                if self.waiters.load(Ordering::SeqCst) == 0 {
                    return;
                }
                self.epoch.fetch_add(1, Ordering::SeqCst);
                drop(self.mutex.lock().unwrap());
                self.cv.notify_all();
            }
        }
        struct State {
            done: AtomicU64,
            ec: Ec,
        }
        let st = Arc::new(State {
            done: AtomicU64::new(0),
            ec: Ec {
                epoch: AtomicU64::new(0),
                waiters: AtomicUsize::new(0),
                mutex: Mutex::new(()),
                cv: Condvar::new(),
            },
        });

        // Producer: the run's final task.
        let producer = {
            let st = st.clone();
            thread::spawn(move || {
                st.done.store(1, Ordering::SeqCst);
                st.ec.notify_all();
            })
        };

        // Consumer: one iteration of wait_run's park loop (without the
        // timer backstop — the model must be live without it).
        if st.done.load(Ordering::SeqCst) < 1 {
            let epoch = st.ec.prepare_wait();
            if st.done.load(Ordering::SeqCst) >= 1 {
                st.ec.cancel_wait();
            } else {
                st.ec.commit_wait(epoch);
            }
        }
        assert_eq!(st.done.load(Ordering::SeqCst), 1);

        producer.join().unwrap();
    });
}

/// Model 7: the deque's grow/retire (buffer reclamation) path (PR 6;
/// ROADMAP loom debt (2) — "model the grow path, not just the
/// fixed-capacity miniature").
///
/// A miniature of `pool/deque.rs`'s `Worker::push` grow branch with
/// the production orders: the owner, finding `bottom - top >= cap`,
/// copies `[top, bottom)` into a double-size buffer (plain per-slot
/// copies — the new buffer is still private), publishes it with a
/// **Release** store of the buffer pointer (here: a buffer index),
/// pushes the old buffer onto the `retired` list (it is NOT freed
/// until `Drop` — that is the whole reclamation scheme), and only
/// then stores the new element and bumps `bottom`. The thief runs the
/// production order `top SeqCst → bottom SeqCst → buffer Acquire →
/// speculative slot read → CAS top SeqCst`.
///
/// The race this exhausts: a thief that loaded the buffer pointer
/// *before* the grow reads its slot from the retired buffer while the
/// owner concurrently publishes (and pushes into) the new one. The
/// claim is exactly-once delivery regardless: the copy preserved every
/// unstolen index, the retired buffer still holds valid contents for
/// indices below the old capacity, and the CAS on `top` arbitrates
/// which reader keeps the element. A freed-too-early buffer has no
/// loom equivalent (no raw memory here) — what the model pins down is
/// that *correctness never requires the old buffer to be gone*, i.e.
/// readers of the stale pointer are benign, which is exactly the
/// property that makes retire-until-drop a sound reclamation policy.
#[test]
fn deque_grow_retires_old_buffer_and_loses_no_element() {
    loom::model(|| {
        const CAPS: [usize; 2] = [2, 4]; // buffer 0 grows into buffer 1
        struct Deque {
            top: AtomicI64,
            bottom: AtomicI64,
            /// Index into `bufs` — the production `buffer` pointer.
            buf: AtomicUsize,
            bufs: [[AtomicU64; 4]; 2],
            /// Retired buffer indices (production: `Mutex<Vec<Box<..>>>`
            /// freed only in Drop).
            retired: Mutex<Vec<usize>>,
        }
        impl Deque {
            // Worker::push, including the grow branch.
            fn push(&self, v: u64) {
                let b = self.bottom.load(Ordering::Relaxed); // owner-private
                let t = self.top.load(Ordering::Acquire);
                let mut bi = self.buf.load(Ordering::Relaxed); // owner owns it
                if (b - t) as usize >= CAPS[bi] {
                    // Grow: copy [top, bottom) into the bigger buffer,
                    // publish Release, retire the old buffer.
                    let ni = bi + 1;
                    for i in t..b {
                        let val = self.bufs[bi][i as usize & (CAPS[bi] - 1)].load(Ordering::Relaxed);
                        self.bufs[ni][i as usize & (CAPS[ni] - 1)].store(val, Ordering::Relaxed);
                    }
                    self.buf.store(ni, Ordering::Release);
                    self.retired.lock().unwrap().push(bi);
                    bi = ni;
                }
                self.bufs[bi][b as usize & (CAPS[bi] - 1)].store(v, Ordering::Relaxed);
                self.bottom.store(b + 1, Ordering::Release);
            }
            // Worker::pop (owner). Reads through the current buffer.
            fn pop(&self) -> Option<u64> {
                let b = self.bottom.load(Ordering::Relaxed);
                let t_approx = self.top.load(Ordering::Relaxed);
                if t_approx >= b {
                    return None;
                }
                let b = self.bottom.fetch_sub(1, Ordering::SeqCst) - 1;
                let t = self.top.load(Ordering::SeqCst);
                let bi = self.buf.load(Ordering::Relaxed);
                let result = if t < b {
                    Some(self.bufs[bi][b as usize & (CAPS[bi] - 1)].load(Ordering::Relaxed))
                } else if t == b {
                    let value = self.bufs[bi][b as usize & (CAPS[bi] - 1)].load(Ordering::Relaxed);
                    if self
                        .top
                        .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                        .is_ok()
                    {
                        Some(value)
                    } else {
                        None
                    }
                } else {
                    None
                };
                self.bottom.store(b + 1, Ordering::SeqCst);
                result
            }
            // Stealer::steal — the production order, including the
            // Acquire buffer load *after* the index loads.
            fn steal(&self) -> Result<Option<u64>, ()> {
                let t = self.top.load(Ordering::SeqCst);
                let b = self.bottom.load(Ordering::SeqCst);
                if t >= b {
                    return Ok(None);
                }
                let bi = self.buf.load(Ordering::Acquire);
                let value = self.bufs[bi][t as usize & (CAPS[bi] - 1)].load(Ordering::Relaxed);
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok()
                {
                    Ok(Some(value))
                } else {
                    Err(())
                }
            }
        }

        let mk_buf = || {
            [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ]
        };
        let dq = Arc::new(Deque {
            top: AtomicI64::new(0),
            bottom: AtomicI64::new(0),
            buf: AtomicUsize::new(0),
            bufs: [mk_buf(), mk_buf()],
            retired: Mutex::new(Vec::new()),
        });
        // Pre-fill to capacity: the next push must grow.
        dq.push(1);
        dq.push(2);

        // Thief: steal until it has one element or sees Empty twice
        // (retries re-loop — they mean the other side made progress).
        let thief = {
            let dq = dq.clone();
            thread::spawn(move || {
                let mut empties = 0;
                loop {
                    match dq.steal() {
                        Ok(Some(v)) => return Some(v),
                        Ok(None) => {
                            empties += 1;
                            if empties == 2 {
                                return None;
                            }
                        }
                        Err(()) => {}
                    }
                }
            })
        };

        // Owner: the growing push, racing the thief, then drain.
        dq.push(3);
        let mut popped = Vec::new();
        loop {
            match dq.pop() {
                Some(v) => popped.push(v),
                None => {
                    if dq.top.load(Ordering::SeqCst) >= dq.bottom.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        }

        // The grow always happened (cap 2, third push) and the old
        // buffer was retired, not reused.
        assert_eq!(*dq.retired.lock().unwrap(), vec![0], "old buffer retired exactly once");
        let mut all: Vec<u64> = popped;
        all.extend(thief.join().unwrap());
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3], "every element exactly once across the grow");
    });
}

/// Model 8: the cancel-flag vs. completion-handshake race (PR 6).
///
/// Mirrors executor.rs: each task loads the per-run abort cause
/// (SeqCst) at its dispatch boundary and runs its closure only when
/// the cause is unset; the *last* `remaining` decrement (AcqRel)
/// publishes `completed = gen` (SeqCst) whether or not the run was
/// aborted. The canceller is `RunHandle::cancel` verbatim: guard on
/// `is_complete` (SeqCst load of `completed`), then a first-wins CAS
/// on the cause. Loom exhausts the schedules; the assertions pin the
/// three lifecycle invariants:
///
/// * the run **always drains** — `completed` reaches the generation
///   exactly once, cancelled or not (quiescence/generation exactness);
/// * a skipped node implies the cause was set (skips never happen
///   spontaneously), and every node runs at most once;
/// * a cancel whose guard observed completion aborts nothing — the
///   cause stays unset and every node ran (cancel-after-done is a
///   no-op, so a harvested `Ok` can never coexist with a skip).
#[test]
fn cancel_flag_vs_completion_handshake_keeps_quiescence_exact() {
    loom::model(|| {
        struct State {
            cancelled: AtomicU64, // CAUSE_NONE = 0, CAUSE_CANCEL = 1
            remaining: AtomicUsize,
            completed: AtomicU64,
            executed: [AtomicUsize; 2],
        }
        let st = Arc::new(State {
            cancelled: AtomicU64::new(0),
            remaining: AtomicUsize::new(2),
            completed: AtomicU64::new(0),
            executed: [AtomicUsize::new(0), AtomicUsize::new(0)],
        });

        // Two workers, one task each — execute_node's dispatch check
        // followed by the remaining-counter cascade.
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let st = st.clone();
                thread::spawn(move || {
                    let aborted = st.cancelled.load(Ordering::SeqCst) != 0;
                    if !aborted {
                        st.executed[i].fetch_add(1, Ordering::Relaxed);
                    }
                    // Skipped or not, the task flows through the same
                    // decrement — that is what keeps quiescence exact.
                    if st.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        st.completed.store(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();

        // Canceller: RunHandle::cancel — is_complete guard, then the
        // first-wins CAS (RunState::abort).
        let canceller = {
            let st = st.clone();
            thread::spawn(move || {
                let saw_done = st.completed.load(Ordering::SeqCst) >= 1;
                if !saw_done {
                    let _ = st.cancelled.compare_exchange(
                        0,
                        1,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                }
                saw_done
            })
        };

        for w in workers {
            w.join().unwrap();
        }
        let saw_done = canceller.join().unwrap();

        // Invariant 1: the run drained exactly — cancelled or not.
        assert_eq!(st.completed.load(Ordering::SeqCst), 1, "run must reach completion");
        assert_eq!(st.remaining.load(Ordering::SeqCst), 0);

        let cause = st.cancelled.load(Ordering::SeqCst);
        for i in 0..2 {
            let runs = st.executed[i].load(Ordering::Relaxed);
            // Invariant 2: at-most-once, and skips only under a cause.
            assert!(runs <= 1, "node {i} ran twice");
            assert!(runs == 1 || cause != 0, "node {i} skipped without a cause");
        }
        // Invariant 3: cancel-after-done is a no-op.
        if saw_done {
            assert_eq!(cause, 0, "cancel observed completion yet set the cause");
            assert_eq!(st.executed[0].load(Ordering::Relaxed), 1);
            assert_eq!(st.executed[1].load(Ordering::Relaxed), 1);
        }
    });
}
