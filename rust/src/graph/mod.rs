//! Task graphs (paper §2.2, §4.2).
//!
//! A task graph is a collection of tasks plus dependencies that define
//! execution order. Each node is a thin wrapper over a closure storing
//! its successor list and the number of uncompleted predecessors; when
//! a node finishes, the worker decrements each successor's counter and
//! executes the *first* successor that becomes ready **inline on the
//! same worker thread**, submitting the rest to the pool — the paper's
//! §2.2 continuation rule, which keeps chain-shaped graphs on one
//! worker with zero queue traffic.
//!
//! Repeated runs are the fast path (PR 2): sealing a graph
//! ([`TaskGraph::seal`], or implicitly on first run) flattens the
//! dependency structure into a CSR successor arena with dense
//! cache-line-aligned pending counters, the run's bookkeeping lives in
//! a graph-owned reusable slot, and the calling thread assists the run
//! instead of sleeping — so a sealed graph's second and later `run()`
//! calls perform **zero heap allocations** and no handoff context
//! switch. Each piece is independently toggleable via [`RunOptions`].
//!
//! Scheduling is **priority-aware** (PR 4): sealing also computes each
//! node's weighted critical-path rank (`schedule.rs`), the continuation
//! rule prefers the highest-rank ready successor, submission bursts are
//! published most-critical-first through the injector's priority lanes,
//! and whole runs carry a [`RunPriority`] class so concurrent fleets
//! can express tenant tiers — all toggleable via [`RunOptions`].
//!
//! Ranks are **self-correcting** (PR 8): the executor records each
//! node's observed duration into a per-node EWMA beside the CSR
//! arena, and a launch recomputes the critical-path ranks from those
//! observations — in place, allocation-free — once they drift ≥2×
//! from the weights the current ranks encode. Declared weights that
//! are wrong by orders of magnitude stop mattering after a couple of
//! re-runs ([`RunOptions::dynamic_rank`] opts out;
//! [`TaskGraph::reranks`] / [`TaskGraph::observed_duration`] observe).
//!
//! Submission is **shard-aware** (PR 5): a run's cross-thread bursts
//! route through the pool's per-shard injectors (striped round-robin
//! by default), and [`RunOptions::shard`] pins a run to one shard so a
//! fleet of concurrent graphs can partition the machine's cache
//! domains between them.
//!
//! Runs can also be launched **without blocking** (PR 3):
//! [`TaskGraph::run_async`] submits the sources and returns a
//! [`RunHandle`] that pins the graph borrow for the lifetime of the
//! run, so one external thread can keep many graphs in flight and
//! observe completion by polling, blocking, or `.await`ing the
//! handle. Sealed re-runs through a handle stay zero-allocation.
//!
//! Runs have a **lifecycle** (PR 6): cooperative cancellation
//! ([`RunHandle::cancel`], fleet-wide [`CancelToken`]s), deadlines
//! ([`RunOptions::deadline`]), typed panic quarantine
//! ([`GraphError::NodePanicked`] aborts the run, the graph un-poisons
//! on the next `run()`), and admission control with backpressure
//! (`PoolConfig::max_inflight_runs` / `max_queued_tasks`,
//! [`TaskGraph::try_run`] → [`GraphError::Overloaded`]). See the
//! executor module docs for the full failure model.

mod builder;
mod dataflow;
mod executor;
mod par;
mod schedule;
mod trace;

pub use builder::{GraphError, NodeId, TaskGraph};
pub use dataflow::{Dataflow, DataflowError, Input, Output};
pub use executor::{wait_all, wait_any, CancelToken, RunHandle, RunOptions};
pub use par::{
    parallel_for, parallel_for_with, parallel_reduce, parallel_reduce_with, ParOptions,
    DEFAULT_OVERSUBSCRIPTION,
};
pub use schedule::RunPriority;
pub use trace::{ShardDepthSample, SpanGuard, TraceEvent, Tracer};

pub(crate) use executor::{
    chaos_inject_launch_panic, chaos_inject_overload, execute_node, NodeRun,
};

/// Runtime override for the chaos serving knobs (PR 7) — re-exported
/// for the chaos-storm soak test; see
/// `executor::chaos_set_serving_rates`.
#[cfg(feature = "chaos")]
pub use executor::chaos_set_serving_rates;

/// Runtime override for the chaos launch-panic rate (PR 8) —
/// re-exported for the grant-leak chaos test; see
/// `executor::chaos_set_launch_panic_rate`.
#[cfg(feature = "chaos")]
pub use executor::chaos_set_launch_panic_rate;
