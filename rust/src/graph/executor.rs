//! Task-graph execution (paper §2.2), optimized for repeated runs
//! (PR 2) and extended with non-blocking run handles (PR 3).
//!
//! When the pool executes a graph node it first runs the wrapped
//! closure, then for each successor decrements the uncompleted-
//! predecessor counter. The **first** successor whose counter reaches
//! zero is executed on the *same worker thread* (an inline
//! continuation — no deque traffic, no wakeup); every *other* ready
//! successor is collected into a burst buffer and published to the
//! pool as one batch (flushing and refilling the buffer for fan-outs
//! wider than [`READY_BURST`]). A linear chain therefore runs entirely
//! on one worker as a single pool job.
//!
//! # Priority scheduling (PR 4)
//!
//! On a sealed graph the §2.2 rule is **critical-path-first** by
//! default: the inline continuation is the *highest-rank* ready
//! successor (rank = weighted longest-path-to-sink, computed at seal
//! time — see `graph/schedule.rs`), and the remaining ready successors
//! are published most-critical-first ([`ReadyBurst`]). Cross-thread
//! submissions additionally ride the injector's priority lanes,
//! composing the run's [`RunPriority`] class with each node's rank
//! bucket, so concurrent fleets can express tenant tiers. Both
//! behaviours are independently toggleable
//! ([`RunOptions::no_critical_path`], [`RunOptions::no_priority_lanes`]);
//! with both off a run is scheduled exactly like the pre-PR 4 FIFO
//! path. None of this allocates on the re-run path: ranks, buckets,
//! and the ordered source lists are seal-time arrays, and burst
//! sorting is in-place on the stack buffer.
//!
//! # Shard-aware submission (PR 5)
//!
//! Every cross-thread submission a run makes — the source burst from
//! the launching thread, successor bursts published by assist
//! helpers — routes through the pool's shard layer
//! (`pool/topology.rs`): by default the launching thread's striped
//! round-robin (or an assist helper's home shard) picks the injector,
//! and [`RunOptions::shard`] pins the whole run to one shard so a
//! fleet of graphs can partition the machine. Worker-local pushes
//! (the common §2.2 case) never consult the shard layer — the
//! executing worker's deque is already the locality optimum — and on
//! a single-shard pool all of this degenerates to the pre-PR 5
//! single-injector path.
//!
//! # Run-lifecycle robustness (PR 6)
//!
//! A launched run can now be stopped, timed out, and survive a
//! panicking node, and the pool can bound how many runs it accepts:
//!
//! * **Cooperative cancellation** — [`RunHandle::cancel`] (one run) and
//!   [`CancelToken`] via [`RunOptions::cancel_token`] (a whole fleet)
//!   set a per-run abort cause that every worker checks at the
//!   node-dispatch boundary, *before* running the node's closure. A
//!   closure that already started is never preempted; every node not
//!   yet started is **skipped** — its task still flows through the
//!   successor pending-counter decrements and the `remaining` count,
//!   so the run drains to the normal quiescent completion (`finish`
//!   fires exactly once, every waiter kind wakes, `wait_idle`
//!   balances) and the generation pair stays exact. The result
//!   surfaces as [`GraphError::Cancelled`] from every wait surface
//!   (`run`, `wait`, `try_wait`, `Future::poll`).
//! * **Deadlines** — [`RunOptions::deadline`] arms the pool's
//!   monotonic timer (one lazily-spawned thread over a min-heap —
//!   `pool/timer.rs`), which promotes the run's abort cause to
//!   *deadline* when it fires; the same skip-and-cascade path then
//!   drains the run, surfacing [`GraphError::DeadlineExceeded`]. The
//!   timer also backs [`RunHandle::wait_timeout`].
//! * **Panic quarantine** — a panicking node records the first payload
//!   and **aborts the run**: the remaining nodes are skipped exactly
//!   like a cancellation and the run reports
//!   [`GraphError::NodePanicked`] (node id, optional name, rendered
//!   payload). The slot un-poisons on the next launch (payload and
//!   cause are cleared in the quiescent window), and the pool's
//!   workers revive themselves should a panic ever escape the node
//!   containment, so the pool never silently shrinks (see
//!   `pool/thread_pool.rs`).
//! * **Admission control** — `PoolConfig::max_inflight_runs` /
//!   `max_queued_tasks` bound the pool's graph-run intake:
//!   [`TaskGraph::try_run`] fails fast with
//!   [`GraphError::Overloaded`], blocking launches park on a budget
//!   eventcount, and Low-class runs are shed first (never blocked) so
//!   background work yields to the tiers above it under overload.
//!
//! # Re-run hot path (PR 2)
//!
//! The paper's §4.2 benchmarks re-run the same `tasks` collection over
//! and over; three independently toggleable optimizations make that
//! re-run path allocation-free and context-switch-free:
//!
//! 1. **CSR topology arena** ([`RunOptions::no_topology_cache`] to
//!    disable) — successor lists are flattened into one contiguous
//!    arena and pending counters into a dense cache-line-aligned array
//!    (see `builder::Topology`), built on first run or by
//!    [`TaskGraph::seal`] and reset with one linear sweep.
//! 2. **Reusable run state** ([`RunOptions::no_state_reuse`]) — the
//!    `Arc<RunState>` holding the run's remaining/panic/completion
//!    machinery lives in a `TaskGraph`-owned slot and is re-armed in
//!    place, so a sealed graph's second and later `run()` calls
//!    allocate nothing (asserted by the counting-allocator test in
//!    `rust/tests/graph_alloc.rs` — for the blocking, caller-assist
//!    and async-handle paths alike).
//! 3. **Caller-assisted execution** ([`RunOptions::no_caller_assist`])
//!    — instead of blocking on a condvar while workers do all the
//!    work, the thread inside `run()` registers as an ephemeral helper
//!    that executes ready tasks itself (injector first, then stealing)
//!    and parks on the pool's eventcount only when there is genuinely
//!    nothing to take. This removes one context switch per run and
//!    makes single-threaded-pool graph runs latency-competitive with a
//!    direct loop. Note the helper takes whatever the queues hold, so
//!    unrelated pool tasks may execute on the calling thread.
//!
//! # Async run handles (PR 3)
//!
//! [`TaskGraph::run_async`] splits `run()` into its two halves — launch
//! and completion-wait — and hands the second half back to the caller
//! as a [`RunHandle`]: the sources are submitted exactly as for a
//! blocking run, but instead of parking, `run_async` returns
//! immediately. One external thread can therefore keep many graphs in
//! flight (one handle per graph; see `workloads::MultiRun`), poll them
//! (`is_done`/`try_wait`), block on one (`wait`), or `.await` them
//! ([`RunHandle`] implements [`Future`] via a waker slot on the
//! done-path). Handle waiters park on a **dedicated run eventcount**
//! (`PoolInner::wait_run`) so they never swallow the work-arrival
//! wakeups meant for workers.
//!
//! Async runs always use the graph-owned reusable `RunState` slot
//! (`no_state_reuse` is ignored) and never assist (`no_caller_assist`
//! is ignored) — the handle, not the blocked caller, is the run's
//! anchor.
//!
//! # Memory-safety protocol
//!
//! The raw node-slice and topology pointers inside [`RunState`]'s
//! header must outlive every job of a run. What pins them depends on
//! the wait mode:
//!
//! * **blocking runs** — [`run_graph`] returns only once the run has
//!   completed, so the `&mut TaskGraph` borrow pins both for the whole
//!   run;
//! * **async runs** — the [`RunHandle`] holds the `&mut TaskGraph`
//!   borrow, and its `Drop` blocks until the run is quiescent, so the
//!   borrow cannot end (and the CSR arena cannot be freed or rebuilt)
//!   under running tasks;
//! * **forgotten handles** — `mem::forget(handle)` skips the blocking
//!   `Drop` and releases the borrow early. Every operation that could
//!   invalidate run-pinned memory afterwards (mutation via
//!   `invalidate_caches`, a new launch re-arming the header, and
//!   `TaskGraph`'s own `Drop`) first waits for
//!   `completed >= generation` on the slot state, so even a leaked
//!   handle cannot lead to a rewrite or free under running tasks.
//!   (Async runs are restricted to the graph-owned slot precisely so
//!   this backstop sees every possibly-in-flight run.) A plain *move*
//!   of the graph runs no code at all, so the header may only point
//!   into run structures whose addresses survive moves of the
//!   `TaskGraph` value: the node slice lives in `Vec`-owned heap
//!   memory and the topology is boxed for exactly this reason.
//!
//! Exclusive access to each node's `FnMut` closure holds because (a) a
//! node is scheduled exactly once per run — only the worker that
//! decrements its `pending` counter to zero schedules it, and
//! `fetch_sub` picks a unique such worker — and (b) all predecessor
//! effects happen-before the node via the `AcqRel` decrements.
//!
//! Reusing the `RunState` across runs is sound because the mutable
//! header is rewritten only between runs, when no task of any run can
//! read it. Completion is recorded by a **monotone generation pair**
//! rather than a resettable flag: launch *k* stores
//! `generation = k` before submitting sources, the final decrement of
//! run *k* stores `completed = k` (SeqCst), and every waiter — assist
//! helper, handle waiter, `Future` poll, condvar sleeper, or the
//! forget backstop — waits for `completed >= k`. Every header read a
//! task performs is sequenced before that task's final `remaining`
//! decrement, the waiter acquires the `completed` store, and run
//! *k + 1*'s header write is sequenced after the wait returns — so all
//! reads of run *k* happen-before the write for run *k + 1*. Because
//! `completed` never goes backwards there is no "reset the done flag"
//! window, and a stale handle from run *k* (which checks
//! `completed >= k`) can never observe run *k + 1*'s completion as its
//! own, nor can a fresh handle for run *k + 1* (checking
//! `completed >= k + 1`) be satisfied by run *k*'s record. Stale
//! `Arc<RunState>` clones held briefly by workers after the final
//! decrement only drop their refcount; they never touch the header
//! again.
//!
//! The completion side fans out to every waiter kind the run may have:
//! the pool's worker eventcount (assist mode), the dedicated run
//! eventcount (handle waiters), the registered [`Waker`] (async
//! `.await`), and the state's condvar (`no_caller_assist` waiters and
//! the forget backstop). Each unused channel costs one load. The
//! waker handshake is a store-buffering pair: `poll` publishes the
//! waker and *then* re-checks `completed` (both SeqCst); the completer
//! stores `completed` and *then* checks the waker flag (both SeqCst) —
//! at least one side must observe the other, so a wakeup cannot be
//! lost. Both protocols (header-rewrite quiescence and the
//! completion/waker handshake) are model-checked under loom in
//! `rust/tests/loom_model.rs`.

use std::cell::UnsafeCell;
use std::future::Future;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::pin::Pin;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

use super::builder::{GraphError, Node, TaskGraph, Topology};
use super::schedule::{lane_compose, RunPriority, Schedule};
use crate::obs::{EventKind, RunProfile};
use crate::pool::injector::DEFAULT_LANE;
use crate::pool::task::RawTask;
use crate::pool::thread_pool::PoolInner;
use crate::pool::timer;
use crate::pool::ThreadPool;

/// Fleet-wide cooperative cancellation token (PR 6).
///
/// Attach a clone to any number of runs via
/// [`RunOptions::cancel_token`]; calling [`CancelToken::cancel`]
/// aborts every run carrying the token at its next node-dispatch
/// boundary (a closure already running is never preempted). The token
/// is **sticky**: once cancelled it stays cancelled, so a later run
/// launched with the same token aborts at its first dispatch — build a
/// fresh token per wave if that is not what you want. Cloning is a
/// refcount bump; sealed re-runs with a token stay allocation-free.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-cancelled token.
    pub fn new() -> Self {
        CancelToken(Arc::new(AtomicBool::new(false)))
    }

    /// Requests cancellation of every run carrying a clone of this
    /// token. Idempotent; returns immediately (the runs drain
    /// cooperatively — wait on their handles to observe quiescence).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// True once [`CancelToken::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Options controlling one graph run. The default is every
/// optimization ON (the paper's §2.2 behaviour plus the PR 2 re-run
/// optimizations); each `no_*` flag disables one independently for the
/// `graph_rerun`/`ablations` benches.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Execute the first ready successor inline on the same worker
    /// (paper §2.2). Disabling this resubmits *every* ready successor
    /// to the pool — the `ablations` bench quantifies the difference.
    /// (Inverted flag so `Default` means the paper's behaviour.)
    pub no_inline_continuation: bool,
    /// Disable the CSR topology arena: walk the builder's per-node
    /// successor `Vec`s and per-node `pending` counters instead (the
    /// seed's layout, kept as the ablation arm).
    pub no_topology_cache: bool,
    /// Allocate a fresh `RunState` (and, with the topology cache also
    /// off, a fresh source list) on every run instead of reusing the
    /// graph-owned slot — the seed's per-run allocation behaviour.
    /// Ignored by [`TaskGraph::run_async`]: async runs always use the
    /// reusable slot (the handle's generation check and the
    /// forgotten-handle backstop both key off it).
    pub no_state_reuse: bool,
    /// Block the calling thread on a condvar until workers finish the
    /// run, instead of letting it execute ready tasks itself. Ignored
    /// by [`TaskGraph::run_async`]: handle waiters park on the run
    /// eventcount and never assist.
    pub no_caller_assist: bool,
    /// Disable critical-path-first dispatch (PR 4): fall back to the
    /// paper's shape-oblivious §2.2 rule (first ready successor inline,
    /// rest FIFO) instead of "highest-rank ready successor inline, rest
    /// in descending rank order". Also implied whenever the run has no
    /// rank information (`no_topology_cache` — the rank array lives in
    /// the sealed topology).
    pub no_critical_path: bool,
    /// Disable the injector's priority lanes for this run (PR 4):
    /// cross-thread submissions all use the default lane instead of the
    /// run-class × node-rank composition (`graph/schedule.rs`). With
    /// both this and `no_critical_path` set, a run's scheduling is
    /// bit-identical to the pre-PR 4 FIFO path.
    pub no_priority_lanes: bool,
    /// Priority class of the whole run (PR 4): the tenant tier for
    /// concurrent fleets. Shifts every cross-thread submission of this
    /// run up or down the injector's lane order; node ranks refine the
    /// order within the class. No effect while `no_priority_lanes` is
    /// set.
    pub priority: RunPriority,
    /// Home shard of the run (PR 5): pins every **cross-thread**
    /// submission of this run (sources launched from the caller,
    /// successors published by assist helpers) to one shard's
    /// injector, so a fleet of concurrent graphs can each keep their
    /// working set on one cache-sharing worker group. Clamped to the
    /// pool's shard count; `None` (default) routes through the
    /// striped round-robin / assist-home rules. Worker-local pushes
    /// are unaffected — the executing worker's own deque is already
    /// the locality optimum — and the two-level sweep means a pinned
    /// run can never starve even if its shard's workers are busy.
    pub shard: Option<usize>,
    /// Record per-node execution spans into this tracer
    /// (see [`super::Tracer`]).
    pub tracer: Option<Arc<super::Tracer>>,
    /// Fleet-wide cancel token (PR 6): checked at every node-dispatch
    /// boundary of the run and promoted into the run's abort cause on
    /// first observation — see [`CancelToken`]. `None` (default)
    /// leaves per-run [`RunHandle::cancel`] as the only cancel path.
    pub cancel: Option<CancelToken>,
    /// Deadline for the whole run (PR 6), measured from launch. When
    /// it expires before completion the run aborts exactly like a
    /// cancellation (remaining nodes skipped, quiescence exact) and
    /// reports [`GraphError::DeadlineExceeded`]. Enforced by the
    /// lazily-spawned monotonic timer thread (`pool/timer.rs`);
    /// arming it allocates one timer entry, so deadline runs are
    /// excluded from the zero-alloc re-run guarantee.
    pub deadline: Option<Duration>,
    /// Disable duration-feedback re-ranking for this run (PR 8): the
    /// executor stops sampling per-node durations and the launch-time
    /// drift check is skipped, freezing the ranks at their current
    /// values (seal-time declared weights, or whatever the last
    /// re-rank computed). The ablation arm for measuring what observed
    /// ranks buy on stale-weight graphs. No effect while
    /// `no_topology_cache` or `no_critical_path` is set (no rank
    /// consumer).
    pub no_dynamic_rank: bool,
}

impl RunOptions {
    /// The default behaviour: inline continuations, CSR topology,
    /// state reuse, and caller assistance all on; no tracing.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compatibility constructor used by benches/tests.
    pub fn inline(inline_continuation: bool) -> Self {
        Self {
            no_inline_continuation: !inline_continuation,
            ..Self::default()
        }
    }

    /// Toggles the CSR topology arena (PR 2 piece 1).
    pub fn topology_cache(mut self, on: bool) -> Self {
        self.no_topology_cache = !on;
        self
    }

    /// Toggles run-state reuse (PR 2 piece 2).
    pub fn state_reuse(mut self, on: bool) -> Self {
        self.no_state_reuse = !on;
        self
    }

    /// Toggles caller-assisted execution (PR 2 piece 3).
    pub fn caller_assist(mut self, on: bool) -> Self {
        self.no_caller_assist = !on;
        self
    }

    /// Toggles critical-path-first dispatch (PR 4).
    pub fn critical_path(mut self, on: bool) -> Self {
        self.no_critical_path = !on;
        self
    }

    /// Toggles the injector priority lanes for this run (PR 4).
    pub fn priority_lanes(mut self, on: bool) -> Self {
        self.no_priority_lanes = !on;
        self
    }

    /// Tags the whole run with a priority class (PR 4) — see
    /// [`RunPriority`].
    pub fn priority(mut self, class: RunPriority) -> Self {
        self.priority = class;
        self
    }

    /// Pins the run's cross-thread submissions to one shard (PR 5) —
    /// see [`RunOptions::shard`].
    pub fn on_shard(mut self, shard: usize) -> Self {
        self.shard = Some(shard);
        self
    }

    /// Attaches a tracer.
    pub fn with_tracer(mut self, tracer: Arc<super::Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Attaches a fleet-wide [`CancelToken`] (PR 6) — see
    /// [`RunOptions::cancel`].
    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Sets a deadline for the run (PR 6) — see
    /// [`RunOptions::deadline`].
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Toggles duration-feedback re-ranking (PR 8) — see
    /// [`RunOptions::no_dynamic_rank`].
    pub fn dynamic_rank(mut self, on: bool) -> Self {
        self.no_dynamic_rank = !on;
        self
    }
}

/// The per-run view of the graph: raw pointers into the
/// `&mut TaskGraph` pinned by the run's anchor (blocked caller or
/// [`RunHandle`]), plus this run's options. Rewritten at the start of
/// every run (see the module-level protocol argument for why that is
/// race-free).
pub(crate) struct RunHeader {
    nodes: *const Node,
    len: usize,
    /// Null ⇒ the topology cache is disabled for this run; walk the
    /// builder's per-node `Vec`s instead.
    topo: *const Topology,
    options: RunOptions,
}

impl RunHeader {
    #[inline]
    fn node(&self, i: usize) -> &Node {
        debug_assert!(i < self.len);
        // SAFETY: i < len and the node slice outlives the run (module
        // docs).
        unsafe { &*self.nodes.add(i) }
    }
}

/// Which waiter kind a run's completion must wake (stored in
/// [`RunState::wake_mode`], written only in the quiescent launch
/// window). The waker slot and the condvar are checked
/// unconditionally — they are flag-gated loads — so these modes only
/// select the *eventcount* to poke.
const WAKE_EC: u8 = 0; // sync caller-assist run: the workers' eventcount
const WAKE_RUN_EC: u8 = 1; // async handle: the dedicated run eventcount
const WAKE_CONDVAR: u8 = 2; // sync condvar run: no eventcount at all

/// Abort causes of a run (PR 6), stored in [`RunState::cancelled`].
/// First cause wins (CAS from `CAUSE_NONE`); reset only in the
/// quiescent launch window. The cause drives the dispatch-boundary
/// skip in [`execute_node`] and the typed error in [`take_result`].
const CAUSE_NONE: u8 = 0; // run proceeds normally
const CAUSE_CANCEL: u8 = 1; // RunHandle::cancel or a fleet CancelToken
const CAUSE_DEADLINE: u8 = 2; // the run's deadline expired (timer thread)
const CAUSE_PANIC: u8 = 3; // a node panicked; payload is in `panic`

/// Shared state of one in-flight graph run, reusable across runs.
pub(crate) struct RunState {
    /// See [`RunHeader`]. Written only between runs (the quiescent
    /// launch window); read only by tasks of the current run.
    header: UnsafeCell<RunHeader>,
    /// Nodes not yet finished; the run is complete at zero.
    remaining: AtomicUsize,
    /// Generation of the run the header currently describes. Written
    /// only in the quiescent launch window; monotonically increasing.
    generation: AtomicU64,
    /// Highest generation that has fully completed (monotone; SeqCst —
    /// the completion flag every waiter keys off). `completed >= g`
    /// means run `g` is done; because it never goes backwards there is
    /// no reset window and stale/fresh handles cannot confuse runs
    /// (module docs).
    completed: AtomicU64,
    /// Which eventcount (if any) completion must poke; see the
    /// `WAKE_*` constants.
    wake_mode: AtomicU8,
    /// First panic observed, if any: (node index, rendered message).
    /// Cleared at launch so an unharvested panic from a dropped handle
    /// cannot leak into the next run's result.
    panic: Mutex<Option<(usize, String)>>,
    /// Abort cause of the current run (PR 6, `CAUSE_*`): first cause
    /// wins; every dispatch boundary checks it and skips the node when
    /// set. Reset in the quiescent launch window (the un-poison step).
    cancelled: AtomicU8,
    /// True while this run holds one of the pool's admission slots
    /// (PR 6, `PoolConfig::max_inflight_runs`); the completion path
    /// releases it exactly once (`swap`).
    admitted: AtomicBool,
    /// Threads blocked in [`RunState::wait_sync`] (condvar-mode waiters
    /// and the forgotten-handle quiesce backstop); gates the
    /// completion-side condvar notify to one load when unused.
    sync_waiters: AtomicUsize,
    done_mutex: Mutex<()>,
    done_cv: Condvar,
    /// Waker registered by [`RunHandle`]'s `Future` impl, if any.
    waker: Mutex<Option<Waker>>,
    /// Publication flag for `waker` — the SeqCst half of the
    /// store-buffering handshake with the completion path (module
    /// docs).
    has_waker: AtomicBool,
    /// The pool the current run targets (written in the quiescent
    /// launch window). Only the forgotten-handle backstop reads it:
    /// [`RunState::wait_quiesce`] must drain pool tasks instead of
    /// parking when called from a thread that is itself executing a
    /// task of that pool (see `PoolInner::wait_run`), and a condvar
    /// park there would deadlock a single-worker pool.
    pool: Mutex<Weak<PoolInner>>,
}

// SAFETY: the pointed-to node slice and topology are pinned for the
// lifetime of the run by the run anchor (blocked caller, live handle,
// or the quiesce backstop — module docs); Node is Sync (see
// builder.rs) and Topology's shared surface is atomics + shared
// slices. Header mutation is confined to the quiescent window between
// runs.
unsafe impl Send for RunState {}
unsafe impl Sync for RunState {}

impl RunState {
    pub(crate) fn new() -> Self {
        RunState {
            header: UnsafeCell::new(RunHeader {
                nodes: ptr::null(),
                len: 0,
                topo: ptr::null(),
                options: RunOptions::default(),
            }),
            remaining: AtomicUsize::new(0),
            generation: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            wake_mode: AtomicU8::new(WAKE_EC),
            panic: Mutex::new(None),
            cancelled: AtomicU8::new(CAUSE_NONE),
            admitted: AtomicBool::new(false),
            sync_waiters: AtomicUsize::new(0),
            done_mutex: Mutex::new(()),
            done_cv: Condvar::new(),
            waker: Mutex::new(None),
            has_waker: AtomicBool::new(false),
            pool: Mutex::new(Weak::new()),
        }
    }

    /// True once run `gen` has fully completed.
    #[inline]
    fn is_complete(&self, gen: u64) -> bool {
        self.completed.load(Ordering::SeqCst) >= gen
    }

    /// Requests an abort of the current run with `cause` (PR 6). The
    /// first cause wins — a deadline firing after a user cancel (or a
    /// panic after either) leaves the original cause in place, and the
    /// panic payload is reported with priority by [`take_result`]
    /// regardless of which cause won the CAS. Returns whether this
    /// call set the cause.
    fn abort(&self, cause: u8) -> bool {
        debug_assert_ne!(cause, CAUSE_NONE);
        self.cancelled
            .compare_exchange(CAUSE_NONE, cause, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }

    /// [`RunState::abort`] plus a flight-recorder `Abort` event (PR 9)
    /// when this call actually set the cause — so a dump shows exactly
    /// one abort per run, attributed to the lane that raised it
    /// (worker, caller thread, or the timer via the external lane).
    fn abort_observed(&self, cause: u8, pool: &PoolInner) -> bool {
        let set = self.abort(cause);
        if set {
            pool.record_flight(
                pool.flight_lane_of_caller(),
                EventKind::Abort,
                cause as u32,
                self.generation.load(Ordering::Relaxed),
            );
        }
        set
    }

    /// Completion path: records run `generation` as done and wakes
    /// every waiter kind this run may have. Called exactly once per
    /// run, by the task that decrements `remaining` to zero; after the
    /// `completed` store the header/nodes/topology must not be touched
    /// (the launcher may already be re-arming them).
    fn finish(&self, pool: &Arc<PoolInner>) {
        // `generation` is stable for the whole run; reading it here
        // (before the store below releases the run) is race-free.
        let gen = self.generation.load(Ordering::SeqCst);
        self.completed.store(gen, Ordering::SeqCst);
        match self.wake_mode.load(Ordering::Relaxed) {
            // Assist helpers park on the workers' eventcount; workers
            // that wake spuriously just re-park.
            WAKE_EC => pool.notify_all_workers(),
            // Handle waiters park on the dedicated run eventcount so
            // they never swallow work-arrival wakeups (thread_pool.rs).
            WAKE_RUN_EC => pool.notify_run_waiters(),
            _ => {}
        }
        // Async waker: SeqCst load pairs with register_waker's SeqCst
        // store — the store-buffering handshake in the module docs.
        // The flag is updated only while holding the slot lock (here
        // and in register/clear), so flag and slot can never disagree:
        // without that, a take here racing a re-registration could
        // leave a live Waker stranded behind a false flag.
        if self.has_waker.load(Ordering::SeqCst) {
            let waker = {
                let mut slot = self.waker.lock().unwrap();
                self.has_waker.store(false, Ordering::SeqCst);
                slot.take()
            };
            if let Some(waker) = waker {
                waker.wake();
            }
        }
        // Condvar waiters (no_caller_assist mode, forget backstop).
        // If our load sees 0, any later-registering waiter's SeqCst
        // increment orders after our `completed` store, so its
        // predicate check observes completion without the notify.
        if self.sync_waiters.load(Ordering::SeqCst) != 0 {
            // Lock/unlock serializes with a waiter between its
            // predicate check and cv.wait.
            drop(self.done_mutex.lock().unwrap());
            self.done_cv.notify_all();
        }
        // PR 6: return this run's admission slot (if it took one) and
        // wake launchers parked on the budget eventcount. The `swap`
        // makes the release exactly-once even if a later quiesce path
        // revisits this state.
        if self.admitted.swap(false, Ordering::SeqCst) {
            pool.release_run_slot();
        }
    }

    /// Blocks on the state's condvar until run `gen` completes.
    fn wait_sync(&self, gen: u64) {
        self.sync_waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.done_mutex.lock().unwrap();
        while !self.is_complete(gen) {
            guard = self.done_cv.wait(guard).unwrap();
        }
        drop(guard);
        self.sync_waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Blocks until the most recently launched run (if any) has
    /// completed — the forgotten-handle backstop (module docs). In the
    /// normal lifecycle the run is already quiescent and this is two
    /// loads.
    ///
    /// Goes through `PoolInner::wait_run` so that, on a thread already
    /// executing a task of the run's own pool, the wait *drains* pool
    /// tasks instead of parking (a condvar park there would wedge a
    /// single-worker pool forever — the orphan run's nodes could never
    /// execute). An in-flight run can only be orphaned by
    /// `mem::forget` of an async handle, and async runs always record
    /// their pool here at launch; if the pool is already gone its drop
    /// drained every task, so the run is complete and the condvar
    /// fallback returns immediately.
    pub(crate) fn wait_quiesce(&self) {
        let gen = self.generation.load(Ordering::SeqCst);
        if self.is_complete(gen) {
            return;
        }
        let pool = self.pool.lock().unwrap().upgrade();
        match pool {
            Some(pool) => pool.wait_run(|| self.is_complete(gen)),
            None => self.wait_sync(gen),
        }
    }

    /// Publishes `waker` for the completion path. The SeqCst flag
    /// store must precede the caller's completion re-check (Future
    /// impl) for the handshake to be lossless; it happens under the
    /// slot lock so flag and slot stay consistent (see `finish`).
    fn register_waker(&self, waker: &Waker) {
        let mut slot = self.waker.lock().unwrap();
        *slot = Some(waker.clone());
        self.has_waker.store(true, Ordering::SeqCst);
    }

    /// Drops any registered waker (handle harvested or dropped) so a
    /// later run's completion does not wake a dead task spuriously —
    /// and so the Waker's executor resources are released promptly.
    /// Cold path (once per handle), so it takes the lock
    /// unconditionally rather than trusting the flag.
    fn clear_waker(&self) {
        let mut slot = self.waker.lock().unwrap();
        slot.take();
        self.has_waker.store(false, Ordering::SeqCst);
    }
}

/// A scheduled node of an in-flight run — the payload of a node
/// `RawTask` (two words: it always stores inline, never allocates).
pub(crate) struct NodeRun {
    pub(crate) state: Arc<RunState>,
    pub(crate) node: usize,
}

/// Ready successors collected per executed node before being published
/// as one submission burst. Fan-outs wider than the buffer flush it as
/// a full batch and keep filling, so arbitrarily wide fan-outs stay at
/// one counter bump + one wake per `READY_BURST` successors.
const READY_BURST: usize = 32;

/// The priority-aware ready-successor burst (PR 4): a stack buffer that
/// — when the run has rank information and critical-path dispatch is on
/// — is sorted by descending rank before every flush and submitted
/// through `PoolInner::submit_node_burst`, which keeps the
/// most-critical-first order under every queue discipline (reversed
/// pushes for the owner's LIFO deque, contiguous per-lane batches for
/// the FIFO injector). Entirely stack-allocated: the sort is in-place
/// (`sort_unstable_by_key`) and the per-lane grouping walks slices, so
/// sealed re-runs stay zero-allocation with priorities enabled.
///
/// Fan-outs wider than [`READY_BURST`] flush and refill; each flushed
/// burst is internally rank-ordered, but ordering across bursts is
/// best-effort (the inline candidate is still the global maximum — see
/// `execute_node`).
struct ReadyBurst<'a> {
    buf: [usize; READY_BURST],
    len: usize,
    /// `Some(ranks)` ⇒ critical-path mode: sort descending, reverse
    /// LIFO pushes.
    ranks: Option<&'a [u64]>,
    /// Rank-quartile buckets for the lane composition (present iff the
    /// run has a sealed topology).
    buckets: Option<&'a [u8]>,
    /// `None` ⇒ priority lanes disabled: everything to [`DEFAULT_LANE`].
    class: Option<RunPriority>,
    /// Shard pin for the run's cross-thread submissions (PR 5) —
    /// see [`RunOptions::shard`].
    shard: Option<usize>,
}

impl<'a> ReadyBurst<'a> {
    fn new(sched: Option<&'a Schedule>, options: &RunOptions) -> Self {
        ReadyBurst {
            buf: [0; READY_BURST],
            len: 0,
            ranks: sched.filter(|_| !options.no_critical_path).map(|s| s.ranks.as_slice()),
            buckets: sched.map(|s| s.buckets.as_slice()),
            class: (!options.no_priority_lanes).then_some(options.priority),
            shard: options.shard,
        }
    }

    /// True when this run uses rank-aware dispatch (highest-rank inline
    /// continuation, rank-ordered bursts).
    #[inline]
    fn critical_path(&self) -> bool {
        self.ranks.is_some()
    }

    #[inline]
    fn rank(&self, node: usize) -> u64 {
        self.ranks.map(|r| r[node]).unwrap_or(0)
    }

    /// Buffers a ready node, flushing first if full.
    fn push(&mut self, node: usize, pool: &Arc<PoolInner>, state: &Arc<RunState>) {
        if self.len == READY_BURST {
            self.flush(pool, state);
        }
        self.buf[self.len] = node;
        self.len += 1;
    }

    /// Publishes the buffered nodes as one burst and empties the
    /// buffer.
    fn flush(&mut self, pool: &Arc<PoolInner>, state: &Arc<RunState>) {
        let n = self.len;
        if n == 0 {
            return;
        }
        if self.ranks.is_none() && self.class.is_none() {
            // Both priority behaviours off: the untouched pre-PR 4
            // submission path, bit-identical by construction (the
            // shard hint only selects WHICH injector an off-worker
            // burst lands in, never how it is queued).
            pool.submit_job_batch_sharded(
                self.shard,
                self.buf[..n].iter().map(|&node| {
                    RawTask::node(NodeRun {
                        state: state.clone(),
                        node,
                    })
                }),
            );
            self.len = 0;
            return;
        }
        let ranked = if let Some(ranks) = self.ranks {
            // Descending rank; node index breaks ties so the order is
            // deterministic under any discovery interleaving.
            self.buf[..n].sort_unstable_by_key(|&i| (std::cmp::Reverse(ranks[i]), i));
            true
        } else {
            false
        };
        let (class, buckets) = (self.class, self.buckets);
        let lane_for = move |node: usize| match class {
            Some(class) => lane_compose(class, buckets.map(|b| b[node])),
            None => DEFAULT_LANE,
        };
        let mk = |node: usize| {
            RawTask::node(NodeRun {
                state: state.clone(),
                node,
            })
        };
        pool.submit_node_burst(self.shard, &self.buf[..n], ranked, &lane_for, &mk);
        self.len = 0;
    }
}

/// Executes `run.node`, then chains ready successors per §2.2.
/// Called from the node-task vtable (`pool::task`) on a worker, or on
/// a caller-assist helper thread (`worker_index` is then the pool's
/// helper metrics lane).
pub(crate) fn execute_node(pool: &Arc<PoolInner>, worker_index: usize, run: NodeRun) {
    let state = run.state;
    // SAFETY: the header is immutable for the whole run this task
    // belongs to (see the module-level protocol argument).
    let header = unsafe { &*state.header.get() };
    // SAFETY: non-null topo points at the graph-owned Topology, pinned
    // like the node slice until the run completes.
    let topo: Option<&Topology> = unsafe { header.topo.as_ref() };
    // Seal-time priority schedule (PR 4); absent when the topology
    // cache is disabled, which also disables critical-path dispatch.
    let sched: Option<&Schedule> = topo.map(|t| t.sched());
    let no_inline = header.options.no_inline_continuation;
    let mut current = run.node;
    loop {
        let node = header.node(current);

        // 0. Dispatch-boundary cancellation check (PR 6): a run whose
        //    abort cause is set — by `RunHandle::cancel`, a fleet
        //    token, the deadline timer, or an earlier node's panic —
        //    **skips** every node it has not yet started. The skip
        //    still flows through the successor decrements and the
        //    `remaining` count below, so the run drains to the normal
        //    quiescent completion and the generation counters stay
        //    exact. A closure that already started is never preempted
        //    (cooperative model: this is the only check point).
        let aborted = state.cancelled.load(Ordering::SeqCst) != CAUSE_NONE
            || match &header.options.cancel {
                // Promote the fleet token into the per-run cause so
                // the rest of the cascade (and the final result) need
                // only the run-local atomic.
                Some(token) if token.is_cancelled() => {
                    state.abort_observed(CAUSE_CANCEL, pool);
                    true
                }
                _ => false,
            };

        // 1. Execute the wrapped function (paper: "it first executes
        //    the wrapped function"), containing panics so counters
        //    still advance and the run cannot deadlock. A panic
        //    records its first payload and aborts the run (PR 6):
        //    remaining nodes are skipped exactly like a cancellation
        //    and the run reports `GraphError::NodePanicked`.
        if !aborted {
            let span = header.options.tracer.as_ref().map(|t| {
                t.span_ranked(
                    worker_index,
                    match &node.name {
                        Some(n) => n.clone(),
                        None => format!("n{current}"),
                    },
                    sched.map(|s| s.ranks[current]).unwrap_or(0),
                    header.options.priority,
                )
            });
            // SAFETY: exclusive access per the module-level protocol.
            let func = unsafe { &mut *node.func.get() };
            chaos_maybe_spike();
            // Duration sampling (PR 8 + PR 9): one timestamp pair per
            // node on the pool's observability epoch, shared by the
            // dynamic-rank EWMA cells, the node-duration histogram,
            // the flight recorder's TaskStart/TaskEnd events, and the
            // topology's span arrays (the run-profile input). Only
            // this run's worker touches node `current`'s cells (runs
            // of a graph are serialized), so the relaxed stores are
            // exact. All four sinks are allocation-free atomics.
            let want_rank_sample = topo.is_some() && !header.options.no_dynamic_rank;
            let start_ns = (want_rank_sample || pool.hists().is_some() || pool.flight().is_some())
                .then(|| pool.now_ns());
            if start_ns.is_some() {
                pool.record_flight(
                    worker_index,
                    EventKind::TaskStart,
                    current as u32,
                    state.generation.load(Ordering::Relaxed),
                );
            }
            let outcome = if chaos_should_panic(&state) {
                catch_unwind(|| panic!("chaos: injected node panic"))
            } else {
                catch_unwind(AssertUnwindSafe(func))
            };
            if let Some(t0) = start_ns {
                let t1 = pool.now_ns().max(t0);
                let dur = t1 - t0;
                if want_rank_sample {
                    if let Some(t) = topo {
                        t.note_duration(current, dur);
                    }
                }
                if let Some(h) = pool.hists() {
                    h.node_duration.record(dur);
                }
                pool.record_flight(worker_index, EventKind::TaskEnd, current as u32, dur);
                if let Some(t) = topo {
                    t.record_span(current, t0, t1, worker_index as u32);
                }
            }
            if let Err(payload) = outcome {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic payload>".to_string());
                let mut p = state.panic.lock().unwrap();
                if p.is_none() {
                    *p = Some((current, msg));
                }
                drop(p);
                state.abort_observed(CAUSE_PANIC, pool);
            }
            drop(span); // record the span before scheduling successors
        }

        // 2. Decrement each successor's uncompleted-predecessor count.
        //    With critical-path dispatch (PR 4, default on a sealed
        //    graph): the **highest-rank** ready successor continues
        //    inline, the rest are buffered, rank-sorted, and published
        //    most-critical-first (a single pending-counter bump and a
        //    single wake per burst). The FIFO fallback (`no_critical_
        //    path`, or no rank information) keeps the paper's rule:
        //    first ready successor inline, rest in discovery order.
        //    When batched wakeups are disabled in the PoolConfig the
        //    burst degrades to the seed's per-successor submission for
        //    the ablation bench.
        let mut inline_next: Option<usize> = None;
        let mut burst = ReadyBurst::new(sched, &header.options);
        {
            let mut on_ready = |succ: usize| {
                if !no_inline {
                    match inline_next {
                        None => {
                            inline_next = Some(succ);
                            return;
                        }
                        // Critical-path mode: keep the max-rank ready
                        // successor as the inline continuation, even
                        // across burst flushes — displaced candidates
                        // join the burst like any other ready node.
                        Some(cur) if burst.critical_path() && burst.rank(succ) > burst.rank(cur) => {
                            burst.push(cur, pool, &state);
                            inline_next = Some(succ);
                            return;
                        }
                        _ => {}
                    }
                }
                burst.push(succ, pool, &state);
            };
            // AcqRel on the decrements: the final decrement acquires
            // every predecessor's release, ordering all predecessor
            // effects before the successor's execution.
            match topo {
                Some(t) => {
                    for &succ in t.successors(current) {
                        let succ = succ as usize;
                        if t.pending(succ).fetch_sub(1, Ordering::AcqRel) == 1 {
                            on_ready(succ);
                        }
                    }
                }
                None => {
                    for &succ in &node.successors {
                        if header.node(succ).pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                            on_ready(succ);
                        }
                    }
                }
            }
        }
        burst.flush(pool, &state);

        // 3. Mark this node complete. After this point we must not
        //    touch `node`, `header`, or `topo` again: if this was the
        //    last node, the run anchor may wake, invalidate the
        //    pointers, and even start the next run (rewriting the
        //    header). `finish` fans the completion out to every waiter
        //    kind this run may have.
        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            state.finish(pool);
        }

        match inline_next {
            Some(next) => {
                pool.metrics()[worker_index].on_inline_continuation();
                current = next;
            }
            None => break,
        }
    }
}

/// Chaos fault injection (PR 6, `--features chaos`): decides whether
/// the node about to execute should panic instead, and — as a side
/// effect — may inject a forced cancellation of the run. Rates come
/// from `CHAOS_PANIC_RATE` / `CHAOS_CANCEL_RATE` (events per 1000
/// dispatches; default 0 = inert even with the feature compiled in),
/// stream seeded by `CHAOS_SEED`.
#[cfg(feature = "chaos")]
fn chaos_should_panic(state: &RunState) -> bool {
    let cfg = chaos::config();
    if chaos::roll(cfg.cancel_per_mille) {
        state.abort(CAUSE_CANCEL);
    }
    chaos::roll(cfg.panic_per_mille)
}

#[cfg(not(feature = "chaos"))]
#[inline(always)]
fn chaos_should_panic(_state: &RunState) -> bool {
    false
}

/// Chaos node-latency spike (PR 7, `--features chaos`): with
/// probability `CHAOS_SPIKE_RATE`/1000 per dispatch, busy-holds the
/// worker for `CHAOS_SPIKE_US` µs (default 100) before the node's
/// closure runs — the "one slow node" failure mode a serving tier must
/// absorb without blowing its tail latencies.
#[cfg(feature = "chaos")]
fn chaos_maybe_spike() {
    let (per_mille, us) = chaos::spike_params();
    if chaos::roll(per_mille) {
        let until = Instant::now() + Duration::from_micros(us as u64);
        while Instant::now() < until {
            std::hint::spin_loop();
        }
    }
}

#[cfg(not(feature = "chaos"))]
#[inline(always)]
fn chaos_maybe_spike() {}

/// Chaos `Overloaded` injection at the serving dispatch boundary
/// (PR 7, `--features chaos`): with probability `CHAOS_OVERLOAD_RATE`
/// /1000 per dispatch, `serve::GraphService` treats the launch as if
/// the pool's admission budget were exhausted, exercising its
/// retry/backoff path. Inert without the feature (or with the rate
/// unset/zero).
#[cfg(feature = "chaos")]
pub(crate) fn chaos_inject_overload() -> bool {
    chaos::roll(chaos::overload_per_mille())
}

#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub(crate) fn chaos_inject_overload() -> bool {
    false
}

/// Runtime override of the chaos serving knobs (PR 7): lets the
/// chaos-storm soak test turn injection on mid-process and then **off**
/// again to assert the service converges back to steady-state goodput —
/// something the read-once env knobs cannot express. Env values seed
/// these on first use; the setters overwrite them.
#[cfg(feature = "chaos")]
pub fn chaos_set_serving_rates(overload_per_mille: u32, spike_per_mille: u32, spike_us: u32) {
    chaos::set_serving_rates(overload_per_mille, spike_per_mille, spike_us);
}

/// Chaos panic injection *inside the serving launch path* (PR 8,
/// `--features chaos`): with probability `CHAOS_LAUNCH_PANIC_RATE`
/// /1000 per launch, `serve::GraphService` panics between taking a
/// grant and releasing it — the failure mode the grant RAII guard
/// exists for. Returns whether to panic; the caller supplies the
/// actual `panic!` so the message names its own boundary.
#[cfg(feature = "chaos")]
pub(crate) fn chaos_inject_launch_panic() -> bool {
    chaos::roll(chaos::launch_panic_per_mille())
}

#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub(crate) fn chaos_inject_launch_panic() -> bool {
    false
}

/// Runtime override of the launch-panic rate (PR 8) — same
/// storm-then-recover contract as [`chaos_set_serving_rates`].
#[cfg(feature = "chaos")]
pub fn chaos_set_launch_panic_rate(per_mille: u32) {
    chaos::set_launch_panic_rate(per_mille);
}

/// Runtime-gated fault injection for the CI chaos job (PR 6). Only
/// compiled under `--features chaos`; with the env rates unset the
/// hooks are inert, so the full suite still passes under the feature.
#[cfg(feature = "chaos")]
mod chaos {
    use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
    use std::sync::OnceLock;

    pub(super) struct Config {
        pub(super) panic_per_mille: u32,
        pub(super) cancel_per_mille: u32,
    }

    static CONFIG: OnceLock<Config> = OnceLock::new();
    static RNG: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);

    /// Serving-boundary knobs (PR 7). Unlike the panic/cancel rates
    /// these live in plain atomics, env-seeded on first use and
    /// overridable at runtime (`set_serving_rates`), because the
    /// chaos-storm soak test must be able to stop injection
    /// mid-process and watch the service recover.
    static OVERLOAD_PER_MILLE: AtomicU32 = AtomicU32::new(0);
    static SPIKE_PER_MILLE: AtomicU32 = AtomicU32::new(0);
    static SPIKE_US: AtomicU32 = AtomicU32::new(100);
    static LAUNCH_PANIC_PER_MILLE: AtomicU32 = AtomicU32::new(0);
    static SERVING_SEEDED: OnceLock<()> = OnceLock::new();

    pub(super) fn config() -> &'static Config {
        CONFIG.get_or_init(|| {
            let rate = |key: &str| {
                std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(0)
            };
            if let Some(seed) =
                std::env::var("CHAOS_SEED").ok().and_then(|v| v.parse::<u64>().ok())
            {
                // Odd-ize so a zero seed still produces a live stream.
                RNG.store(seed.wrapping_mul(2).wrapping_add(1), Ordering::Relaxed);
            }
            Config {
                panic_per_mille: rate("CHAOS_PANIC_RATE"),
                cancel_per_mille: rate("CHAOS_CANCEL_RATE"),
            }
        })
    }

    fn seed_serving() {
        SERVING_SEEDED.get_or_init(|| {
            config(); // make sure CHAOS_SEED has been applied
            let rate = |key: &str, default: u32| {
                std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
            };
            OVERLOAD_PER_MILLE.store(rate("CHAOS_OVERLOAD_RATE", 0), Ordering::Relaxed);
            SPIKE_PER_MILLE.store(rate("CHAOS_SPIKE_RATE", 0), Ordering::Relaxed);
            SPIKE_US.store(rate("CHAOS_SPIKE_US", 100), Ordering::Relaxed);
            LAUNCH_PANIC_PER_MILLE.store(rate("CHAOS_LAUNCH_PANIC_RATE", 0), Ordering::Relaxed);
        });
    }

    pub(super) fn overload_per_mille() -> u32 {
        seed_serving();
        OVERLOAD_PER_MILLE.load(Ordering::Relaxed)
    }

    pub(super) fn spike_params() -> (u32, u32) {
        seed_serving();
        (SPIKE_PER_MILLE.load(Ordering::Relaxed), SPIKE_US.load(Ordering::Relaxed))
    }

    pub(super) fn set_serving_rates(overload: u32, spike: u32, spike_us: u32) {
        seed_serving(); // later env reads must not clobber the override
        OVERLOAD_PER_MILLE.store(overload, Ordering::Relaxed);
        SPIKE_PER_MILLE.store(spike, Ordering::Relaxed);
        SPIKE_US.store(spike_us, Ordering::Relaxed);
    }

    pub(super) fn launch_panic_per_mille() -> u32 {
        seed_serving();
        LAUNCH_PANIC_PER_MILLE.load(Ordering::Relaxed)
    }

    pub(super) fn set_launch_panic_rate(per_mille: u32) {
        seed_serving();
        LAUNCH_PANIC_PER_MILLE.store(per_mille, Ordering::Relaxed);
    }

    /// One splitmix64 step on a process-shared counter per roll;
    /// concurrent rolls just interleave the stream, which is fine for
    /// fault injection.
    pub(super) fn roll(per_mille: u32) -> bool {
        if per_mille == 0 {
            return false;
        }
        let x = RNG.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
        let mut z = x;
        z ^= z >> 30;
        z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^= z >> 27;
        z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z % 1000) < per_mille as u64
    }
}

/// The launch half shared by [`run_graph`] and [`run_graph_async`]:
/// guards, quiesce backstop, topology + counter re-arm, header
/// rewrite, and the source-burst submission. Returns the armed state
/// and this run's generation. The caller owns the completion half.
fn launch_run(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
    wake_mode: u8,
    admitted: bool,
) -> Result<(Arc<RunState>, u64), GraphError> {
    let n = graph.nodes.len();
    debug_assert!(n > 0, "empty graphs are handled by the callers");
    debug_assert!(
        pool.current_worker().is_none() && !pool.inner().on_assisting_thread(),
        "reject_run_from_worker must run before launch_run"
    );

    // Forgotten-handle backstop: `mem::forget` on a RunHandle skips
    // its blocking Drop and releases the graph borrow with the run
    // still in flight. Re-arming counters or the header under running
    // tasks would be UB, so wait for quiescence first (two loads in
    // the normal lifecycle — see the module docs).
    if let Some(state) = &graph.run_state {
        state.wait_quiesce();
    }

    let use_topo = !options.no_topology_cache;

    // (1) Topology: build the CSR arena if this run uses it and the
    //     graph is not already sealed. Boxed: the header points at it,
    //     and the box keeps that address stable even if the TaskGraph
    //     value itself is moved (reachable via a forgotten handle).
    if use_topo && graph.topology.is_none() {
        graph.topology = Some(Box::new(Topology::build(&graph.nodes)));
    }

    // (2) Reset per-run pending counters (the graph is reusable, paper
    //     §4.2 runs the same `tasks` collection repeatedly): one linear
    //     sweep over the dense array, or the per-node fallback.
    if use_topo {
        graph.topology.as_ref().unwrap().reset_pending();
    } else {
        for node in &graph.nodes {
            node.pending.store(node.num_predecessors, Ordering::Relaxed);
        }
    }

    // (2b) Duration-feedback re-rank (PR 8): still inside the
    //      quiescent window — no task of any run can be reading the
    //      schedule, and `&mut TaskGraph` proves no other launch races
    //      us — fold the observed-duration EWMAs back into the
    //      critical-path ranks when they have drifted far enough from
    //      the weights the current ranks encode. Allocation-free, so
    //      sealed re-runs keep the zero-alloc guarantee.
    if use_topo && !options.no_dynamic_rank && !options.no_critical_path {
        graph.topology.as_mut().unwrap().maybe_rerank();
    }

    // (2c) Observability spans (PR 9): clear the previous run's
    //      per-node span cells and stash the worker count for the
    //      profile's efficiency denominator — still in the quiescent
    //      window, one allocation-free linear sweep like the counter
    //      reset above.
    if use_topo {
        graph.topology.as_ref().unwrap().reset_spans(pool.num_threads());
    }

    // (3) Run state: re-arm the graph-owned slot (zero allocations on
    //     re-run), or allocate fresh for the ablation arm. Async runs
    //     always use the slot: the generation check and the forget
    //     backstop both key off it.
    let state = if options.no_state_reuse && wake_mode != WAKE_RUN_EC {
        Arc::new(RunState::new())
    } else {
        graph.run_state.get_or_insert_with(|| Arc::new(RunState::new())).clone()
    };
    // Scheduling knobs needed after `options` moves into the header.
    let critical_path = use_topo && !options.no_critical_path;
    let lanes_on = !options.no_priority_lanes;
    let class = options.priority;
    let shard = options.shard;
    let deadline = options.deadline;
    // Un-poison the slot (PR 6): drop any panic a dropped-without-wait
    // handle left unharvested and clear the previous run's abort
    // cause — both writes are in the quiescent window, so no task of
    // any run can observe them mid-flight. (A fleet [`CancelToken`] is
    // sticky by design: if it is already cancelled, this run's first
    // dispatch re-promotes it and the run aborts immediately.)
    state.panic.lock().unwrap().take();
    state.cancelled.store(CAUSE_NONE, Ordering::SeqCst);
    // Whether this run holds one of the pool's admission slots (PR 6);
    // `finish` releases it exactly once. Stored before the sources are
    // submitted so completion can never miss the release.
    state.admitted.store(admitted, Ordering::SeqCst);
    let generation = state.generation.load(Ordering::SeqCst) + 1;
    let topo_ptr: *const Topology = match (use_topo, graph.topology.as_ref()) {
        (true, Some(t)) => t.as_ref() as *const Topology,
        _ => ptr::null(),
    };
    // SAFETY: no task of a previous run can still read the header —
    // either that run's wait returned (acquiring the final `completed`
    // store) or the quiesce above did — and tasks of this run are only
    // created below, after the write. Module docs give the full
    // argument.
    unsafe {
        *state.header.get() = RunHeader {
            nodes: graph.nodes.as_ptr(),
            len: n,
            topo: topo_ptr,
            options,
        };
    }
    state.generation.store(generation, Ordering::SeqCst);
    state.wake_mode.store(wake_mode, Ordering::Relaxed);
    // Recorded for wait_quiesce's drain-vs-park decision (a Weak so a
    // lingering RunState never keeps a dropped pool's memory alive).
    *state.pool.lock().unwrap() = Arc::downgrade(pool.inner());
    // The submission below publishes this store to workers.
    state.remaining.store(n, Ordering::Relaxed);

    // Arm the deadline (PR 6) *after* the generation store — the timer
    // fires only while the generation still matches and the run is
    // incomplete, so a stale entry for a finished (or re-armed) run is
    // a no-op — and *before* the sources are submitted, so even a
    // zero-length deadline is honoured at the very first dispatch
    // boundary. The expiry itself just promotes the abort cause; the
    // skip cascade drains the run through the normal completion path.
    if let Some(after) = deadline {
        let weak = Arc::downgrade(&state);
        timer::schedule_at(
            Instant::now() + after,
            Box::new(move || {
                if let Some(state) = weak.upgrade() {
                    if state.generation.load(Ordering::SeqCst) == generation
                        && !state.is_complete(generation)
                    {
                        // The timer thread is not a pool worker, so
                        // the Abort event lands on the external lane.
                        match state.pool.lock().unwrap().upgrade() {
                            Some(pool) => {
                                state.abort_observed(CAUSE_DEADLINE, &pool);
                            }
                            None => {
                                state.abort(CAUSE_DEADLINE);
                            }
                        }
                    }
                }
            }),
        );
    }

    // (4) Submit every source (zero predecessors) as one burst — a
    //     graph with S independent sources wakes the pool once, not S
    //     times. Validation guarantees at least one source exists for a
    //     non-empty acyclic graph. The sealed path reuses the
    //     precomputed source lists (rank-ordered for critical-path
    //     runs, insertion-ordered otherwise); the fallback builds its
    //     list fresh. Lane composition matches the successor bursts
    //     (run class × node rank bucket — see `graph/schedule.rs`).
    let mk = |node: usize| {
        RawTask::node(NodeRun {
            state: state.clone(),
            node,
        })
    };
    if use_topo {
        let sched = graph.topology.as_ref().unwrap().sched();
        if critical_path || lanes_on {
            let nodes: &[usize] = if critical_path { &sched.sources_desc } else { &sched.sources };
            let buckets = sched.buckets.as_slice();
            let lane_for = move |node: usize| {
                if lanes_on {
                    lane_compose(class, Some(buckets[node]))
                } else {
                    DEFAULT_LANE
                }
            };
            pool.inner().submit_node_burst(shard, nodes, critical_path, &lane_for, &mk);
        } else {
            // Both priority behaviours off: the untouched pre-PR 4
            // submission path, bit-identical by construction.
            pool.inner()
                .submit_job_batch_sharded(shard, sched.sources.iter().map(|&node| mk(node)));
        }
    } else {
        let sources: Vec<usize> = graph
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, node)| node.num_predecessors == 0)
            .map(|(i, _)| i)
            .collect();
        // No rank information without the topology cache: sources are
        // submitted in insertion order, lane from the class alone.
        if lanes_on {
            let lane_for = move |_node: usize| lane_compose(class, None);
            pool.inner().submit_node_burst(shard, &sources, false, &lane_for, &mk);
        } else {
            pool.inner()
                .submit_job_batch_sharded(shard, sources.iter().map(|&node| mk(node)));
        }
    }
    Ok((state, generation))
}

/// Rejects a launch from inside a task of the target pool — whether
/// that task was picked up by a worker thread or by a caller-assist
/// helper. A worker blocking (or helping) on its own pool's run can
/// deadlock the pool, so this errors in every build profile, and it
/// runs before the empty-graph fast path so the answer depends only on
/// *where* the call was made, never on the graph's node count.
fn reject_run_from_worker(pool: &ThreadPool) -> Result<(), GraphError> {
    if pool.current_worker().is_some() || pool.inner().on_assisting_thread() {
        return Err(GraphError::RunFromWorker);
    }
    Ok(())
}

/// Renders the completed run's outcome (called once per run, after
/// completion): a recorded panic wins — the payload is the harder
/// fact, whichever cause won the first-writer CAS — then the abort
/// cause, else success. The cause itself is reset by the next launch.
fn take_result(graph: &TaskGraph, state: &RunState) -> Result<(), GraphError> {
    if let Some((node, payload)) = state.panic.lock().unwrap().take() {
        auto_flight_dump(graph, state);
        return Err(GraphError::NodePanicked {
            node,
            name: graph.nodes[node].name.clone(),
            payload,
        });
    }
    match state.cancelled.load(Ordering::SeqCst) {
        CAUSE_DEADLINE => {
            auto_flight_dump(graph, state);
            Err(GraphError::DeadlineExceeded)
        }
        CAUSE_CANCEL => Err(GraphError::Cancelled),
        _ => Ok(()),
    }
}

/// Automatic flight dump on run failure (PR 9): when a run surfaces
/// `NodePanicked` or `DeadlineExceeded`, snapshot the pool's flight
/// recorder so the scheduler events leading up to the failure are
/// preserved before the rings overwrite them. The dump is stashed on
/// the pool (`ThreadPool::last_flight_dump`) and — when the
/// `FLIGHT_DUMP_DIR` environment variable names a directory, as the CI
/// chaos job sets it — also written there as Chrome-trace JSON with
/// flow arrows along this graph's edges. Failure-path only; the
/// success path stays allocation-free.
fn auto_flight_dump(graph: &TaskGraph, state: &RunState) {
    let Some(pool) = state.pool.lock().unwrap().upgrade() else {
        return;
    };
    let Some(flight) = pool.flight() else {
        return;
    };
    let dump = flight.dump();
    if let Ok(dir) = std::env::var("FLIGHT_DUMP_DIR") {
        if !dir.is_empty() {
            let edges = graph.topology.as_ref().map(|t| t.edge_list()).unwrap_or_default();
            let json = dump.to_chrome_trace_with_edges(&edges);
            let gen = state.generation.load(Ordering::Relaxed);
            let name = format!(
                "flight-{}-gen{gen}.json",
                std::process::id(),
            );
            let _ = std::fs::write(std::path::Path::new(&dir).join(name), json);
        }
    }
    pool.stash_flight_dump(dump);
}

/// Admission mode of one launch (PR 6): fail fast
/// ([`TaskGraph::try_run`]) or park on the pool's budget eventcount
/// (plain `run` / `run_async`).
#[derive(Clone, Copy, PartialEq)]
enum Admission {
    Block,
    TryNow,
}

/// The PR 6 admission gate, run after the worker-thread guard and the
/// empty-graph fast path. Returns whether the run took a budget slot
/// (`false` when the pool's budget is unlimited — the default — so
/// existing behaviour is untouched). Low-class runs are shed first:
/// they see a reduced slot limit and never block, even in
/// [`Admission::Block`] mode.
///
/// PR 7 adds the deadline-infeasibility check **in front of** the
/// budget: a run whose whole deadline is already shorter than the
/// pool's observed dispatch-queue delay
/// ([`ThreadPool::queue_delay_ewma`]) is rejected with
/// [`GraphError::WouldMissDeadline`] *before* an inflight slot is
/// taken — admitting it would burn budget on work guaranteed to be
/// aborted, displacing runs that could still meet their deadlines.
/// Inert (the EWMA is zero) unless a serving front-end feeds
/// [`ThreadPool::note_queue_delay`].
fn admit_run(
    pool: &ThreadPool,
    n_tasks: usize,
    class: RunPriority,
    deadline: Option<Duration>,
    mode: Admission,
) -> Result<bool, GraphError> {
    if let Some(d) = deadline {
        // PR 9: once the pool's queue-delay histogram has enough
        // samples its p99 drives the feasibility check — a tail
        // estimate, which is what a deadline actually competes with —
        // with the EWMA kept as the cold-start fallback.
        let delay = pool.inner().queue_delay_p99().unwrap_or_else(|| {
            pool.inner().queue_delay_ewma()
        });
        if !delay.is_zero() && d <= delay {
            pool.inner().record_flight(
                pool.inner().flight_lane_of_caller(),
                EventKind::AdmitDeadline,
                class as u32,
                d.as_nanos() as u64,
            );
            return Err(GraphError::WouldMissDeadline);
        }
    }
    let low = matches!(class, RunPriority::Low);
    let block = mode == Admission::Block && !low;
    pool.inner().admit_run(n_tasks, low, block).map_err(|()| GraphError::Overloaded)
}

/// Runs `graph` on `pool`, returning once all nodes have executed (or
/// the run aborted — cancel, deadline, panic — and drained).
pub(crate) fn run_graph(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
) -> Result<(), GraphError> {
    run_graph_admitted(graph, pool, options, Admission::Block)
}

/// Fail-fast variant behind [`TaskGraph::try_run`] (PR 6): identical
/// to [`run_graph`] except an exhausted admission budget returns
/// [`GraphError::Overloaded`] immediately instead of parking.
pub(crate) fn try_run_graph(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
) -> Result<(), GraphError> {
    run_graph_admitted(graph, pool, options, Admission::TryNow)
}

fn run_graph_admitted(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
    admission: Admission,
) -> Result<(), GraphError> {
    reject_run_from_worker(pool)?;
    if graph.nodes.is_empty() {
        return Ok(());
    }
    let admitted =
        admit_run(pool, graph.nodes.len(), options.priority, options.deadline, admission)?;
    let caller_assist = !options.no_caller_assist;
    let wake_mode = if caller_assist { WAKE_EC } else { WAKE_CONDVAR };
    let (state, generation) = launch_run(graph, pool, options, wake_mode, admitted)?;

    // Wait for the run to drain. Either way this pins `graph.nodes`
    // (and the topology) for the whole run — the soundness linchpin of
    // the raw pointers above.
    if caller_assist {
        // Help instead of sleeping: execute ready tasks on this thread
        // until the run completes (see PoolInner::assist_until).
        pool.inner().assist_until(|| state.is_complete(generation));
    } else {
        state.wait_sync(generation);
    }
    take_result(graph, &state)
}

/// Launches `graph` on `pool` without blocking on completion,
/// returning a [`RunHandle`] for that half. The launch itself is
/// subject to admission control (PR 6): with a budget configured and
/// exhausted, a Normal/High launch parks on the budget eventcount
/// until a slot frees and a Low launch is shed with
/// [`GraphError::Overloaded`].
pub(crate) fn run_graph_async<'g>(
    graph: &'g mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
) -> Result<RunHandle<'g>, GraphError> {
    reject_run_from_worker(pool)?;
    if graph.nodes.is_empty() {
        // Nothing to run: hand back an already-finished handle. The
        // generation pair still advances (as a unit — no task ever
        // observes this state) so handle generations stay unique and
        // monotone, as documented, even across empty runs.
        let state = graph.run_state.get_or_insert_with(|| Arc::new(RunState::new())).clone();
        state.wait_quiesce(); // a forgotten handle's run may be in flight
        let generation = state.generation.load(Ordering::SeqCst) + 1;
        state.generation.store(generation, Ordering::SeqCst);
        state.completed.store(generation, Ordering::SeqCst);
        return Ok(RunHandle {
            graph,
            pool: pool.inner().clone(),
            state,
            generation,
            finished: true,
        });
    }
    let admitted =
        admit_run(pool, graph.nodes.len(), options.priority, options.deadline, Admission::Block)?;
    let (state, generation) = launch_run(graph, pool, options, WAKE_RUN_EC, admitted)?;
    Ok(RunHandle {
        graph,
        pool: pool.inner().clone(),
        state,
        generation,
        finished: false,
    })
}

/// Handle to one in-flight graph run, returned by
/// [`TaskGraph::run_async`].
///
/// The handle **is the run's lifetime anchor**: it holds the
/// `&mut TaskGraph` borrow for as long as it lives (so the graph can
/// be neither mutated nor dropped under running tasks), and dropping
/// it blocks until the run is quiescent. Completion can be observed
/// four ways, freely mixed:
///
/// * [`RunHandle::is_done`] — non-blocking flag check;
/// * [`RunHandle::try_wait`] — non-blocking result harvest;
/// * [`RunHandle::wait`] — block (parked on the pool's dedicated run
///   eventcount; the waiter does **not** assist);
/// * `.await` — [`RunHandle`] implements [`Future`] via a waker slot
///   on the run's done-path.
///
/// A handle is tagged with the run's **generation**: a handle from run
/// *k* of a graph reports completion for run *k* only, and can never
/// be satisfied by (or confused with) any later run of the same graph
/// (the counters are monotone; see the module docs).
///
/// Like the blocking waits, [`RunHandle::wait`] called from inside a
/// task of the *same* pool is rejected with
/// [`GraphError::RunFromWorker`] in all build profiles — a blocked
/// worker could deadlock the very run it waits for. (`Drop` in that
/// position cannot error, so it drains pool tasks instead of
/// parking — see `PoolInner::wait_run`.)
#[must_use = "dropping a RunHandle blocks until the run completes; wait() it (or keep it) instead"]
pub struct RunHandle<'g> {
    graph: &'g mut TaskGraph,
    pool: Arc<PoolInner>,
    state: Arc<RunState>,
    generation: u64,
    /// Result already delivered (or the graph was empty): every
    /// accessor short-circuits and Drop returns immediately.
    finished: bool,
}

impl RunHandle<'_> {
    /// True once this handle's run has fully completed (non-blocking).
    pub fn is_done(&self) -> bool {
        self.finished || self.state.is_complete(self.generation)
    }

    /// The run generation this handle is tagged with — monotonically
    /// increasing across runs of one graph. Exposed for diagnostics
    /// and the stale-handle tests.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Requests cooperative cancellation of this handle's run (PR 6):
    /// every node not yet started is skipped, the run drains to the
    /// normal quiescent completion, and the wait surfaces report
    /// [`GraphError::Cancelled`]. Returns immediately — wait on the
    /// handle to observe the drain. Idempotent, and a no-op once the
    /// run has completed (cancelling a finished run does not poison
    /// the result or any later run). A cancel racing the final node's
    /// completion may legitimately land either way.
    pub fn cancel(&self) {
        if self.finished || self.state.is_complete(self.generation) {
            return;
        }
        self.state.abort_observed(CAUSE_CANCEL, &self.pool);
    }

    /// Scheduling profile of this handle's run (PR 9): observed
    /// critical path vs declared ranks, busy/idle makespan breakdown,
    /// and scheduling efficiency, computed from the per-node spans the
    /// workers recorded. `None` while the run is still in flight (the
    /// spans are not yet stable), or when no spans were recorded — the
    /// pool had both its flight recorder and histograms disabled and
    /// the run opted out of duration sampling, or the topology cache
    /// was off. Non-consuming: call it after [`RunHandle::try_wait`]
    /// (or any other wait surface) reports completion.
    pub fn profile(&self) -> Option<RunProfile> {
        if !self.finished && !self.state.is_complete(self.generation) {
            return None;
        }
        self.graph.topology.as_ref()?.profile()
    }

    /// Bounded wait (PR 6): blocks until the run completes or
    /// `timeout` elapses. Returns `Some(result)` on completion — the
    /// handle is then fused like after [`RunHandle::try_wait`] — or
    /// `None` on timeout, in which case the run keeps going and the
    /// handle stays live (time out, then [`RunHandle::cancel`], then
    /// [`RunHandle::wait`] is the graceful-shutdown idiom). Backed by
    /// the same monotonic timer thread as [`RunOptions::deadline`]:
    /// the timer pokes the pool's run eventcount at the deadline, so
    /// the waiter parks instead of spin-polling. From inside a task of
    /// the same pool this returns `Some(Err(RunFromWorker))`, exactly
    /// like the other wait surfaces.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<(), GraphError>> {
        if self.pool.on_worker_thread() || self.pool.on_assisting_thread() {
            return Some(Err(GraphError::RunFromWorker));
        }
        if self.finished {
            return Some(Ok(()));
        }
        if !self.state.is_complete(self.generation) {
            let deadline = Instant::now() + timeout;
            let weak = Arc::downgrade(&self.pool);
            timer::schedule_at(
                deadline,
                Box::new(move || {
                    if let Some(pool) = weak.upgrade() {
                        pool.notify_run_waiters();
                    }
                }),
            );
            let (state, generation) = (&self.state, self.generation);
            self.pool
                .wait_run(|| state.is_complete(generation) || Instant::now() >= deadline);
            if !self.state.is_complete(self.generation) {
                return None;
            }
        }
        Some(self.harvest())
    }

    /// Non-blocking completion check: `Some(result)` once the run has
    /// finished, `None` while it is still in flight. After the result
    /// has been delivered once, keeps returning `Some(Ok(()))`.
    pub fn try_wait(&mut self) -> Option<Result<(), GraphError>> {
        if self.finished {
            return Some(Ok(()));
        }
        if !self.state.is_complete(self.generation) {
            return None;
        }
        Some(self.harvest())
    }

    /// Blocks until the run completes and returns its result. The
    /// calling thread parks on the pool's dedicated run eventcount —
    /// it does not execute pool tasks (use the blocking
    /// [`TaskGraph::run`] if you want caller assistance).
    ///
    /// Called from inside a task of the same pool this returns
    /// [`GraphError::RunFromWorker`] deterministically (even if the
    /// run already finished); the handle's `Drop` then drains the run
    /// safely.
    pub fn wait(mut self) -> Result<(), GraphError> {
        // Guard first, before even the finished short-circuit: the
        // answer must depend only on where the call was made (the
        // launch side orders its guard before the empty-graph fast
        // path for the same determinism).
        if self.pool.on_worker_thread() || self.pool.on_assisting_thread() {
            return Err(GraphError::RunFromWorker);
        }
        if self.finished {
            return Ok(());
        }
        self.wait_quiescent();
        self.harvest()
    }

    /// Blocks (or drains, on a pool-task thread — see
    /// `PoolInner::wait_run`) until this handle's run has completed.
    fn wait_quiescent(&self) {
        let (pool, state, generation) = (&self.pool, &self.state, self.generation);
        pool.wait_run(|| state.is_complete(generation));
    }

    /// Delivers the completed run's result and detaches the handle
    /// from the completion machinery (waker slot included).
    fn harvest(&mut self) -> Result<(), GraphError> {
        debug_assert!(self.state.is_complete(self.generation));
        self.finished = true;
        self.state.clear_waker();
        take_result(self.graph, &self.state)
    }
}

impl Drop for RunHandle<'_> {
    /// Blocks until the run is quiescent, so the graph borrow this
    /// handle holds cannot end (and the CSR arena cannot be freed)
    /// under running tasks. On a thread already executing a task of
    /// this pool, parking could deadlock the run, so the wait drains
    /// pool tasks instead (see `PoolInner::wait_run`). An unharvested
    /// panic stays in the state and is discarded by the next launch.
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        self.wait_quiescent();
        self.state.clear_waker();
    }
}

/// Blocks until **every** handle's run has completed, then harvests
/// them all, returning the first error encountered (in slice order).
///
/// This is the fleet combinator for `run_async` (PR 3 follow-up): the
/// waiter parks on the run eventcount of the first still-pending
/// handle's pool instead of spin-polling `is_done()`. Fleets spanning
/// several pools stay live through a timer-parked re-check chain
/// (PR 7, see `PoolInner::wait_run_backstopped`): a completion on
/// another pool never notifies this pool's eventcount, so the
/// `pool/timer.rs` min-heap thread re-wakes the waiter at 1, 2, 4, …,
/// 8 ms until the fleet drains — replacing the retired per-waiter 1 ms
/// timeout poll.
///
/// Called from inside a task of a pool that any handle targets, this
/// returns [`GraphError::RunFromWorker`] deterministically, exactly
/// like [`RunHandle::wait`] (a parked worker could deadlock the very
/// runs it waits for). An empty fleet is trivially complete.
pub fn wait_all(handles: &mut [RunHandle<'_>]) -> Result<(), GraphError> {
    // Guard first, before any completion check: the answer must depend
    // only on where the call was made (see RunHandle::wait).
    if handles.iter().any(|h| h.pool.on_worker_thread() || h.pool.on_assisting_thread()) {
        return Err(GraphError::RunFromWorker);
    }
    if let Some(pending) = handles.iter().position(|h| !h.is_done()) {
        let pool = handles[pending].pool.clone();
        if handles.iter().any(|h| !Arc::ptr_eq(&h.pool, &pool)) {
            // Multi-pool fleet: other pools' completions cannot notify
            // this pool's run eventcount — the 1 ms timer chain is the
            // functional re-check, not just a defensive backstop.
            pool.wait_run_backstopped(
                || handles.iter().all(|h| h.is_done()),
                Duration::from_millis(1),
            );
        } else {
            pool.wait_run(|| handles.iter().all(|h| h.is_done()));
        }
    }
    let mut result = Ok(());
    for h in handles.iter_mut() {
        // All runs are complete, so try_wait always harvests; keep the
        // first error but detach every handle from its run.
        if let Some(Err(e)) = h.try_wait() {
            if result.is_ok() {
                result = Err(e);
            }
        }
    }
    result
}

/// Blocks until **at least one** handle's run has completed and
/// returns its index (the lowest such index when several are already
/// done). The winner is *not* harvested — call
/// [`RunHandle::try_wait`] / [`RunHandle::wait`] on it to collect the
/// result.
///
/// Parks on the first handle's pool run eventcount instead of
/// spin-polling; multi-pool fleets ride the same timer-parked re-check
/// chain as [`wait_all`]. On a thread already executing a task of that pool the
/// wait *drains* pool tasks instead of parking (see
/// `PoolInner::wait_run`), so it cannot deadlock a single-worker pool.
///
/// # Panics
/// If `handles` is empty — there is no run whose completion could ever
/// be awaited.
pub fn wait_any(handles: &mut [RunHandle<'_>]) -> usize {
    assert!(!handles.is_empty(), "wait_any on an empty handle fleet");
    if let Some(done) = handles.iter().position(|h| h.is_done()) {
        return done;
    }
    let pool = handles[0].pool.clone();
    if handles.iter().any(|h| !Arc::ptr_eq(&h.pool, &pool)) {
        pool.wait_run_backstopped(
            || handles.iter().any(|h| h.is_done()),
            Duration::from_millis(1),
        );
    } else {
        pool.wait_run(|| handles.iter().any(|h| h.is_done()));
    }
    handles
        .iter()
        .position(|h| h.is_done())
        .expect("wait_run returned with no completed handle")
}

impl Future for RunHandle<'_> {
    type Output = Result<(), GraphError>;

    /// Completion future: registers the task's waker in the run
    /// state's slot and re-checks completion afterwards, so the
    /// completion path's store-buffering handshake (module docs)
    /// guarantees either this poll observes the finished run or the
    /// completer observes the waker. Polling after the result has been
    /// delivered returns `Ready(Ok(()))` (the handle is fused).
    ///
    /// Awaiting from inside a task of the same pool resolves to
    /// [`GraphError::RunFromWorker`], exactly like [`RunHandle::wait`]
    /// and regardless of the run's progress or delivered result:
    /// returning `Pending` there would let the executor park a worker
    /// whose queues hold the very nodes the run needs.
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        // Guard before everything else, mirroring wait(): the answer
        // must depend only on where poll was called, never on whether
        // the run happened to finish (or deliver) a moment earlier.
        if this.pool.on_worker_thread() || this.pool.on_assisting_thread() {
            return Poll::Ready(Err(GraphError::RunFromWorker));
        }
        if this.finished {
            return Poll::Ready(Ok(()));
        }
        if this.state.is_complete(this.generation) {
            return Poll::Ready(this.harvest());
        }
        this.state.register_waker(cx.waker());
        // Re-check AFTER publishing the waker: if the run completed in
        // between, the completer may have missed the flag — deliver
        // now instead of sleeping on a wakeup that will never come.
        if this.state.is_complete(this.generation) {
            return Poll::Ready(this.harvest());
        }
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::pool::ThreadPool;
    use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering::Relaxed};

    #[test]
    fn paper_arithmetic_example() {
        // (a + b) * (c + d) with the paper's dependency structure.
        let a = Arc::new(AtomicI32::new(0));
        let b = Arc::new(AtomicI32::new(0));
        let c = Arc::new(AtomicI32::new(0));
        let d = Arc::new(AtomicI32::new(0));
        let sum_ab = Arc::new(AtomicI32::new(0));
        let sum_cd = Arc::new(AtomicI32::new(0));
        let product = Arc::new(AtomicI32::new(0));

        let mut tasks = TaskGraph::new();
        let get_a = {
            let a = a.clone();
            tasks.add(move || a.store(1, Relaxed))
        };
        let get_b = {
            let b = b.clone();
            tasks.add(move || b.store(2, Relaxed))
        };
        let get_c = {
            let c = c.clone();
            tasks.add(move || c.store(3, Relaxed))
        };
        let get_d = {
            let d = d.clone();
            tasks.add(move || d.store(4, Relaxed))
        };
        let get_sum_ab = {
            let (a, b, s) = (a.clone(), b.clone(), sum_ab.clone());
            tasks.add(move || s.store(a.load(Relaxed) + b.load(Relaxed), Relaxed))
        };
        let get_sum_cd = {
            let (c, d, s) = (c.clone(), d.clone(), sum_cd.clone());
            tasks.add(move || s.store(c.load(Relaxed) + d.load(Relaxed), Relaxed))
        };
        let get_product = {
            let (x, y, p) = (sum_ab.clone(), sum_cd.clone(), product.clone());
            tasks.add(move || p.store(x.load(Relaxed) * y.load(Relaxed), Relaxed))
        };
        tasks.succeed(get_sum_ab, &[get_a, get_b]);
        tasks.succeed(get_sum_cd, &[get_c, get_d]);
        tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);

        let pool = ThreadPool::new(4);
        tasks.run(&pool).unwrap();
        assert_eq!(product.load(Relaxed), 21);
    }

    #[test]
    fn each_node_runs_exactly_once() {
        let n = 64;
        let counts: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let mut g = TaskGraph::new();
        let ids: Vec<_> = (0..n)
            .map(|i| {
                let counts = counts.clone();
                g.add(move || {
                    counts[i].fetch_add(1, Relaxed);
                })
            })
            .collect();
        // Layered dependencies: each node after the first 8 depends on
        // two earlier nodes.
        for i in 8..n {
            g.succeed(ids[i], &[ids[i - 8], ids[i - 3]]);
        }
        let pool = ThreadPool::new(3);
        g.run(&pool).unwrap();
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Relaxed), 1, "node {i}");
        }
    }

    #[test]
    fn rerun_reuses_graph_and_state() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let a = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(1, Relaxed);
            })
        };
        let b = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(10, Relaxed);
            })
        };
        g.succeed(b, &[a]);
        let pool = ThreadPool::new(2);
        for run in 1..=5 {
            g.run(&pool).unwrap();
            assert_eq!(counter.load(Relaxed), run * 11);
        }
        // The run state and topology were created once and reused.
        assert!(g.is_sealed());
        assert!(g.run_state.is_some());
    }

    #[test]
    fn chain_order_respected() {
        // A strict chain must observe strictly increasing sequence.
        let order = Arc::new(Mutex::new(Vec::<usize>::new()));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for i in 0..50 {
            let order = order.clone();
            let id = g.add(move || order.lock().unwrap().push(i));
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        let seen = order.lock().unwrap().clone();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn inline_continuation_metric_counts_chain() {
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..100 {
            let id = g.add(|| {});
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
        let inline = pool.metrics().total().inline_continuations;
        assert_eq!(inline, 99, "a 100-node chain should continue inline 99 times");
    }

    #[test]
    fn no_inline_option_still_correct() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..64 {
            let c = counter.clone();
            let id = g.add(move || {
                c.fetch_add(1, Relaxed);
            });
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(2);
        g.run_with_options(&pool, RunOptions::inline(false)).unwrap();
        assert_eq!(counter.load(Relaxed), 64);
        assert_eq!(pool.metrics().total().inline_continuations, 0);
    }

    #[test]
    fn every_toggle_combination_is_correct() {
        // The three PR 2 re-run optimizations (topology cache, state
        // reuse, caller assist) plus inline continuation must be
        // behaviour-preserving in every combination.
        let pool = ThreadPool::new(2);
        for mask in 0..16u32 {
            let options = RunOptions {
                no_inline_continuation: mask & 1 != 0,
                no_topology_cache: mask & 2 != 0,
                no_state_reuse: mask & 4 != 0,
                no_caller_assist: mask & 8 != 0,
                ..RunOptions::default()
            };
            let counter = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            // Chain of diamonds: a -> (b, c) -> d -> ...
            let mut tail: Option<crate::graph::NodeId> = None;
            for _ in 0..8 {
                let mk = |add: usize, c: &Arc<AtomicUsize>| {
                    let c = c.clone();
                    move || {
                        c.fetch_add(add, Relaxed);
                    }
                };
                let a = g.add(mk(1, &counter));
                let b = g.add(mk(1, &counter));
                let c = g.add(mk(1, &counter));
                let d = g.add(mk(1, &counter));
                g.succeed(b, &[a]);
                g.succeed(c, &[a]);
                g.succeed(d, &[b, c]);
                if let Some(t) = tail {
                    g.succeed(a, &[t]);
                }
                tail = Some(d);
            }
            for rep in 1..=3 {
                g.run_with_options(&pool, options.clone()).unwrap();
                assert_eq!(counter.load(Relaxed), rep * 32, "mask={mask:#06b} rep={rep}");
            }
        }
    }

    #[test]
    fn priority_toggles_and_classes_are_behaviour_preserving() {
        // The PR 4 knobs (critical-path dispatch, priority lanes, run
        // class) are pure scheduling hints: every combination must keep
        // exactly-once execution across re-runs, on a weighted graph.
        let pool = ThreadPool::new(2);
        for mask in 0..4u32 {
            for class in [RunPriority::High, RunPriority::Normal, RunPriority::Low] {
                let options = RunOptions {
                    no_critical_path: mask & 1 != 0,
                    no_priority_lanes: mask & 2 != 0,
                    priority: class,
                    ..RunOptions::default()
                };
                let counter = Arc::new(AtomicUsize::new(0));
                let mut g = TaskGraph::new();
                let mk = |c: &Arc<AtomicUsize>| {
                    let c = c.clone();
                    move || {
                        c.fetch_add(1, Relaxed);
                    }
                };
                let src = g.add(mk(&counter));
                let heavy = g.add_weighted(9, mk(&counter));
                let light = g.add(mk(&counter));
                let sink = g.add_weighted(3, mk(&counter));
                g.succeed(heavy, &[src]);
                g.succeed(light, &[src]);
                g.succeed(sink, &[heavy, light]);
                for rep in 1..=3 {
                    g.run_with_options(&pool, options.clone()).unwrap();
                    assert_eq!(counter.load(Relaxed), rep * 4, "mask={mask} class={class:?} rep={rep}");
                }
            }
        }
    }

    #[test]
    fn run_from_worker_errors_in_all_profiles() {
        let pool = Arc::new(ThreadPool::new(1));
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(move || {
            let mut g = TaskGraph::new();
            g.add(|| {});
            tx.send(matches!(g.run(&p), Err(GraphError::RunFromWorker))).unwrap();
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            "run from a worker task must return GraphError::RunFromWorker"
        );
        pool.wait_idle();
        // The pool (and graph runs from outside) remain usable.
        let mut g = TaskGraph::new();
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        g.add(move || {
            h.fetch_add(1, Relaxed);
        });
        g.run(&pool).unwrap();
        assert_eq!(hit.load(Relaxed), 1);
    }

    #[test]
    fn nested_run_from_a_node_errors_on_worker_and_helper_alike() {
        // A graph node that tries to run another graph on the SAME
        // pool must get RunFromWorker deterministically — no matter
        // whether a worker thread or the caller-assist helper happened
        // to execute it.
        let pool = Arc::new(ThreadPool::new(1));
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut outer = TaskGraph::new();
        outer.add(move || {
            let mut inner = TaskGraph::new();
            inner.add(|| {});
            tx.send(matches!(inner.run(&p), Err(GraphError::RunFromWorker))).unwrap();
        });
        for rep in 0..8 {
            outer.run(&pool).unwrap();
            assert!(
                rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
                "nested run must error (rep {rep})"
            );
        }
        // From a plain external thread the same pool still accepts runs.
        let mut g = TaskGraph::new();
        g.add(|| {});
        g.run(&pool).unwrap();
    }

    #[test]
    fn panicking_node_aborts_run_and_reports() {
        let after = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let bad = g.add_named("bad", || panic!("kaboom"));
        let next = {
            let after = after.clone();
            g.add(move || {
                after.fetch_add(1, Relaxed);
            })
        };
        g.succeed(next, &[bad]);
        let pool = ThreadPool::new(2);
        match g.run(&pool) {
            Err(GraphError::NodePanicked { node, name, payload }) => {
                assert_eq!(node, 0);
                assert_eq!(name.as_deref(), Some("bad"));
                assert!(payload.contains("kaboom"));
            }
            other => panic!("expected panic error, got {other:?}"),
        }
        // PR 6 abort semantics: the panicked node's successor is
        // skipped, the run still drains to quiescence, and every
        // worker is alive afterwards.
        assert_eq!(after.load(Relaxed), 0);
        pool.wait_idle();
        assert_eq!(pool.metrics().alive_workers, 2);
        // A rerun of the same (reused) state reports the fresh panic,
        // not a stale one — and the abort cause does not leak into the
        // rerun either (un-poisoned at launch).
        match g.run(&pool) {
            Err(GraphError::NodePanicked { node, .. }) => assert_eq!(node, 0),
            other => panic!("expected panic error on rerun, got {other:?}"),
        }
        assert_eq!(after.load(Relaxed), 0);
    }

    #[test]
    fn cancel_before_first_dispatch_skips_all_closures() {
        // A pre-cancelled fleet token aborts the run at the very first
        // dispatch boundary: zero closures execute, the run drains,
        // and the same graph runs clean immediately afterwards.
        let ran = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..32 {
            let ran = ran.clone();
            let id = g.add(move || {
                ran.fetch_add(1, Relaxed);
            });
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(2);
        let token = CancelToken::new();
        token.cancel();
        let options = RunOptions::new().cancel_token(token.clone());
        assert!(matches!(
            g.run_with_options(&pool, options),
            Err(GraphError::Cancelled)
        ));
        assert_eq!(ran.load(Relaxed), 0);
        pool.wait_idle();
        // Fresh token (the old one is sticky): the rerun is clean.
        g.run_with_options(&pool, RunOptions::new().cancel_token(CancelToken::new())).unwrap();
        assert_eq!(ran.load(Relaxed), 32);
    }

    #[test]
    fn empty_graph_is_a_noop() {
        let mut g = TaskGraph::new();
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
    }

    #[test]
    fn wide_fanout_fanin() {
        let sum = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let src = g.add(|| {});
        let sink = {
            let sum = sum.clone();
            g.add(move || {
                sum.fetch_add(1000, Relaxed);
            })
        };
        for _ in 0..200 {
            let sum = sum.clone();
            let mid = g.add(move || {
                sum.fetch_add(1, Relaxed);
            });
            g.succeed(mid, &[src]);
            g.succeed(sink, &[mid]);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        assert_eq!(sum.load(Relaxed), 1200);
    }

    #[test]
    fn fanout_past_ready_burst_flushes_in_batches() {
        // Fan-out far wider than READY_BURST, with inline continuation
        // disabled so every ready successor goes through the burst
        // buffer — exercising the flush-and-refill overflow path on
        // both topology modes, across reruns.
        for no_topology_cache in [false, true] {
            let options = RunOptions {
                no_inline_continuation: true,
                no_topology_cache,
                ..RunOptions::default()
            };
            let width = 4 * READY_BURST + 7;
            let sum = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            let src = g.add(|| {});
            let sink = {
                let sum = sum.clone();
                g.add(move || {
                    sum.fetch_add(1_000_000, Relaxed);
                })
            };
            for _ in 0..width {
                let sum = sum.clone();
                let mid = g.add(move || {
                    sum.fetch_add(1, Relaxed);
                });
                g.succeed(mid, &[src]);
                g.succeed(sink, &[mid]);
            }
            let pool = ThreadPool::new(3);
            for rep in 1..=3 {
                g.run_with_options(&pool, options.clone()).unwrap();
                assert_eq!(
                    sum.load(Relaxed),
                    rep * (1_000_000 + width),
                    "csr-off={no_topology_cache} rep={rep}"
                );
            }
        }
    }

    #[test]
    fn sealed_graph_survives_mutation_and_rerun() {
        // Mutating a sealed graph invalidates the CSR cache; the next
        // run rebuilds it and the new structure is honoured.
        let log = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let mut g = TaskGraph::new();
        let a = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("a"))
        };
        let b = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("b"))
        };
        g.succeed(b, &[a]);
        g.seal().unwrap();
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["a", "b"]);

        // Mutate: append c after b; the old topology must not be used.
        log.lock().unwrap().clear();
        let c = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("c"))
        };
        g.succeed(c, &[b]);
        assert!(!g.is_sealed());
        g.run(&pool).unwrap();
        assert!(g.is_sealed());
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn async_handle_completes_and_generations_advance() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let a = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(1, Relaxed);
            })
        };
        let b = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(10, Relaxed);
            })
        };
        g.succeed(b, &[a]);
        let pool = ThreadPool::new(2);
        let mut last_gen = 0;
        for run in 1..=5 {
            let h = g.run_async(&pool).unwrap();
            assert!(h.generation() > last_gen, "generations are monotone");
            last_gen = h.generation();
            h.wait().unwrap();
            assert_eq!(counter.load(Relaxed), run * 11);
        }
        // Sync and async runs share the reusable slot and the
        // generation sequence.
        g.run(&pool).unwrap();
        let h = g.run_async(&pool).unwrap();
        assert_eq!(h.generation(), last_gen + 2);
        h.wait().unwrap();
    }

    #[test]
    fn async_empty_graph_is_immediately_done() {
        let mut g = TaskGraph::new();
        let pool = ThreadPool::new(1);
        let mut h = g.run_async(&pool).unwrap();
        assert!(h.is_done());
        assert!(matches!(h.try_wait(), Some(Ok(()))));
        h.wait().unwrap();
    }
}
