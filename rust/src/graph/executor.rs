//! Task-graph execution (paper §2.2), optimized for repeated runs
//! (PR 2).
//!
//! When the pool executes a graph node it first runs the wrapped
//! closure, then for each successor decrements the uncompleted-
//! predecessor counter. The **first** successor whose counter reaches
//! zero is executed on the *same worker thread* (an inline
//! continuation — no deque traffic, no wakeup); every *other* ready
//! successor is collected into a burst buffer and published to the
//! pool as one batch (flushing and refilling the buffer for fan-outs
//! wider than [`READY_BURST`]). A linear chain therefore runs entirely
//! on one worker as a single pool job.
//!
//! # Re-run hot path (PR 2)
//!
//! The paper's §4.2 benchmarks re-run the same `tasks` collection over
//! and over; three independently toggleable optimizations make that
//! re-run path allocation-free and context-switch-free:
//!
//! 1. **CSR topology arena** ([`RunOptions::no_topology_cache`] to
//!    disable) — successor lists are flattened into one contiguous
//!    arena and pending counters into a dense cache-line-aligned array
//!    (see `builder::Topology`), built on first run or by
//!    [`TaskGraph::seal`] and reset with one linear sweep.
//! 2. **Reusable run state** ([`RunOptions::no_state_reuse`]) — the
//!    `Arc<RunState>` holding the run's remaining/panic/done machinery
//!    lives in a `TaskGraph`-owned slot and is re-armed in place, so a
//!    sealed graph's second and later `run()` calls allocate nothing
//!    (asserted by the counting-allocator test in
//!    `rust/tests/graph_alloc.rs`).
//! 3. **Caller-assisted execution** ([`RunOptions::no_caller_assist`])
//!    — instead of blocking on a condvar while workers do all the
//!    work, the thread inside `run()` registers as an ephemeral helper
//!    that executes ready tasks itself (injector first, then stealing)
//!    and parks on the pool's eventcount only when there is genuinely
//!    nothing to take. This removes one context switch per run and
//!    makes single-threaded-pool graph runs latency-competitive with a
//!    direct loop. Note the helper takes whatever the queues hold, so
//!    unrelated pool tasks may execute on the calling thread.
//!
//! # Memory-safety protocol
//!
//! [`run_graph`] returns only once `remaining == 0`, so the raw
//! node-slice and topology pointers inside [`RunState`]'s header
//! outlive every job of the run (the `&mut TaskGraph` borrow pins
//! both). Exclusive access to each node's `FnMut` closure holds
//! because (a) a node is scheduled exactly once per run — only the
//! worker that decrements its `pending` counter to zero schedules it,
//! and `fetch_sub` picks a unique such worker — and (b) all
//! predecessor effects happen-before the node via the `AcqRel`
//! decrements.
//!
//! Reusing the `RunState` across runs is sound because the mutable
//! header is rewritten only between runs, when no task of any run can
//! read it: every header read a task performs is sequenced before that
//! task's final `remaining` decrement, the caller's wakeup acquires
//! the last decrement, and the next run's header write is sequenced
//! after the wakeup — so all reads of run *k* happen-before the write
//! for run *k + 1*. Stale `Arc<RunState>` clones held briefly by
//! workers after the final decrement only drop their refcount; they
//! never touch the header again.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::builder::{GraphError, Node, TaskGraph, Topology};
use crate::pool::task::RawTask;
use crate::pool::thread_pool::PoolInner;
use crate::pool::ThreadPool;

/// Options controlling one graph run. The default is every
/// optimization ON (the paper's §2.2 behaviour plus the PR 2 re-run
/// optimizations); each `no_*` flag disables one independently for the
/// `graph_rerun`/`ablations` benches.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Execute the first ready successor inline on the same worker
    /// (paper §2.2). Disabling this resubmits *every* ready successor
    /// to the pool — the `ablations` bench quantifies the difference.
    /// (Inverted flag so `Default` means the paper's behaviour.)
    pub no_inline_continuation: bool,
    /// Disable the CSR topology arena: walk the builder's per-node
    /// successor `Vec`s and per-node `pending` counters instead (the
    /// seed's layout, kept as the ablation arm).
    pub no_topology_cache: bool,
    /// Allocate a fresh `RunState` (and, with the topology cache also
    /// off, a fresh source list) on every run instead of reusing the
    /// graph-owned slot — the seed's per-run allocation behaviour.
    pub no_state_reuse: bool,
    /// Block the calling thread on a condvar until workers finish the
    /// run, instead of letting it execute ready tasks itself.
    pub no_caller_assist: bool,
    /// Record per-node execution spans into this tracer
    /// (see [`super::Tracer`]).
    pub tracer: Option<Arc<super::Tracer>>,
}

impl RunOptions {
    /// The default behaviour: inline continuations, CSR topology,
    /// state reuse, and caller assistance all on; no tracing.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compatibility constructor used by benches/tests.
    pub fn inline(inline_continuation: bool) -> Self {
        Self {
            no_inline_continuation: !inline_continuation,
            ..Self::default()
        }
    }

    /// Toggles the CSR topology arena (PR 2 piece 1).
    pub fn topology_cache(mut self, on: bool) -> Self {
        self.no_topology_cache = !on;
        self
    }

    /// Toggles run-state reuse (PR 2 piece 2).
    pub fn state_reuse(mut self, on: bool) -> Self {
        self.no_state_reuse = !on;
        self
    }

    /// Toggles caller-assisted execution (PR 2 piece 3).
    pub fn caller_assist(mut self, on: bool) -> Self {
        self.no_caller_assist = !on;
        self
    }

    /// Attaches a tracer.
    pub fn with_tracer(mut self, tracer: Arc<super::Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }
}

/// The per-run view of the graph: raw pointers into the
/// `&mut TaskGraph` pinned by [`run_graph`], plus this run's options.
/// Rewritten at the start of every run (see the module-level protocol
/// argument for why that is race-free).
pub(crate) struct RunHeader {
    nodes: *const Node,
    len: usize,
    /// Null ⇒ the topology cache is disabled for this run; walk the
    /// builder's per-node `Vec`s instead.
    topo: *const Topology,
    options: RunOptions,
}

impl RunHeader {
    #[inline]
    fn node(&self, i: usize) -> &Node {
        debug_assert!(i < self.len);
        // SAFETY: i < len and the node slice outlives the run (module
        // docs).
        unsafe { &*self.nodes.add(i) }
    }
}

/// Shared state of one in-flight graph run, reusable across runs.
pub(crate) struct RunState {
    /// See [`RunHeader`]. Written only by `run_graph` between runs;
    /// read only by tasks of the current run.
    header: UnsafeCell<RunHeader>,
    /// Nodes not yet finished; the run is complete at zero.
    remaining: AtomicUsize,
    /// SeqCst completion flag — the caller-assist wait condition. The
    /// SeqCst store before `notify_all` and the SeqCst load after
    /// `prepare_wait` slot into the eventcount's total order, so a
    /// helper that registers after the final notify still observes
    /// `true` on its re-check (same argument as `event_count.rs`).
    done: AtomicBool,
    /// First panic observed, if any: (node index, rendered message).
    panic: Mutex<Option<(usize, String)>>,
    done_mutex: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: the pointed-to node slice and topology are pinned for the
// lifetime of the run by run_graph's blocking contract; Node is Sync
// (see builder.rs) and Topology's shared surface is atomics + shared
// slices. Header mutation is confined to the quiescent window between
// runs (module docs).
unsafe impl Send for RunState {}
unsafe impl Sync for RunState {}

impl RunState {
    pub(crate) fn new() -> Self {
        RunState {
            header: UnsafeCell::new(RunHeader {
                nodes: ptr::null(),
                len: 0,
                topo: ptr::null(),
                options: RunOptions::default(),
            }),
            remaining: AtomicUsize::new(0),
            done: AtomicBool::new(false),
            panic: Mutex::new(None),
            done_mutex: Mutex::new(false),
            done_cv: Condvar::new(),
        }
    }
}

/// A scheduled node of an in-flight run — the payload of a node
/// `RawTask` (two words: it always stores inline, never allocates).
pub(crate) struct NodeRun {
    pub(crate) state: Arc<RunState>,
    pub(crate) node: usize,
}

/// Ready successors collected per executed node before being published
/// as one submission burst. Fan-outs wider than the buffer flush it as
/// a full batch and keep filling, so arbitrarily wide fan-outs stay at
/// one counter bump + one wake per `READY_BURST` successors.
const READY_BURST: usize = 32;

/// Executes `run.node`, then chains ready successors per §2.2.
/// Called from the node-task vtable (`pool::task`) on a worker, or on
/// a caller-assist helper thread (`worker_index` is then the pool's
/// helper metrics lane).
pub(crate) fn execute_node(pool: &Arc<PoolInner>, worker_index: usize, run: NodeRun) {
    let state = run.state;
    // SAFETY: the header is immutable for the whole run this task
    // belongs to (see the module-level protocol argument).
    let header = unsafe { &*state.header.get() };
    // SAFETY: non-null topo points at the graph-owned Topology, pinned
    // like the node slice until the run completes.
    let topo: Option<&Topology> = unsafe { header.topo.as_ref() };
    let no_inline = header.options.no_inline_continuation;
    let caller_assist = !header.options.no_caller_assist;
    let mut current = run.node;
    loop {
        let node = header.node(current);

        // 1. Execute the wrapped function (paper: "it first executes
        //    the wrapped function"), containing panics so counters
        //    still advance and the run cannot deadlock.
        let span = header.options.tracer.as_ref().map(|t| {
            t.span(
                worker_index,
                match &node.name {
                    Some(n) => n.clone(),
                    None => format!("n{current}"),
                },
            )
        });
        // SAFETY: exclusive access per the module-level protocol.
        let func = unsafe { &mut *node.func.get() };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(func)) {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            let mut p = state.panic.lock().unwrap();
            if p.is_none() {
                *p = Some((current, msg));
            }
        }
        drop(span); // record the span before scheduling successors

        // 2. Decrement each successor's uncompleted-predecessor count.
        //    First ready successor continues inline; the rest are
        //    buffered and submitted as bursts (a single pending-counter
        //    bump and a single wake per burst instead of per task) —
        //    unless batched wakeups are disabled in the PoolConfig, in
        //    which case submit_job_batch degrades to the seed's
        //    per-successor submission for the ablation bench.
        let mut inline_next: Option<usize> = None;
        let mut ready = [0usize; READY_BURST];
        let mut nready = 0usize;
        {
            let mut on_ready = |succ: usize| {
                if !no_inline && inline_next.is_none() {
                    inline_next = Some(succ);
                    return;
                }
                if nready == READY_BURST {
                    // Buffer full (fan-out wider than READY_BURST):
                    // flush the whole burst as one batch and refill, so
                    // wide fan-outs keep the one-bump/one-wake batching
                    // instead of degrading to per-successor submission.
                    pool.submit_job_batch(ready.iter().map(|&node| {
                        RawTask::node(NodeRun {
                            state: state.clone(),
                            node,
                        })
                    }));
                    nready = 0;
                }
                ready[nready] = succ;
                nready += 1;
            };
            // AcqRel on the decrements: the final decrement acquires
            // every predecessor's release, ordering all predecessor
            // effects before the successor's execution.
            match topo {
                Some(t) => {
                    for &succ in t.successors(current) {
                        let succ = succ as usize;
                        if t.pending(succ).fetch_sub(1, Ordering::AcqRel) == 1 {
                            on_ready(succ);
                        }
                    }
                }
                None => {
                    for &succ in &node.successors {
                        if header.node(succ).pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                            on_ready(succ);
                        }
                    }
                }
            }
        }
        if nready > 0 {
            pool.submit_job_batch(ready[..nready].iter().map(|&node| {
                RawTask::node(NodeRun {
                    state: state.clone(),
                    node,
                })
            }));
        }

        // 3. Mark this node complete. After this point we must not
        //    touch `node`, `header`, or `topo` again: if this was the
        //    last node, run_graph may wake, invalidate the pointers,
        //    and even start the next run (rewriting the header).
        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            state.done.store(true, Ordering::SeqCst);
            if caller_assist {
                // The caller waits on the pool's eventcount; wake it
                // (workers that wake spuriously just re-park).
                pool.notify_all_workers();
            } else {
                let mut done = state.done_mutex.lock().unwrap();
                *done = true;
                drop(done);
                state.done_cv.notify_all();
            }
        }

        match inline_next {
            Some(next) => {
                pool.metrics()[worker_index].on_inline_continuation();
                current = next;
            }
            None => break,
        }
    }
}

/// Runs `graph` on `pool`, returning once all nodes have executed.
pub(crate) fn run_graph(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
) -> Result<(), GraphError> {
    let n = graph.nodes.len();
    if n == 0 {
        return Ok(());
    }
    if pool.current_worker().is_some() || pool.inner().on_assisting_thread() {
        // A worker blocking (or helping) on its own pool's run can
        // deadlock the pool; reject in every build profile. The
        // assisting-thread check keeps the answer deterministic: a
        // pool task that calls `run` on its own pool errors whether a
        // worker or a caller-assist helper happened to pick it up.
        return Err(GraphError::RunFromWorker);
    }

    let use_topo = !options.no_topology_cache;
    let caller_assist = !options.no_caller_assist;

    // (1) Topology: build the CSR arena if this run uses it and the
    //     graph is not already sealed.
    if use_topo && graph.topology.is_none() {
        graph.topology = Some(Topology::build(&graph.nodes));
    }

    // (2) Reset per-run pending counters (the graph is reusable, paper
    //     §4.2 runs the same `tasks` collection repeatedly): one linear
    //     sweep over the dense array, or the per-node fallback.
    if use_topo {
        graph.topology.as_ref().unwrap().reset_pending();
    } else {
        for node in &graph.nodes {
            node.pending.store(node.num_predecessors, Ordering::Relaxed);
        }
    }

    // (3) Run state: re-arm the graph-owned slot (zero allocations on
    //     re-run), or allocate fresh for the ablation arm.
    let state = if options.no_state_reuse {
        Arc::new(RunState::new())
    } else {
        graph.run_state.get_or_insert_with(|| Arc::new(RunState::new())).clone()
    };
    let topo_ptr: *const Topology = match (use_topo, graph.topology.as_ref()) {
        (true, Some(t)) => t as *const Topology,
        _ => ptr::null(),
    };
    // SAFETY: no task of a previous run can still read the header (its
    // reads happened-before the final `remaining` decrement we already
    // observed when that run's wait returned — module docs), and tasks
    // of this run are only created below, after the write.
    unsafe {
        *state.header.get() = RunHeader {
            nodes: graph.nodes.as_ptr(),
            len: n,
            topo: topo_ptr,
            options,
        };
    }
    state.done.store(false, Ordering::SeqCst);
    if !caller_assist {
        *state.done_mutex.lock().unwrap() = false;
    }
    // The submission below publishes this store to workers.
    state.remaining.store(n, Ordering::Relaxed);

    // (4) Submit every source (zero predecessors) as one burst — a
    //     graph with S independent sources wakes the pool once, not S
    //     times. Validation guarantees at least one source exists for a
    //     non-empty acyclic graph. The sealed path reuses the
    //     precomputed source list; the fallback builds it fresh.
    if use_topo {
        let topo = graph.topology.as_ref().unwrap();
        pool.inner().submit_job_batch(topo.sources.iter().map(|&node| {
            RawTask::node(NodeRun {
                state: state.clone(),
                node: node as usize,
            })
        }));
    } else {
        let sources: Vec<usize> = graph
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, node)| node.num_predecessors == 0)
            .map(|(i, _)| i)
            .collect();
        pool.inner().submit_job_batch(sources.iter().map(|&node| {
            RawTask::node(NodeRun {
                state: state.clone(),
                node,
            })
        }));
    }

    // (5) Wait for the run to drain. Either way this pins
    //     `graph.nodes` (and the topology) for the whole run — the
    //     soundness linchpin of the raw pointers above.
    if caller_assist {
        // Help instead of sleeping: execute ready tasks on this thread
        // until the run completes (see PoolInner::assist_until).
        pool.inner().assist_until(|| state.done.load(Ordering::SeqCst));
    } else {
        let mut done = state.done_mutex.lock().unwrap();
        while !*done {
            done = state.done_cv.wait(done).unwrap();
        }
        drop(done);
    }

    let panic = state.panic.lock().unwrap().take();
    match panic {
        None => Ok(()),
        Some((node, message)) => Err(GraphError::TaskPanicked {
            node,
            name: graph.nodes[node].name.clone(),
            message,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::pool::ThreadPool;
    use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering::Relaxed};

    #[test]
    fn paper_arithmetic_example() {
        // (a + b) * (c + d) with the paper's dependency structure.
        let a = Arc::new(AtomicI32::new(0));
        let b = Arc::new(AtomicI32::new(0));
        let c = Arc::new(AtomicI32::new(0));
        let d = Arc::new(AtomicI32::new(0));
        let sum_ab = Arc::new(AtomicI32::new(0));
        let sum_cd = Arc::new(AtomicI32::new(0));
        let product = Arc::new(AtomicI32::new(0));

        let mut tasks = TaskGraph::new();
        let get_a = {
            let a = a.clone();
            tasks.add(move || a.store(1, Relaxed))
        };
        let get_b = {
            let b = b.clone();
            tasks.add(move || b.store(2, Relaxed))
        };
        let get_c = {
            let c = c.clone();
            tasks.add(move || c.store(3, Relaxed))
        };
        let get_d = {
            let d = d.clone();
            tasks.add(move || d.store(4, Relaxed))
        };
        let get_sum_ab = {
            let (a, b, s) = (a.clone(), b.clone(), sum_ab.clone());
            tasks.add(move || s.store(a.load(Relaxed) + b.load(Relaxed), Relaxed))
        };
        let get_sum_cd = {
            let (c, d, s) = (c.clone(), d.clone(), sum_cd.clone());
            tasks.add(move || s.store(c.load(Relaxed) + d.load(Relaxed), Relaxed))
        };
        let get_product = {
            let (x, y, p) = (sum_ab.clone(), sum_cd.clone(), product.clone());
            tasks.add(move || p.store(x.load(Relaxed) * y.load(Relaxed), Relaxed))
        };
        tasks.succeed(get_sum_ab, &[get_a, get_b]);
        tasks.succeed(get_sum_cd, &[get_c, get_d]);
        tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);

        let pool = ThreadPool::new(4);
        tasks.run(&pool).unwrap();
        assert_eq!(product.load(Relaxed), 21);
    }

    #[test]
    fn each_node_runs_exactly_once() {
        let n = 64;
        let counts: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let mut g = TaskGraph::new();
        let ids: Vec<_> = (0..n)
            .map(|i| {
                let counts = counts.clone();
                g.add(move || {
                    counts[i].fetch_add(1, Relaxed);
                })
            })
            .collect();
        // Layered dependencies: each node after the first 8 depends on
        // two earlier nodes.
        for i in 8..n {
            g.succeed(ids[i], &[ids[i - 8], ids[i - 3]]);
        }
        let pool = ThreadPool::new(3);
        g.run(&pool).unwrap();
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Relaxed), 1, "node {i}");
        }
    }

    #[test]
    fn rerun_reuses_graph_and_state() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let a = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(1, Relaxed);
            })
        };
        let b = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(10, Relaxed);
            })
        };
        g.succeed(b, &[a]);
        let pool = ThreadPool::new(2);
        for run in 1..=5 {
            g.run(&pool).unwrap();
            assert_eq!(counter.load(Relaxed), run * 11);
        }
        // The run state and topology were created once and reused.
        assert!(g.is_sealed());
        assert!(g.run_state.is_some());
    }

    #[test]
    fn chain_order_respected() {
        // A strict chain must observe strictly increasing sequence.
        let order = Arc::new(Mutex::new(Vec::<usize>::new()));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for i in 0..50 {
            let order = order.clone();
            let id = g.add(move || order.lock().unwrap().push(i));
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        let seen = order.lock().unwrap().clone();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn inline_continuation_metric_counts_chain() {
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..100 {
            let id = g.add(|| {});
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
        let inline = pool.metrics().total().inline_continuations;
        assert_eq!(inline, 99, "a 100-node chain should continue inline 99 times");
    }

    #[test]
    fn no_inline_option_still_correct() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..64 {
            let c = counter.clone();
            let id = g.add(move || {
                c.fetch_add(1, Relaxed);
            });
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(2);
        g.run_with_options(&pool, RunOptions::inline(false)).unwrap();
        assert_eq!(counter.load(Relaxed), 64);
        assert_eq!(pool.metrics().total().inline_continuations, 0);
    }

    #[test]
    fn every_toggle_combination_is_correct() {
        // The three PR 2 re-run optimizations (topology cache, state
        // reuse, caller assist) plus inline continuation must be
        // behaviour-preserving in every combination.
        let pool = ThreadPool::new(2);
        for mask in 0..16u32 {
            let options = RunOptions {
                no_inline_continuation: mask & 1 != 0,
                no_topology_cache: mask & 2 != 0,
                no_state_reuse: mask & 4 != 0,
                no_caller_assist: mask & 8 != 0,
                tracer: None,
            };
            let counter = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            // Chain of diamonds: a -> (b, c) -> d -> ...
            let mut tail: Option<crate::graph::NodeId> = None;
            for _ in 0..8 {
                let mk = |add: usize, c: &Arc<AtomicUsize>| {
                    let c = c.clone();
                    move || {
                        c.fetch_add(add, Relaxed);
                    }
                };
                let a = g.add(mk(1, &counter));
                let b = g.add(mk(1, &counter));
                let c = g.add(mk(1, &counter));
                let d = g.add(mk(1, &counter));
                g.succeed(b, &[a]);
                g.succeed(c, &[a]);
                g.succeed(d, &[b, c]);
                if let Some(t) = tail {
                    g.succeed(a, &[t]);
                }
                tail = Some(d);
            }
            for rep in 1..=3 {
                g.run_with_options(&pool, options.clone()).unwrap();
                assert_eq!(counter.load(Relaxed), rep * 32, "mask={mask:#06b} rep={rep}");
            }
        }
    }

    #[test]
    fn run_from_worker_errors_in_all_profiles() {
        let pool = Arc::new(ThreadPool::new(1));
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(move || {
            let mut g = TaskGraph::new();
            g.add(|| {});
            tx.send(matches!(g.run(&p), Err(GraphError::RunFromWorker))).unwrap();
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            "run from a worker task must return GraphError::RunFromWorker"
        );
        pool.wait_idle();
        // The pool (and graph runs from outside) remain usable.
        let mut g = TaskGraph::new();
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        g.add(move || {
            h.fetch_add(1, Relaxed);
        });
        g.run(&pool).unwrap();
        assert_eq!(hit.load(Relaxed), 1);
    }

    #[test]
    fn nested_run_from_a_node_errors_on_worker_and_helper_alike() {
        // A graph node that tries to run another graph on the SAME
        // pool must get RunFromWorker deterministically — no matter
        // whether a worker thread or the caller-assist helper happened
        // to execute it.
        let pool = Arc::new(ThreadPool::new(1));
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut outer = TaskGraph::new();
        outer.add(move || {
            let mut inner = TaskGraph::new();
            inner.add(|| {});
            tx.send(matches!(inner.run(&p), Err(GraphError::RunFromWorker))).unwrap();
        });
        for rep in 0..8 {
            outer.run(&pool).unwrap();
            assert!(
                rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
                "nested run must error (rep {rep})"
            );
        }
        // From a plain external thread the same pool still accepts runs.
        let mut g = TaskGraph::new();
        g.add(|| {});
        g.run(&pool).unwrap();
    }

    #[test]
    fn panicking_node_reported_and_graph_completes() {
        let after = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let bad = g.add_named("bad", || panic!("kaboom"));
        let next = {
            let after = after.clone();
            g.add(move || {
                after.fetch_add(1, Relaxed);
            })
        };
        g.succeed(next, &[bad]);
        let pool = ThreadPool::new(2);
        match g.run(&pool) {
            Err(GraphError::TaskPanicked { node, name, message }) => {
                assert_eq!(node, 0);
                assert_eq!(name.as_deref(), Some("bad"));
                assert!(message.contains("kaboom"));
            }
            other => panic!("expected panic error, got {other:?}"),
        }
        // Successors of the panicked node still ran (documented policy).
        assert_eq!(after.load(Relaxed), 1);
        // A rerun of the same (reused) state reports the fresh panic,
        // not a stale one.
        match g.run(&pool) {
            Err(GraphError::TaskPanicked { node, .. }) => assert_eq!(node, 0),
            other => panic!("expected panic error on rerun, got {other:?}"),
        }
    }

    #[test]
    fn empty_graph_is_a_noop() {
        let mut g = TaskGraph::new();
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
    }

    #[test]
    fn wide_fanout_fanin() {
        let sum = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let src = g.add(|| {});
        let sink = {
            let sum = sum.clone();
            g.add(move || {
                sum.fetch_add(1000, Relaxed);
            })
        };
        for _ in 0..200 {
            let sum = sum.clone();
            let mid = g.add(move || {
                sum.fetch_add(1, Relaxed);
            });
            g.succeed(mid, &[src]);
            g.succeed(sink, &[mid]);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        assert_eq!(sum.load(Relaxed), 1200);
    }

    #[test]
    fn fanout_past_ready_burst_flushes_in_batches() {
        // Fan-out far wider than READY_BURST, with inline continuation
        // disabled so every ready successor goes through the burst
        // buffer — exercising the flush-and-refill overflow path on
        // both topology modes, across reruns.
        for no_topology_cache in [false, true] {
            let options = RunOptions {
                no_inline_continuation: true,
                no_topology_cache,
                ..RunOptions::default()
            };
            let width = 4 * READY_BURST + 7;
            let sum = Arc::new(AtomicUsize::new(0));
            let mut g = TaskGraph::new();
            let src = g.add(|| {});
            let sink = {
                let sum = sum.clone();
                g.add(move || {
                    sum.fetch_add(1_000_000, Relaxed);
                })
            };
            for _ in 0..width {
                let sum = sum.clone();
                let mid = g.add(move || {
                    sum.fetch_add(1, Relaxed);
                });
                g.succeed(mid, &[src]);
                g.succeed(sink, &[mid]);
            }
            let pool = ThreadPool::new(3);
            for rep in 1..=3 {
                g.run_with_options(&pool, options.clone()).unwrap();
                assert_eq!(
                    sum.load(Relaxed),
                    rep * (1_000_000 + width),
                    "csr-off={no_topology_cache} rep={rep}"
                );
            }
        }
    }

    #[test]
    fn sealed_graph_survives_mutation_and_rerun() {
        // Mutating a sealed graph invalidates the CSR cache; the next
        // run rebuilds it and the new structure is honoured.
        let log = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let mut g = TaskGraph::new();
        let a = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("a"))
        };
        let b = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("b"))
        };
        g.succeed(b, &[a]);
        g.seal().unwrap();
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        assert_eq!(*log.lock().unwrap(), vec!["a", "b"]);

        // Mutate: append c after b; the old topology must not be used.
        log.lock().unwrap().clear();
        let c = {
            let log = log.clone();
            g.add(move || log.lock().unwrap().push("c"))
        };
        g.succeed(c, &[b]);
        assert!(!g.is_sealed());
        g.run(&pool).unwrap();
        assert!(g.is_sealed());
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c"]);
    }
}
