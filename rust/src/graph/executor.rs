//! Task-graph execution (paper §2.2).
//!
//! When the pool executes a graph node it first runs the wrapped
//! closure, then for each successor decrements the uncompleted-
//! predecessor counter. The **first** successor whose counter reaches
//! zero is executed on the *same worker thread* (an inline
//! continuation — no deque traffic, no wakeup); every *other* ready
//! successor is submitted to the pool. A linear chain therefore runs
//! entirely on one worker as a single pool job.
//!
//! # Memory-safety protocol
//!
//! [`run_graph`] blocks until `remaining == 0`, so the raw node-slice
//! pointer inside [`RunState`] outlives every job of the run (the
//! `&mut TaskGraph` borrow pins the nodes). Exclusive access to each
//! node's `FnMut` closure holds because (a) a node is scheduled exactly
//! once per run — only the worker that decrements its `pending` counter
//! to zero schedules it, and `fetch_sub` picks a unique such worker —
//! and (b) all predecessor effects happen-before the node via the
//! `AcqRel` decrements.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::builder::{GraphError, Node, TaskGraph};
use crate::pool::task::RawTask;
use crate::pool::thread_pool::PoolInner;
use crate::pool::ThreadPool;

/// Options controlling one graph run.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Execute the first ready successor inline on the same worker
    /// (paper §2.2). Disabling this resubmits *every* ready successor
    /// to the pool — the `ablations` bench quantifies the difference.
    /// (Inverted flag so `Default` means the paper's behaviour.)
    pub no_inline_continuation: bool,
    /// Record per-node execution spans into this tracer
    /// (see [`super::Tracer`]).
    pub tracer: Option<Arc<super::Tracer>>,
}

impl RunOptions {
    /// The paper's §2.2 behaviour (inline continuation on, no tracing).
    pub fn new() -> Self {
        Self::default()
    }

    /// Compatibility constructor used by benches/tests.
    pub fn inline(inline_continuation: bool) -> Self {
        Self {
            no_inline_continuation: !inline_continuation,
            tracer: None,
        }
    }

    /// Attaches a tracer.
    pub fn with_tracer(mut self, tracer: Arc<super::Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }
}

/// Shared state of one in-flight graph run.
pub(crate) struct RunState {
    nodes: *const Node,
    len: usize,
    /// Nodes not yet finished; the run is complete at zero.
    remaining: AtomicUsize,
    /// First panic observed, if any: (node index, rendered message).
    panic: Mutex<Option<(usize, String)>>,
    done_mutex: Mutex<bool>,
    done_cv: Condvar,
    options: RunOptions,
}

// SAFETY: the node slice is pinned for the lifetime of the run by
// run_graph's blocking contract; Node is Sync (see builder.rs).
unsafe impl Send for RunState {}
unsafe impl Sync for RunState {}

impl RunState {
    #[inline]
    fn node(&self, i: usize) -> &Node {
        debug_assert!(i < self.len);
        // SAFETY: i < len and the slice outlives the run (see above).
        unsafe { &*self.nodes.add(i) }
    }
}

/// A scheduled node of an in-flight run — the payload of a node
/// `RawTask` (two words: it always stores inline, never allocates).
pub(crate) struct NodeRun {
    pub(crate) state: Arc<RunState>,
    pub(crate) node: usize,
}

/// Ready successors collected per executed node before being published
/// as one submission burst. Wider fan-outs spill to direct submission;
/// 32 covers every workload in the bench suite except the synthetic
/// wide-fanout tests, which exercise the spill path on purpose.
const READY_BURST: usize = 32;

/// Executes `run.node`, then chains ready successors per §2.2.
/// Called from the node-task vtable (`pool::task`) on a worker.
pub(crate) fn execute_node(pool: &Arc<PoolInner>, worker_index: usize, run: NodeRun) {
    let state = run.state;
    let mut current = run.node;
    loop {
        let node = state.node(current);

        // 1. Execute the wrapped function (paper: "it first executes
        //    the wrapped function"), containing panics so counters
        //    still advance and the run cannot deadlock.
        let span = state.options.tracer.as_ref().map(|t| {
            t.span(
                worker_index,
                match &node.name {
                    Some(n) => n.clone(),
                    None => format!("n{current}"),
                },
            )
        });
        // SAFETY: exclusive access per the module-level protocol.
        let func = unsafe { &mut *node.func.get() };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(func)) {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            let mut p = state.panic.lock().unwrap();
            if p.is_none() {
                *p = Some((current, msg));
            }
        }
        drop(span); // record the span before scheduling successors

        // 2. Decrement each successor's uncompleted-predecessor count.
        //    First ready successor continues inline; the rest are
        //    collected and submitted to the pool as ONE burst (a single
        //    pending-counter bump and a single wake for a fan-out of N,
        //    instead of N of each) — unless batched wakeups are
        //    disabled, in which case submit_job_batch degrades to the
        //    seed's per-successor submission for the ablation bench.
        let mut inline_next: Option<usize> = None;
        let mut ready = [0usize; READY_BURST];
        let mut nready = 0usize;
        for &succ in &node.successors {
            // AcqRel: the final decrement acquires every predecessor's
            // release, ordering all predecessor effects before the
            // successor's execution.
            if state.node(succ).pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                if !state.options.no_inline_continuation && inline_next.is_none() {
                    inline_next = Some(succ);
                } else if nready < READY_BURST {
                    ready[nready] = succ;
                    nready += 1;
                } else {
                    // Fan-out wider than the burst buffer (rare):
                    // overflow is submitted directly.
                    pool.submit_job(RawTask::node(NodeRun {
                        state: state.clone(),
                        node: succ,
                    }));
                }
            }
        }
        if nready > 0 {
            pool.submit_job_batch(ready[..nready].iter().map(|&node| {
                RawTask::node(NodeRun {
                    state: state.clone(),
                    node,
                })
            }));
        }

        // 3. Mark this node complete. After this point we must not
        //    touch `node` again: if it was the last one, run_graph may
        //    wake and invalidate the node slice.
        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = state.done_mutex.lock().unwrap();
            *done = true;
            drop(done);
            state.done_cv.notify_all();
        }

        match inline_next {
            Some(next) => {
                pool.metrics()[worker_index].on_inline_continuation();
                current = next;
            }
            None => break,
        }
    }
}

/// Runs `graph` on `pool`, blocking until all nodes have executed.
pub(crate) fn run_graph(
    graph: &mut TaskGraph,
    pool: &ThreadPool,
    options: RunOptions,
) -> Result<(), GraphError> {
    let n = graph.nodes.len();
    if n == 0 {
        return Ok(());
    }
    debug_assert!(
        pool.current_worker().is_none(),
        "TaskGraph::run called from a worker task of the same pool (would deadlock)"
    );

    // Reset per-run counters (the graph is reusable, paper §4.2 runs
    // the same `tasks` collection repeatedly).
    for node in &graph.nodes {
        node.pending.store(node.num_predecessors, Ordering::Relaxed);
    }

    let state = Arc::new(RunState {
        nodes: graph.nodes.as_ptr(),
        len: n,
        remaining: AtomicUsize::new(n),
        panic: Mutex::new(None),
        done_mutex: Mutex::new(false),
        done_cv: Condvar::new(),
        options,
    });

    // Submit every source (zero predecessors) as one burst — a graph
    // with S independent sources wakes the pool once, not S times.
    // Validation guarantees at least one source exists for a non-empty
    // acyclic graph.
    let sources: Vec<usize> = graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, node)| node.num_predecessors == 0)
        .map(|(i, _)| i)
        .collect();
    pool.inner().submit_job_batch(sources.iter().map(|&node| {
        RawTask::node(NodeRun {
            state: state.clone(),
            node,
        })
    }));

    // Block until the run drains. This pins `graph.nodes` for the
    // whole run — the soundness linchpin of the raw pointer above.
    let mut done = state.done_mutex.lock().unwrap();
    while !*done {
        done = state.done_cv.wait(done).unwrap();
    }
    drop(done);

    let panic = state.panic.lock().unwrap().take();
    match panic {
        None => Ok(()),
        Some((node, message)) => Err(GraphError::TaskPanicked {
            node,
            name: graph.nodes[node].name.clone(),
            message,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::pool::ThreadPool;
    use std::sync::atomic::{AtomicI32, AtomicUsize, Ordering::Relaxed};

    #[test]
    fn paper_arithmetic_example() {
        // (a + b) * (c + d) with the paper's dependency structure.
        let a = Arc::new(AtomicI32::new(0));
        let b = Arc::new(AtomicI32::new(0));
        let c = Arc::new(AtomicI32::new(0));
        let d = Arc::new(AtomicI32::new(0));
        let sum_ab = Arc::new(AtomicI32::new(0));
        let sum_cd = Arc::new(AtomicI32::new(0));
        let product = Arc::new(AtomicI32::new(0));

        let mut tasks = TaskGraph::new();
        let get_a = {
            let a = a.clone();
            tasks.add(move || a.store(1, Relaxed))
        };
        let get_b = {
            let b = b.clone();
            tasks.add(move || b.store(2, Relaxed))
        };
        let get_c = {
            let c = c.clone();
            tasks.add(move || c.store(3, Relaxed))
        };
        let get_d = {
            let d = d.clone();
            tasks.add(move || d.store(4, Relaxed))
        };
        let get_sum_ab = {
            let (a, b, s) = (a.clone(), b.clone(), sum_ab.clone());
            tasks.add(move || s.store(a.load(Relaxed) + b.load(Relaxed), Relaxed))
        };
        let get_sum_cd = {
            let (c, d, s) = (c.clone(), d.clone(), sum_cd.clone());
            tasks.add(move || s.store(c.load(Relaxed) + d.load(Relaxed), Relaxed))
        };
        let get_product = {
            let (x, y, p) = (sum_ab.clone(), sum_cd.clone(), product.clone());
            tasks.add(move || p.store(x.load(Relaxed) * y.load(Relaxed), Relaxed))
        };
        tasks.succeed(get_sum_ab, &[get_a, get_b]);
        tasks.succeed(get_sum_cd, &[get_c, get_d]);
        tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);

        let pool = ThreadPool::new(4);
        tasks.run(&pool).unwrap();
        assert_eq!(product.load(Relaxed), 21);
    }

    #[test]
    fn each_node_runs_exactly_once() {
        let n = 64;
        let counts: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let mut g = TaskGraph::new();
        let ids: Vec<_> = (0..n)
            .map(|i| {
                let counts = counts.clone();
                g.add(move || {
                    counts[i].fetch_add(1, Relaxed);
                })
            })
            .collect();
        // Layered dependencies: each node after the first 8 depends on
        // two earlier nodes.
        for i in 8..n {
            g.succeed(ids[i], &[ids[i - 8], ids[i - 3]]);
        }
        let pool = ThreadPool::new(3);
        g.run(&pool).unwrap();
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Relaxed), 1, "node {i}");
        }
    }

    #[test]
    fn rerun_reuses_graph_and_state() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let a = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(1, Relaxed);
            })
        };
        let b = {
            let c = counter.clone();
            g.add(move || {
                c.fetch_add(10, Relaxed);
            })
        };
        g.succeed(b, &[a]);
        let pool = ThreadPool::new(2);
        for run in 1..=5 {
            g.run(&pool).unwrap();
            assert_eq!(counter.load(Relaxed), run * 11);
        }
    }

    #[test]
    fn chain_order_respected() {
        // A strict chain must observe strictly increasing sequence.
        let order = Arc::new(Mutex::new(Vec::<usize>::new()));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for i in 0..50 {
            let order = order.clone();
            let id = g.add(move || order.lock().unwrap().push(i));
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        let seen = order.lock().unwrap().clone();
        assert_eq!(seen, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn inline_continuation_metric_counts_chain() {
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..100 {
            let id = g.add(|| {});
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
        let inline = pool.metrics().total().inline_continuations;
        assert_eq!(inline, 99, "a 100-node chain should continue inline 99 times");
    }

    #[test]
    fn no_inline_option_still_correct() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let mut prev: Option<crate::graph::NodeId> = None;
        for _ in 0..64 {
            let c = counter.clone();
            let id = g.add(move || {
                c.fetch_add(1, Relaxed);
            });
            if let Some(p) = prev {
                g.succeed(id, &[p]);
            }
            prev = Some(id);
        }
        let pool = ThreadPool::new(2);
        g.run_with_options(&pool, RunOptions::inline(false)).unwrap();
        assert_eq!(counter.load(Relaxed), 64);
        assert_eq!(pool.metrics().total().inline_continuations, 0);
    }

    #[test]
    fn panicking_node_reported_and_graph_completes() {
        let after = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let bad = g.add_named("bad", || panic!("kaboom"));
        let next = {
            let after = after.clone();
            g.add(move || {
                after.fetch_add(1, Relaxed);
            })
        };
        g.succeed(next, &[bad]);
        let pool = ThreadPool::new(2);
        match g.run(&pool) {
            Err(GraphError::TaskPanicked { node, name, message }) => {
                assert_eq!(node, 0);
                assert_eq!(name.as_deref(), Some("bad"));
                assert!(message.contains("kaboom"));
            }
            other => panic!("expected panic error, got {other:?}"),
        }
        // Successors of the panicked node still ran (documented policy).
        assert_eq!(after.load(Relaxed), 1);
    }

    #[test]
    fn empty_graph_is_a_noop() {
        let mut g = TaskGraph::new();
        let pool = ThreadPool::new(1);
        g.run(&pool).unwrap();
    }

    #[test]
    fn wide_fanout_fanin() {
        let sum = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::new();
        let src = g.add(|| {});
        let sink = {
            let sum = sum.clone();
            g.add(move || {
                sum.fetch_add(1000, Relaxed);
            })
        };
        for _ in 0..200 {
            let sum = sum.clone();
            let mid = g.add(move || {
                sum.fetch_add(1, Relaxed);
            });
            g.succeed(mid, &[src]);
            g.succeed(sink, &[mid]);
        }
        let pool = ThreadPool::new(4);
        g.run(&pool).unwrap();
        assert_eq!(sum.load(Relaxed), 1200);
    }
}
