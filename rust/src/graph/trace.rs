//! Execution tracing: per-node timelines for graph runs.
//!
//! A [`Tracer`] records, for every executed node, which worker ran it
//! and when (monotonic µs since the tracer was created). Export
//! formats:
//!
//! * [`Tracer::to_chrome_trace`] — Chrome/Perfetto `chrome://tracing`
//!   JSON (hand-rolled writer; the offline vendor set has no serde),
//!   one row per worker, one slice per task;
//! * [`Tracer::ascii_gantt`] — quick terminal Gantt for examples/CI.
//!
//! Recording is two `Instant::now()` calls plus one vec push into a
//! **per-thread buffer** (PR 9): each recording thread owns its own
//! event vec behind its own lock, cached in a thread-local keyed by
//! tracer id, so concurrent workers never contend on a shared mutex —
//! the lock each worker takes is its own, touched by the export side
//! only when a snapshot is taken. Export merges the per-thread
//! buffers and sorts by start time. (Earlier revisions funnelled every
//! span through one global `Mutex<Vec>`, serializing all workers on a
//! single lock; the docs promised per-worker buffers — now they exist.)
//!
//! Besides task spans, a tracer can record **shard-depth samples**
//! (PR 5): [`Tracer::sample_shard_depths`] snapshots each shard's
//! queued work from a [`crate::pool::PoolSnapshot`], and the Chrome
//! export renders them as counter tracks (`ph:"C"`) next to the task
//! slices — so a storm run shows not just *what* executed where but
//! how evenly the shards' queues were loaded while it did.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::schedule::RunPriority;
use crate::pool::PoolSnapshot;

/// One recorded task execution.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Worker index that executed the node.
    pub worker: usize,
    /// Node name (or its index rendered as text).
    pub name: String,
    /// Start, µs since tracer epoch.
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Critical-path rank of the node at execution time (PR 4) — 0
    /// when the run had no rank information (unsealed / topology cache
    /// disabled). Exported so a Chrome-trace view can check whether
    /// the critical path actually ran first.
    pub rank: u64,
    /// Priority class of the run the node belonged to.
    pub class: RunPriority,
}

/// One shard-depth probe (PR 5): how much work one shard's queues held
/// at `ts_us`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardDepthSample {
    /// Sample time, µs since tracer epoch.
    pub ts_us: u64,
    /// Shard index.
    pub shard: usize,
    /// Injector depth (all lanes).
    pub injector_depth: usize,
    /// Summed member deque depth.
    pub deque_depth: usize,
}

/// Monotone source of tracer identities, used as the thread-local
/// cache key so one thread can record into many tracers over its
/// lifetime without the caches aliasing.
static TRACER_IDS: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// This thread's cached buffer: `(tracer id, buffer)`. One-entry
    /// cache — a thread alternating between two live tracers re-looks
    /// itself up (registering a fresh buffer per switch, which the
    /// merge-at-export handles); the common case of one tracer per
    /// run hits the cache every time.
    static THREAD_BUF: RefCell<Option<(u64, Arc<ThreadBuffer>)>> = const { RefCell::new(None) };
}

/// One recording thread's private event buffer. The lock is
/// *nominally* shared but only its owning thread pushes into it;
/// export (`events`/`len`/`clear`) takes it briefly for snapshots, so
/// worker-vs-worker contention — the cost the old global
/// `Mutex<Vec>` design paid on every span — is gone by construction.
#[derive(Debug, Default)]
struct ThreadBuffer {
    events: Mutex<Vec<TraceEvent>>,
}

/// Collects [`TraceEvent`]s across a run. Shareable (`&Tracer` is
/// `Sync`); per-event cost is one push into the recording thread's own
/// buffer (see [`ThreadBuffer`] — no cross-thread lock contention).
#[derive(Debug)]
pub struct Tracer {
    id: u64,
    epoch: Instant,
    /// Registry of every thread buffer that has recorded into this
    /// tracer; export merges them. Locked only on a thread's *first*
    /// span into this tracer and on export, never per event.
    buffers: Mutex<Vec<Arc<ThreadBuffer>>>,
    depth_samples: Mutex<Vec<ShardDepthSample>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    /// Creates an empty tracer; its creation time is the timeline zero.
    pub fn new() -> Self {
        Self {
            id: TRACER_IDS.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            buffers: Mutex::new(Vec::new()),
            depth_samples: Mutex::new(Vec::new()),
        }
    }

    /// Records one shard-depth probe per shard of `snapshot` (PR 5).
    /// Call it from a sampler loop (or between benchmark phases) with
    /// `pool.metrics()`; flat pools record a single shard-0 track.
    pub fn sample_shard_depths(&self, snapshot: &PoolSnapshot) {
        let ts_us = Instant::now().duration_since(self.epoch).as_micros() as u64;
        let mut samples = self.depth_samples.lock().unwrap();
        for (shard, s) in snapshot.shards.iter().enumerate() {
            samples.push(ShardDepthSample {
                ts_us,
                shard,
                injector_depth: s.injector_depth,
                deque_depth: s.deque_depth,
            });
        }
    }

    /// Snapshot of the recorded shard-depth samples, in sample order.
    pub fn shard_depth_samples(&self) -> Vec<ShardDepthSample> {
        self.depth_samples.lock().unwrap().clone()
    }

    /// Starts a span; call [`SpanGuard::finish`] (or drop it) to record.
    /// Rank and class default to 0 / [`RunPriority::Normal`] — the
    /// graph executor uses [`Tracer::span_ranked`] to attach the node's
    /// scheduling context.
    pub fn span(&self, worker: usize, name: impl Into<String>) -> SpanGuard<'_> {
        self.span_ranked(worker, name, 0, RunPriority::Normal)
    }

    /// [`Tracer::span`] carrying the node's critical-path rank and the
    /// run's priority class (PR 4), so exported traces can show whether
    /// the critical path actually ran first.
    pub fn span_ranked(
        &self,
        worker: usize,
        name: impl Into<String>,
        rank: u64,
        class: RunPriority,
    ) -> SpanGuard<'_> {
        SpanGuard {
            tracer: self,
            worker,
            name: name.into(),
            start: Instant::now(),
            rank,
            class,
            recorded: false,
        }
    }

    /// This thread's buffer for this tracer: thread-local cache hit in
    /// the steady state; a miss (first span from this thread, or the
    /// thread switched tracers) registers a fresh buffer under the
    /// registry lock — the only cross-thread lock on the record path,
    /// taken once per thread, not per event.
    fn thread_buffer(&self) -> Arc<ThreadBuffer> {
        THREAD_BUF.with(|slot| {
            let mut slot = slot.borrow_mut();
            if let Some((id, buf)) = slot.as_ref() {
                if *id == self.id {
                    return buf.clone();
                }
            }
            let buf = Arc::new(ThreadBuffer::default());
            self.buffers.lock().unwrap().push(buf.clone());
            *slot = Some((self.id, buf.clone()));
            buf
        })
    }

    fn record(&self, worker: usize, name: String, start: Instant, end: Instant, rank: u64, class: RunPriority) {
        let start_us = start.duration_since(self.epoch).as_micros() as u64;
        let dur_us = end.duration_since(start).as_micros() as u64;
        let buf = self.thread_buffer();
        buf.events.lock().unwrap().push(TraceEvent {
            worker,
            name,
            start_us,
            dur_us,
            rank,
            class,
        });
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.buffers.lock().unwrap().iter().map(|b| b.events.lock().unwrap().len()).sum()
    }

    /// True if nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the recorded events: the per-thread buffers merged
    /// and ordered by start time. Each buffer's lock gives the
    /// happens-before edge to its recording thread, so every span
    /// whose guard finished before this call is included.
    pub fn events(&self) -> Vec<TraceEvent> {
        let buffers = self.buffers.lock().unwrap();
        let mut evs: Vec<TraceEvent> = Vec::new();
        for buf in buffers.iter() {
            evs.extend(buf.events.lock().unwrap().iter().cloned());
        }
        drop(buffers);
        evs.sort_by_key(|e| e.start_us);
        evs
    }

    /// Clears recorded events and depth samples (reuse between runs).
    /// The thread buffers themselves stay registered — threads keep
    /// their cached handles and simply start refilling them.
    pub fn clear(&self) {
        for buf in self.buffers.lock().unwrap().iter() {
            buf.events.lock().unwrap().clear();
        }
        self.depth_samples.lock().unwrap().clear();
    }

    /// Chrome trace JSON (`chrome://tracing` / Perfetto "trace event
    /// format"): complete events for task spans, counter events
    /// (`ph:"C"`, one track per shard) for the PR 5 depth samples.
    /// Strings are minimally escaped.
    pub fn to_chrome_trace(&self) -> String {
        fn escape(s: &str) -> String {
            s.chars()
                .flat_map(|c| match c {
                    '"' => vec!['\\', '"'],
                    '\\' => vec!['\\', '\\'],
                    c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                    c => vec![c],
                })
                .collect()
        }
        let mut parts: Vec<String> = self
            .events()
            .iter()
            .map(|e| {
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"task\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\
                     \"args\":{{\"rank\":{},\"class\":\"{}\"}}}}",
                    escape(&e.name),
                    e.start_us,
                    e.dur_us.max(1),
                    e.worker,
                    e.rank,
                    e.class.as_str()
                )
            })
            .collect();
        parts.extend(self.shard_depth_samples().iter().map(|s| {
            format!(
                "{{\"name\":\"shard{} depth\",\"cat\":\"shard\",\"ph\":\"C\",\"ts\":{},\"pid\":0,\
                 \"args\":{{\"injector\":{},\"deques\":{}}}}}",
                s.shard, s.ts_us, s.injector_depth, s.deque_depth
            )
        }));
        let mut out = String::from("[");
        for (i, p) in parts.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('\n');
            out.push_str(p);
        }
        out.push_str("\n]\n");
        out
    }

    /// A quick fixed-width Gantt: one row per worker, `#` marks busy
    /// time, bucketed into `width` columns.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let evs = self.events();
        if evs.is_empty() {
            return String::from("(no events)\n");
        }
        let t_end = evs.iter().map(|e| e.start_us + e.dur_us).max().unwrap().max(1);
        let workers = evs.iter().map(|e| e.worker).max().unwrap() + 1;
        let mut rows = vec![vec![' '; width]; workers];
        for e in &evs {
            let from = (e.start_us as usize * width) / t_end as usize;
            let to = (((e.start_us + e.dur_us) as usize * width) / t_end as usize).max(from + 1);
            for c in rows[e.worker][from..to.min(width)].iter_mut() {
                *c = '#';
            }
        }
        let mut out = String::new();
        out.push_str(&format!("timeline 0..{t_end}us, {} events\n", evs.len()));
        for (i, row) in rows.iter().enumerate() {
            out.push_str(&format!("w{i} |{}|\n", row.iter().collect::<String>()));
        }
        out
    }
}

/// Guard recording one span on drop/finish.
pub struct SpanGuard<'t> {
    tracer: &'t Tracer,
    worker: usize,
    name: String,
    start: Instant,
    rank: u64,
    class: RunPriority,
    recorded: bool,
}

impl SpanGuard<'_> {
    /// Records the span now.
    pub fn finish(mut self) {
        self.record_now();
    }

    fn record_now(&mut self) {
        if !self.recorded {
            self.recorded = true;
            self.tracer.record(
                self.worker,
                std::mem::take(&mut self.name),
                self.start,
                Instant::now(),
                self.rank,
                self.class,
            );
        }
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.record_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn records_spans_in_order() {
        let t = Tracer::new();
        {
            let s = t.span(0, "a");
            std::thread::sleep(Duration::from_micros(200));
            s.finish();
        }
        {
            let _s = t.span(1, "b"); // recorded on drop
            std::thread::sleep(Duration::from_micros(200));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "a");
        assert_eq!(evs[0].worker, 0);
        assert_eq!(evs[1].name, "b");
        assert!(evs[1].start_us >= evs[0].start_us);
        assert!(evs[0].dur_us >= 100);
    }

    #[test]
    fn chrome_trace_is_wellformed_json_shape() {
        let t = Tracer::new();
        t.span(0, "weird\"name\\x").finish();
        t.span(3, "plain").finish();
        let json = t.to_chrome_trace();
        assert!(json.starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\\\"name\\\\x"));
        assert!(json.contains("\"tid\":3"));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2);
        // Plain spans export neutral scheduling context.
        assert_eq!(json.matches("\"args\":{\"rank\":0,\"class\":\"normal\"}").count(), 2);
    }

    #[test]
    fn ranked_spans_carry_rank_and_class() {
        let t = Tracer::new();
        t.span_ranked(1, "critical", 42, RunPriority::High).finish();
        t.span_ranked(0, "tail", 1, RunPriority::Low).finish();
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        let crit = evs.iter().find(|e| e.name == "critical").unwrap();
        assert_eq!((crit.rank, crit.class), (42, RunPriority::High));
        let json = t.to_chrome_trace();
        assert!(json.contains("\"args\":{\"rank\":42,\"class\":\"high\"}"));
        assert!(json.contains("\"args\":{\"rank\":1,\"class\":\"low\"}"));
    }

    #[test]
    fn gantt_renders_rows_per_worker() {
        let t = Tracer::new();
        t.span(0, "a").finish();
        std::thread::sleep(Duration::from_micros(300));
        t.span(2, "b").finish();
        let g = t.ascii_gantt(40);
        assert!(g.contains("w0 |"));
        assert!(g.contains("w2 |"));
        assert!(g.contains('#'));
    }

    #[test]
    fn clear_resets() {
        let t = Tracer::new();
        t.span(0, "a").finish();
        t.sample_shard_depths(&PoolSnapshot::default());
        assert_eq!(t.len(), 1);
        t.clear();
        assert!(t.is_empty());
        assert!(t.shard_depth_samples().is_empty());
        assert_eq!(t.ascii_gantt(10), "(no events)\n");
    }

    #[test]
    fn per_thread_buffers_merge_across_threads() {
        let t = Arc::new(Tracer::new());
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for i in 0..8 {
                        t.span(w, format!("w{w}e{i}")).finish();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 32);
        let evs = t.events();
        assert_eq!(evs.len(), 32);
        assert!(evs.windows(2).all(|p| p[0].start_us <= p[1].start_us));
        t.clear();
        assert!(t.is_empty());
        // Buffers stay registered after clear; refilling still works.
        t.span(0, "again").finish();
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn one_thread_can_switch_between_tracers() {
        let a = Tracer::new();
        let b = Tracer::new();
        a.span(0, "a1").finish();
        b.span(0, "b1").finish(); // evicts a's cached buffer
        a.span(0, "a2").finish(); // re-registers with a
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 1);
        assert_eq!(a.events().iter().map(|e| e.name.as_str()).collect::<Vec<_>>(), ["a1", "a2"]);
    }

    #[test]
    fn shard_depth_samples_export_as_counter_events() {
        use crate::pool::ShardSnapshot;
        let t = Tracer::new();
        let snap = PoolSnapshot {
            workers: Vec::new(),
            shards: vec![
                ShardSnapshot {
                    injector_depth: 3,
                    deque_depth: 1,
                    ..ShardSnapshot::default()
                },
                ShardSnapshot::default(),
            ],
            ..PoolSnapshot::default()
        };
        t.sample_shard_depths(&snap);
        let samples = t.shard_depth_samples();
        assert_eq!(samples.len(), 2);
        assert_eq!((samples[0].shard, samples[0].injector_depth, samples[0].deque_depth), (0, 3, 1));
        assert_eq!(samples[1].shard, 1);
        let json = t.to_chrome_trace();
        assert!(json.contains("\"name\":\"shard0 depth\""));
        assert!(json.contains("\"ph\":\"C\""));
        assert!(json.contains("\"args\":{\"injector\":3,\"deques\":1}"));
        // Mixed spans + counters stay comma-separated well-formed.
        t.span(0, "task").finish();
        let json = t.to_chrome_trace();
        assert_eq!(json.matches("\"ph\":\"C\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 1);
    }
}
