//! Typed dataflow on top of [`TaskGraph`] — values flow along edges.
//!
//! The paper's tasks are `void()` closures communicating through
//! captures (§4.2); this module is the "new features can be added
//! easily" (§1) extension: each node *returns* a value, dependencies
//! are declared by consuming other nodes' [`Output`] handles, and the
//! dependency edges are derived automatically. The underlying execution
//! is the unmodified §2.2 protocol.
//!
//! ```
//! use scheduling::graph::Dataflow;
//! use scheduling::pool::ThreadPool;
//!
//! let mut df = Dataflow::new();
//! let a = df.node("a", || 1);
//! let b = df.node("b", || 2);
//! let c = df.node("c", || 3);
//! let d = df.node("d", || 4);
//! let ab = df.node2("a+b", &a, &b, |x, y| x + y);
//! let cd = df.node2("c+d", &c, &d, |x, y| x + y);
//! let product = df.node2("(a+b)*(c+d)", &ab, &cd, |x, y| x * y);
//! let pool = ThreadPool::new(2);
//! df.run(&pool).unwrap();
//! assert_eq!(product.take().unwrap(), 21);
//! ```

use std::sync::{Arc, Mutex};

use super::builder::{GraphError, NodeId, TaskGraph};
use super::executor::RunOptions;
use crate::pool::ThreadPool;

/// Errors specific to dataflow graphs.
#[derive(Debug)]
pub enum DataflowError {
    /// The output was read before the graph ran (or was already taken).
    NotProduced,
    /// The underlying graph failed.
    Graph(GraphError),
}

impl std::fmt::Display for DataflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataflowError::NotProduced => write!(f, "output not produced yet (run the graph first)"),
            DataflowError::Graph(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DataflowError {}

impl From<GraphError> for DataflowError {
    fn from(e: GraphError) -> Self {
        DataflowError::Graph(e)
    }
}

struct Slot<T>(Mutex<Option<T>>);

/// Handle to a node's typed result. Cloneable; also usable as an input
/// to downstream nodes.
pub struct Output<T> {
    slot: Arc<Slot<T>>,
    id: NodeId,
}

/// Alias emphasizing the consuming side.
pub type Input<T> = Output<T>;

impl<T> Clone for Output<T> {
    fn clone(&self) -> Self {
        Output {
            slot: self.slot.clone(),
            id: self.id,
        }
    }
}

impl<T> Output<T> {
    /// The underlying graph node (for mixing with raw [`TaskGraph`]
    /// dependencies via [`Dataflow::graph_mut`]).
    pub fn id(&self) -> NodeId {
        self.id
    }

    /// Takes the produced value out of the slot.
    pub fn take(&self) -> Result<T, DataflowError> {
        self.slot.0.lock().unwrap().take().ok_or(DataflowError::NotProduced)
    }

    /// Clones the produced value, leaving it in place (for re-runs and
    /// multiple readers).
    pub fn get(&self) -> Result<T, DataflowError>
    where
        T: Clone,
    {
        self.slot.0.lock().unwrap().clone().ok_or(DataflowError::NotProduced)
    }
}

/// Builder for typed dataflow graphs (see module docs).
#[derive(Default)]
pub struct Dataflow {
    graph: TaskGraph,
}

impl Dataflow {
    /// Creates an empty dataflow graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// A source node: produces a value from nothing.
    pub fn node<T, F>(&mut self, name: &str, mut f: F) -> Output<T>
    where
        T: Send + 'static,
        F: FnMut() -> T + Send + 'static,
    {
        let slot = Arc::new(Slot(Mutex::new(None)));
        let s = slot.clone();
        let id = self.graph.add_named(name, move || {
            *s.0.lock().unwrap() = Some(f());
        });
        Output { slot, id }
    }

    /// A unary node: consumes one upstream output (cloned from its
    /// slot, so the upstream value stays available to other readers).
    pub fn node1<A, T, F>(&mut self, name: &str, a: &Output<A>, mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(A) -> T + Send + 'static,
    {
        let slot = Arc::new(Slot(Mutex::new(None)));
        let s = slot.clone();
        let ain = a.clone();
        let id = self.graph.add_named(name, move || {
            let av = ain.slot.0.lock().unwrap().clone().expect("predecessor value missing");
            *s.0.lock().unwrap() = Some(f(av));
        });
        self.graph.succeed(id, &[a.id]);
        Output { slot, id }
    }

    /// A binary node: consumes two upstream outputs.
    pub fn node2<A, B, T, F>(&mut self, name: &str, a: &Output<A>, b: &Output<B>, mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        B: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(A, B) -> T + Send + 'static,
    {
        let slot = Arc::new(Slot(Mutex::new(None)));
        let s = slot.clone();
        let (ain, bin) = (a.clone(), b.clone());
        let id = self.graph.add_named(name, move || {
            let av = ain.slot.0.lock().unwrap().clone().expect("predecessor value missing");
            let bv = bin.slot.0.lock().unwrap().clone().expect("predecessor value missing");
            *s.0.lock().unwrap() = Some(f(av, bv));
        });
        self.graph.succeed(id, &[a.id, b.id]);
        Output { slot, id }
    }

    /// An n-ary reduction over homogeneous inputs.
    pub fn collect<A, T, F>(&mut self, name: &str, inputs: &[Output<A>], mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(Vec<A>) -> T + Send + 'static,
    {
        let slot = Arc::new(Slot(Mutex::new(None)));
        let s = slot.clone();
        let ins: Vec<Output<A>> = inputs.to_vec();
        let id = self.graph.add_named(name, move || {
            let vals: Vec<A> = ins
                .iter()
                .map(|i| i.slot.0.lock().unwrap().clone().expect("predecessor value missing"))
                .collect();
            *s.0.lock().unwrap() = Some(f(vals));
        });
        let dep_ids: Vec<NodeId> = inputs.iter().map(|i| i.id).collect();
        self.graph.succeed(id, &dep_ids);
        Output { slot, id }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    /// True if there are no nodes.
    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Escape hatch to the underlying [`TaskGraph`] (e.g. to add
    /// ordering-only edges).
    pub fn graph_mut(&mut self) -> &mut TaskGraph {
        &mut self.graph
    }

    /// Runs the dataflow on `pool`, blocking until complete.
    pub fn run(&mut self, pool: &ThreadPool) -> Result<(), DataflowError> {
        Ok(self.graph.run(pool)?)
    }

    /// [`Dataflow::run`] with explicit [`RunOptions`].
    pub fn run_with_options(&mut self, pool: &ThreadPool, options: RunOptions) -> Result<(), DataflowError> {
        Ok(self.graph.run_with_options(pool, options)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_pipeline() {
        let mut df = Dataflow::new();
        let a = df.node("a", || 2.0f64);
        let b = df.node1("sqrt", &a, |x| x.sqrt());
        let c = df.node1("square", &b, |x| x * x);
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert!((c.take().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn take_before_run_errors() {
        let mut df = Dataflow::new();
        let a = df.node("a", || 5);
        assert!(matches!(a.take(), Err(DataflowError::NotProduced)));
        let pool = ThreadPool::new(1);
        df.run(&pool).unwrap();
        assert_eq!(a.take().unwrap(), 5);
        // Taken: gone now.
        assert!(matches!(a.take(), Err(DataflowError::NotProduced)));
    }

    #[test]
    fn collect_reduces_fanout() {
        let mut df = Dataflow::new();
        let parts: Vec<_> = (0..10).map(|i| df.node("part", move || i as u64)).collect();
        let total = df.collect("sum", &parts, |vs| vs.iter().sum::<u64>());
        let pool = ThreadPool::new(3);
        df.run(&pool).unwrap();
        assert_eq!(total.take().unwrap(), 45);
    }

    #[test]
    fn rerun_produces_fresh_values() {
        let mut df = Dataflow::new();
        let mut counter = 0u32;
        let a = df.node("tick", move || {
            counter += 1;
            counter
        });
        let doubled = df.node1("double", &a, |x| x * 2);
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert_eq!(doubled.get().unwrap(), 2);
        df.run(&pool).unwrap();
        assert_eq!(doubled.get().unwrap(), 4);
    }

    #[test]
    fn get_allows_multiple_readers() {
        let mut df = Dataflow::new();
        let a = df.node("a", || String::from("shared"));
        let up = df.node1("upper", &a, |s| s.to_uppercase());
        let len = df.node1("len", &a, |s| s.len());
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert_eq!(up.get().unwrap(), "SHARED");
        assert_eq!(len.get().unwrap(), 6);
        assert_eq!(a.get().unwrap(), "shared");
    }
}
