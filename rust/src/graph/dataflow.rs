//! Typed dataflow on top of [`TaskGraph`] — values flow along edges.
//!
//! The paper's tasks are `void()` closures communicating through
//! captures (§4.2); this module is the "new features can be added
//! easily" (§1) extension: each node *returns* a value, dependencies
//! are declared by consuming other nodes' [`Output`] handles, and the
//! dependency edges are derived automatically. The underlying execution
//! is the unmodified §2.2 protocol.
//!
//! Values are **generation-stamped** (PR 10): every run attempt bumps
//! the dataflow's epoch, every node stamps its slot with the epoch it
//! ran under, and [`Output::take`]/[`Output::get`] only surface values
//! whose stamp matches — so a cancelled, panicked, or otherwise aborted
//! run can never serve a *previous* run's value as if fresh; stale
//! reads return [`DataflowError::NotProduced`]. Outputs are therefore
//! valid exactly for the last **successful** run.
//!
//! Two node families trade copying for reuse:
//!
//! * [`node`]/[`node1`]/[`node2`]/[`collect`] are by-value — each
//!   consumer deep-clones its inputs out of the upstream slots.
//!   Simple, and the right call for small values.
//! * [`node_inplace`]/[`node1_inplace`]/[`node2_inplace`] are
//!   **buffer-recycling**: inputs are *borrowed* from the upstream
//!   slots (no clone) and the node's kernel writes into its own
//!   retained output buffer, allocated once by `init` on the first run
//!   and reused thereafter. A sealed dataflow built from these makes
//!   **zero heap allocations** on re-runs, tensor payloads included —
//!   proven by the `graph_alloc` counting-allocator tier.
//!
//! ```
//! use scheduling::graph::Dataflow;
//! use scheduling::pool::ThreadPool;
//!
//! let mut df = Dataflow::new();
//! let a = df.node("a", || 1);
//! let b = df.node("b", || 2);
//! let c = df.node("c", || 3);
//! let d = df.node("d", || 4);
//! let ab = df.node2("a+b", &a, &b, |x, y| x + y);
//! let cd = df.node2("c+d", &c, &d, |x, y| x + y);
//! let product = df.node2("(a+b)*(c+d)", &ab, &cd, |x, y| x * y);
//! let pool = ThreadPool::new(2);
//! df.run(&pool).unwrap();
//! assert_eq!(product.take().unwrap(), 21);
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::builder::{GraphError, NodeId, TaskGraph};
use super::executor::RunOptions;
use crate::pool::ThreadPool;

/// Errors specific to dataflow graphs.
#[derive(Debug)]
pub enum DataflowError {
    /// The output was read before the graph ran, was already taken, or
    /// belongs to a run that aborted before this node executed.
    NotProduced,
    /// The underlying graph failed.
    Graph(GraphError),
}

impl std::fmt::Display for DataflowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataflowError::NotProduced => write!(f, "output not produced yet (run the graph first)"),
            DataflowError::Graph(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DataflowError {}

impl From<GraphError> for DataflowError {
    fn from(e: GraphError) -> Self {
        DataflowError::Graph(e)
    }
}

/// Slot payload: the value plus the epoch it was produced under.
struct SlotInner<T> {
    value: Option<T>,
    gen: u64,
}

struct Slot<T>(Mutex<SlotInner<T>>);

impl<T> Slot<T> {
    fn new() -> Arc<Self> {
        Arc::new(Slot(Mutex::new(SlotInner {
            value: None,
            gen: 0,
        })))
    }
}

/// Locks a slot, shrugging off poison: a panicking node body aborts
/// its *run* (PR 6 quarantine), and the generation stamp already
/// guards readers against half-produced state — poisoning the mutex
/// on top of that would wedge every later run of the same graph.
fn lock_slot<T>(slot: &Slot<T>) -> MutexGuard<'_, SlotInner<T>> {
    slot.0.lock().unwrap_or_else(|e| e.into_inner())
}

/// Handle to a node's typed result. Cloneable; also usable as an input
/// to downstream nodes.
pub struct Output<T> {
    slot: Arc<Slot<T>>,
    id: NodeId,
    epoch: Arc<AtomicU64>,
}

/// Alias emphasizing the consuming side.
pub type Input<T> = Output<T>;

impl<T> Clone for Output<T> {
    fn clone(&self) -> Self {
        Output {
            slot: self.slot.clone(),
            id: self.id,
            epoch: self.epoch.clone(),
        }
    }
}

impl<T> Output<T> {
    /// The underlying graph node (for mixing with raw [`TaskGraph`]
    /// dependencies via [`Dataflow::graph_mut`]).
    pub fn id(&self) -> NodeId {
        self.id
    }

    /// Takes the produced value out of the slot. Inplace consumers of
    /// this output rely on the value staying in place — prefer
    /// [`get`](Output::get) when the node feeds an `*_inplace` node.
    pub fn take(&self) -> Result<T, DataflowError> {
        let mut inner = lock_slot(&self.slot);
        if inner.gen != self.epoch.load(Ordering::SeqCst) {
            return Err(DataflowError::NotProduced);
        }
        inner.value.take().ok_or(DataflowError::NotProduced)
    }

    /// Clones the produced value, leaving it in place (for re-runs and
    /// multiple readers).
    pub fn get(&self) -> Result<T, DataflowError>
    where
        T: Clone,
    {
        let inner = lock_slot(&self.slot);
        if inner.gen != self.epoch.load(Ordering::SeqCst) {
            return Err(DataflowError::NotProduced);
        }
        inner.value.clone().ok_or(DataflowError::NotProduced)
    }
}

/// Builder for typed dataflow graphs (see module docs).
#[derive(Default)]
pub struct Dataflow {
    graph: TaskGraph,
    /// Bumped once per run attempt; node slots stamp the epoch they
    /// produced under, and reads require a match.
    epoch: Arc<AtomicU64>,
}

impl Dataflow {
    /// Creates an empty dataflow graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// A source node: produces a value from nothing.
    pub fn node<T, F>(&mut self, name: &str, mut f: F) -> Output<T>
    where
        T: Send + 'static,
        F: FnMut() -> T + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let id = self.graph.add_named(name, move || {
            let v = f();
            let mut inner = lock_slot(&s);
            inner.value = Some(v);
            inner.gen = ep.load(Ordering::SeqCst);
        });
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// A unary node: consumes one upstream output (cloned from its
    /// slot, so the upstream value stays available to other readers).
    pub fn node1<A, T, F>(&mut self, name: &str, a: &Output<A>, mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(A) -> T + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let ain = a.clone();
        let id = self.graph.add_named(name, move || {
            let av = lock_slot(&ain.slot).value.clone().expect("predecessor value missing");
            let v = f(av);
            let mut inner = lock_slot(&s);
            inner.value = Some(v);
            inner.gen = ep.load(Ordering::SeqCst);
        });
        self.graph.succeed(id, &[a.id]);
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// A binary node: consumes two upstream outputs.
    pub fn node2<A, B, T, F>(&mut self, name: &str, a: &Output<A>, b: &Output<B>, mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        B: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(A, B) -> T + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let (ain, bin) = (a.clone(), b.clone());
        let id = self.graph.add_named(name, move || {
            let av = lock_slot(&ain.slot).value.clone().expect("predecessor value missing");
            let bv = lock_slot(&bin.slot).value.clone().expect("predecessor value missing");
            let v = f(av, bv);
            let mut inner = lock_slot(&s);
            inner.value = Some(v);
            inner.gen = ep.load(Ordering::SeqCst);
        });
        self.graph.succeed(id, &[a.id, b.id]);
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// An n-ary reduction over homogeneous inputs.
    pub fn collect<A, T, F>(&mut self, name: &str, inputs: &[Output<A>], mut f: F) -> Output<T>
    where
        A: Clone + Send + 'static,
        T: Send + 'static,
        F: FnMut(Vec<A>) -> T + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let ins: Vec<Output<A>> = inputs.to_vec();
        let id = self.graph.add_named(name, move || {
            let vals: Vec<A> = ins
                .iter()
                .map(|i| lock_slot(&i.slot).value.clone().expect("predecessor value missing"))
                .collect();
            let v = f(vals);
            let mut inner = lock_slot(&s);
            inner.value = Some(v);
            inner.gen = ep.load(Ordering::SeqCst);
        });
        let dep_ids: Vec<NodeId> = inputs.iter().map(|i| i.id).collect();
        self.graph.succeed(id, &dep_ids);
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// A buffer-recycling source: `init` allocates the output once (on
    /// the first run), and `f` refills it in place on every run. After
    /// sealing, re-runs of this node make no heap allocations.
    pub fn node_inplace<T, I, F>(&mut self, name: &str, mut init: I, mut f: F) -> Output<T>
    where
        T: Send + 'static,
        I: FnMut() -> T + Send + 'static,
        F: FnMut(&mut T) + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let id = self.graph.add_named(name, move || {
            let mut inner = lock_slot(&s);
            if inner.value.is_none() {
                inner.value = Some(init());
            }
            f(inner.value.as_mut().expect("just initialized"));
            inner.gen = ep.load(Ordering::SeqCst);
        });
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// A buffer-recycling unary node: the upstream value is *borrowed*
    /// (no clone — safe because the predecessor completed
    /// happens-before and slots are mutex-guarded), and `f` writes
    /// into the retained output buffer.
    ///
    /// Don't [`take`](Output::take) an output that feeds an inplace
    /// consumer between runs — the borrow expects the value in place
    /// (the node panics with "predecessor value missing", aborting
    /// that run like any node panic).
    pub fn node1_inplace<A, T, I, F>(
        &mut self,
        name: &str,
        a: &Output<A>,
        mut init: I,
        mut f: F,
    ) -> Output<T>
    where
        A: Send + 'static,
        T: Send + 'static,
        I: FnMut() -> T + Send + 'static,
        F: FnMut(&A, &mut T) + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let ain = a.clone();
        let id = self.graph.add_named(name, move || {
            // Upstream lock is held across the kernel: the only other
            // contenders are sibling consumers (readers of a finished
            // value) and external `take`/`get` calls, never a lock
            // cycle — every node locks upstreams before its own slot.
            let a_inner = lock_slot(&ain.slot);
            let av = a_inner.value.as_ref().expect("predecessor value missing");
            let mut inner = lock_slot(&s);
            if inner.value.is_none() {
                inner.value = Some(init());
            }
            f(av, inner.value.as_mut().expect("just initialized"));
            inner.gen = ep.load(Ordering::SeqCst);
        });
        self.graph.succeed(id, &[a.id]);
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// A buffer-recycling binary node: both upstream values borrowed,
    /// output written in place (see [`node1_inplace`](Dataflow::node1_inplace)).
    pub fn node2_inplace<A, B, T, I, F>(
        &mut self,
        name: &str,
        a: &Output<A>,
        b: &Output<B>,
        mut init: I,
        mut f: F,
    ) -> Output<T>
    where
        A: Send + 'static,
        B: Send + 'static,
        T: Send + 'static,
        I: FnMut() -> T + Send + 'static,
        F: FnMut(&A, &B, &mut T) + Send + 'static,
    {
        let slot = Slot::new();
        let (s, ep) = (slot.clone(), self.epoch.clone());
        let (ain, bin) = (a.clone(), b.clone());
        let id = self.graph.add_named(name, move || {
            let a_inner = lock_slot(&ain.slot);
            let av = a_inner.value.as_ref().expect("predecessor value missing");
            let b_inner = lock_slot(&bin.slot);
            let bv = b_inner.value.as_ref().expect("predecessor value missing");
            let mut inner = lock_slot(&s);
            if inner.value.is_none() {
                inner.value = Some(init());
            }
            f(av, bv, inner.value.as_mut().expect("just initialized"));
            inner.gen = ep.load(Ordering::SeqCst);
        });
        self.graph.succeed(id, &[a.id, b.id]);
        Output {
            slot,
            id,
            epoch: self.epoch.clone(),
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    /// True if there are no nodes.
    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Escape hatch to the underlying [`TaskGraph`] (e.g. to add
    /// ordering-only edges).
    pub fn graph_mut(&mut self) -> &mut TaskGraph {
        &mut self.graph
    }

    /// Runs the dataflow on `pool`, blocking until complete.
    ///
    /// Every call — successful or not — starts a new epoch, so after
    /// an aborted run ([`GraphError::Cancelled`], a node panic, a
    /// missed deadline) *all* outputs read as
    /// [`DataflowError::NotProduced`] until the next successful run,
    /// including nodes the aborted run never reached.
    pub fn run(&mut self, pool: &ThreadPool) -> Result<(), DataflowError> {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        Ok(self.graph.run(pool)?)
    }

    /// [`Dataflow::run`] with explicit [`RunOptions`].
    pub fn run_with_options(&mut self, pool: &ThreadPool, options: RunOptions) -> Result<(), DataflowError> {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        Ok(self.graph.run_with_options(pool, options)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CancelToken;

    #[test]
    fn arithmetic_pipeline() {
        let mut df = Dataflow::new();
        let a = df.node("a", || 2.0f64);
        let b = df.node1("sqrt", &a, |x| x.sqrt());
        let c = df.node1("square", &b, |x| x * x);
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert!((c.take().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn take_before_run_errors() {
        let mut df = Dataflow::new();
        let a = df.node("a", || 5);
        assert!(matches!(a.take(), Err(DataflowError::NotProduced)));
        let pool = ThreadPool::new(1);
        df.run(&pool).unwrap();
        assert_eq!(a.take().unwrap(), 5);
        // Taken: gone now.
        assert!(matches!(a.take(), Err(DataflowError::NotProduced)));
    }

    #[test]
    fn collect_reduces_fanout() {
        let mut df = Dataflow::new();
        let parts: Vec<_> = (0..10).map(|i| df.node("part", move || i as u64)).collect();
        let total = df.collect("sum", &parts, |vs| vs.iter().sum::<u64>());
        let pool = ThreadPool::new(3);
        df.run(&pool).unwrap();
        assert_eq!(total.take().unwrap(), 45);
    }

    #[test]
    fn rerun_produces_fresh_values() {
        let mut df = Dataflow::new();
        let mut counter = 0u32;
        let a = df.node("tick", move || {
            counter += 1;
            counter
        });
        let doubled = df.node1("double", &a, |x| x * 2);
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert_eq!(doubled.get().unwrap(), 2);
        df.run(&pool).unwrap();
        assert_eq!(doubled.get().unwrap(), 4);
    }

    #[test]
    fn get_allows_multiple_readers() {
        let mut df = Dataflow::new();
        let a = df.node("a", || String::from("shared"));
        let up = df.node1("upper", &a, |s| s.to_uppercase());
        let len = df.node1("len", &a, |s| s.len());
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert_eq!(up.get().unwrap(), "SHARED");
        assert_eq!(len.get().unwrap(), 6);
        assert_eq!(a.get().unwrap(), "shared");
    }

    /// The PR 10 stale-value fix: a cancelled run must not let readers
    /// see the previous run's values as if freshly produced.
    #[test]
    fn aborted_run_invalidates_previous_values() {
        let mut df = Dataflow::new();
        let a = df.node("a", || 7u32);
        let b = df.node1("b", &a, |x| x + 1);
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        assert_eq!(b.get().unwrap(), 8);

        let token = CancelToken::new();
        token.cancel();
        let err = df
            .run_with_options(&pool, RunOptions::new().cancel_token(token))
            .unwrap_err();
        assert!(matches!(err, DataflowError::Graph(GraphError::Cancelled)));
        // The old values are still physically in the slots, but they
        // belong to a previous generation — reads must refuse them.
        assert!(matches!(b.get(), Err(DataflowError::NotProduced)));
        assert!(matches!(a.take(), Err(DataflowError::NotProduced)));

        // A later successful run revalidates everything.
        df.run(&pool).unwrap();
        assert_eq!(b.get().unwrap(), 8);
    }

    /// Panicking nodes abort the run; the un-poisoning slot locks keep
    /// the graph reusable, and stale reads stay invisible.
    #[test]
    fn panicked_run_invalidates_and_recovers() {
        let mut df = Dataflow::new();
        let mut boom = true;
        let a = df.node("a", move || {
            if boom {
                boom = false;
                panic!("first run fails");
            }
            3u64
        });
        let b = df.node1("b", &a, |x| x * 10);
        let pool = ThreadPool::new(2);
        let err = df.run(&pool).unwrap_err();
        assert!(matches!(
            err,
            DataflowError::Graph(GraphError::NodePanicked { .. })
        ));
        assert!(matches!(b.get(), Err(DataflowError::NotProduced)));
        df.run(&pool).unwrap();
        assert_eq!(b.get().unwrap(), 30);
    }

    /// Inplace nodes keep refilling the same buffer: the Vec's heap
    /// allocation must survive across re-runs.
    #[test]
    fn inplace_nodes_recycle_buffers() {
        let mut df = Dataflow::new();
        let mut tick = 0.0f32;
        let src = df.node_inplace(
            "src",
            || vec![0.0f32; 1024],
            move |buf: &mut Vec<f32>| {
                tick += 1.0;
                for v in buf.iter_mut() {
                    *v = tick;
                }
            },
        );
        let addrs = Arc::new(Mutex::new(Vec::new()));
        let rec = addrs.clone();
        let scaled = df.node1_inplace(
            "scale",
            &src,
            || vec![0.0f32; 1024],
            move |a: &Vec<f32>, out: &mut Vec<f32>| {
                rec.lock().unwrap().push(out.as_ptr() as usize);
                for (o, v) in out.iter_mut().zip(a) {
                    *o = v * 2.0;
                }
            },
        );
        let pool = ThreadPool::new(2);
        df.graph_mut().seal().unwrap();
        for pass in 1..=3 {
            df.run(&pool).unwrap();
            assert_eq!(scaled.get().unwrap()[0], 2.0 * pass as f32);
        }
        let addrs = addrs.lock().unwrap();
        assert_eq!(addrs.len(), 3);
        assert!(
            addrs.iter().all(|&a| a == addrs[0]),
            "output buffer must be recycled across runs, got {addrs:?}"
        );
    }

    #[test]
    fn node2_inplace_borrows_both_inputs() {
        let mut df = Dataflow::new();
        let a = df.node_inplace("a", || vec![1.0f32; 8], |_| {});
        let b = df.node_inplace("b", || vec![2.0f32; 8], |_| {});
        let sum = df.node2_inplace(
            "sum",
            &a,
            &b,
            || vec![0.0f32; 8],
            |a: &Vec<f32>, b: &Vec<f32>, out: &mut Vec<f32>| {
                for i in 0..out.len() {
                    out[i] = a[i] + b[i];
                }
            },
        );
        let pool = ThreadPool::new(2);
        df.run(&pool).unwrap();
        df.run(&pool).unwrap();
        assert!(sum.get().unwrap().iter().all(|&v| v == 3.0));
    }
}
