//! Seal-time priority analysis: critical-path ranks, rank-quantile
//! buckets, and the run-class → injector-lane composition (PR 4).
//!
//! The paper's §2.2 continuation rule is shape-oblivious: it executes
//! the *first* ready successor inline and submits the rest FIFO, so on
//! skewed DAGs the critical path routinely waits behind short branches.
//! The standard fix (Taskflow and the task-graph scheduling literature)
//! is priority-aware ready-task selection, and the PR 2 CSR arena makes
//! the static analysis nearly free: one reverse-topological sweep at
//! seal time.
//!
//! # Rank
//!
//! A node's **rank** is its weighted longest-path-to-sink: its own cost
//! weight ([`crate::graph::TaskGraph::set_weight`], default 1) plus the
//! maximum rank among its successors. The rank of a node is therefore
//! the remaining serial work on the most expensive dependency chain
//! through it — exactly the quantity a makespan-minimizing scheduler
//! wants to drain first. Ranks live in a dense array alongside the
//! pending counters and are invalidated with the topology cache (any
//! mutation of the graph, including `set_weight`, drops them; the next
//! seal recomputes).
//!
//! # Dispatch (see `graph/executor.rs`)
//!
//! With critical-path-first dispatch enabled (the default;
//! [`crate::graph::RunOptions::no_critical_path`] disables it), the
//! continuation rule becomes: execute the **highest-rank** ready
//! successor inline, and submit the rest most-critical-first (the burst
//! buffer is sorted by descending rank; worker-local LIFO pushes are
//! reversed so owners also pop in descending rank).
//!
//! # Lanes
//!
//! The pool's injector has [`crate::pool::injector::NUM_LANES`] (4)
//! priority lanes. A task's lane composes the **run's priority class**
//! ([`RunPriority`]: High / Normal / Low — tenant tiers for concurrent
//! async fleets) with the **node's rank bucket** (top-half vs
//! bottom-half rank within its graph):
//!
//! | run class \ node rank | top half | bottom half |
//! |---|---|---|
//! | High   | lane 0 | lane 1 |
//! | Normal | lane 1 | lane 2 |
//! | Low    | lane 2 | lane 3 |
//!
//! Untagged submissions (plain `submit`, lanes disabled) use lane 1,
//! and an occasional lowest-first pop bounds starvation (see
//! `pool/injector.rs`).

use std::cmp::Reverse;

use crate::pool::injector::NUM_LANES;

/// Priority class of a whole graph run — the tenant tier knob for
/// concurrent fleets ([`crate::graph::RunOptions::priority`]): every
/// task of a High run outranks every task of a Low run in the
/// injector's lane order (node ranks refine the order *within* a
/// class; see the module docs for the composition table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RunPriority {
    /// Served first: lanes 0–1.
    High,
    /// The default tier: lanes 1–2.
    #[default]
    Normal,
    /// Served last (but never starved — the injector's reverse-scan
    /// tick guarantees occasional low-lane pops): lanes 2–3.
    Low,
}

impl RunPriority {
    /// Lane of this class's most critical work (the row base in the
    /// composition table).
    #[inline]
    pub(crate) fn lane_base(self) -> u8 {
        match self {
            RunPriority::High => 0,
            RunPriority::Normal => 1,
            RunPriority::Low => 2,
        }
    }

    /// Stable lower-case name (trace export, bench labels).
    pub fn as_str(self) -> &'static str {
        match self {
            RunPriority::High => "high",
            RunPriority::Normal => "normal",
            RunPriority::Low => "low",
        }
    }
}

/// Composes a run class with a node's rank bucket into an injector
/// lane. `bucket` is the node's rank quartile (0 = most critical) or
/// `None` when no rank information exists (topology cache disabled) —
/// unranked nodes are treated as critical so a class's work is never
/// accidentally demoted a tier.
#[inline]
pub(crate) fn lane_compose(class: RunPriority, bucket: Option<u8>) -> u8 {
    let bonus = bucket.map(|b| b >> 1).unwrap_or(0); // quartiles 0–1 ⇒ +0, 2–3 ⇒ +1
    (class.lane_base() + bonus).min(NUM_LANES as u8 - 1)
}

/// The sealed priority schedule of a graph: per-node critical-path
/// ranks, rank-quartile buckets, and pre-ordered source lists. Built by
/// `Topology::build` (one reverse-topological sweep, O(nodes + edges))
/// and dropped with it on any mutation.
pub(crate) struct Schedule {
    /// Weighted longest-path-to-sink per node (own weight included);
    /// the priority key for inline selection and burst ordering.
    pub(crate) ranks: Vec<u64>,
    /// Rank quartile per node, 0 = most critical 25 %. Only the
    /// top-half/bottom-half split feeds the lane composition, but the
    /// full quartile is kept for traces and diagnostics.
    pub(crate) buckets: Vec<u8>,
    /// Zero-predecessor nodes in insertion order (the FIFO source
    /// burst, as `usize` for the burst-submission path).
    pub(crate) sources: Vec<usize>,
    /// Zero-predecessor nodes sorted by descending rank (node index
    /// breaks ties, so the order is deterministic) — the
    /// critical-path-first source burst.
    pub(crate) sources_desc: Vec<usize>,
    /// Kahn visitation order, cached at seal time. The CSR topology is
    /// immutable while sealed, so the same order stays valid for every
    /// duration-feedback re-rank (PR 8) — re-deriving it per re-rank
    /// would cost another O(n + e) pass and a scratch in-degree copy.
    topo_order: Vec<u32>,
    /// The effective per-node weights the *current* `ranks` encode:
    /// the declared weights at seal, then a snapshot of the observed
    /// durations after each re-rank. Drift detection compares fresh
    /// observations against these, so one re-rank quiets the trigger
    /// until behavior shifts again.
    rank_weights: Vec<u64>,
    /// Preallocated scratch for the bucket-threshold sort, retained at
    /// capacity so re-ranks stay allocation-free on sealed re-runs.
    scratch: Vec<u64>,
}

impl Schedule {
    /// Builds the schedule from the CSR topology pieces: `offsets` /
    /// `succ` are the flattened successor arena, `indeg` the per-node
    /// in-degrees, `weights` the per-node cost weights.
    ///
    /// The caller (seal) has already validated acyclicity, so Kahn's
    /// algorithm visits every node; the reverse of that visitation
    /// order is a valid reverse-topological order for the rank sweep.
    pub(crate) fn build(offsets: &[u32], succ: &[u32], indeg: &[u32], weights: &[u32]) -> Self {
        let n = indeg.len();
        debug_assert_eq!(offsets.len(), n + 1);
        debug_assert_eq!(weights.len(), n);

        // Kahn order (the Vec doubles as the queue). Validation ran its
        // own Kahn pass moments earlier, but its cycle-check cache
        // deliberately discards the visitation order (keeping it would
        // pin an O(n) Vec for the life of every validated graph);
        // re-deriving it here keeps seal a one-time, cold-path cost.
        let mut deg = indeg.to_vec();
        let mut order: Vec<u32> = (0..n as u32).filter(|&i| deg[i as usize] == 0).collect();
        let sources: Vec<usize> = order.iter().map(|&i| i as usize).collect();
        let mut head = 0;
        while head < order.len() {
            let i = order[head] as usize;
            head += 1;
            for &s in &succ[offsets[i] as usize..offsets[i + 1] as usize] {
                deg[s as usize] -= 1;
                if deg[s as usize] == 0 {
                    order.push(s);
                }
            }
        }
        debug_assert_eq!(order.len(), n, "Schedule::build requires an acyclic graph");

        let sources_desc = sources.clone();
        let mut sched = Schedule {
            ranks: vec![0u64; n],
            buckets: vec![0u8; n],
            sources,
            sources_desc,
            topo_order: order,
            rank_weights: weights.iter().map(|&w| u64::from(w)).collect(),
            scratch: Vec::with_capacity(n),
        };
        sched.recompute(offsets, succ);
        sched
    }

    /// The effective weights the current ranks were computed from —
    /// the baseline for the topology's drift check (PR 8).
    #[inline]
    pub(crate) fn rank_weights(&self) -> &[u64] {
        &self.rank_weights
    }

    /// Re-derives ranks from observed per-node durations (PR 8). The
    /// caller supplies `weight_of(i)` — the topology's observed-EWMA
    /// accessor — and guarantees the run is quiescent (no worker can be
    /// reading ranks/buckets). Allocation-free: the sweep reuses the
    /// cached Kahn order and every output vector is updated in place.
    pub(crate) fn rerank_from(
        &mut self,
        offsets: &[u32],
        succ: &[u32],
        weight_of: &dyn Fn(usize) -> u64,
    ) {
        for (i, w) in self.rank_weights.iter_mut().enumerate() {
            *w = weight_of(i).max(1);
        }
        self.recompute(offsets, succ);
    }

    /// The shared rank sweep: reverse-topological rank pass over the
    /// cached Kahn order, quartile re-bucketing, and the descending
    /// source re-sort — used by both the seal-time build and re-ranks.
    fn recompute(&mut self, offsets: &[u32], succ: &[u32]) {
        let n = self.rank_weights.len();

        // Reverse-topological sweep: every successor's rank is final
        // before its predecessors are visited.
        for &i in self.topo_order.iter().rev() {
            let i = i as usize;
            let tail = succ[offsets[i] as usize..offsets[i + 1] as usize]
                .iter()
                .map(|&s| self.ranks[s as usize])
                .max()
                .unwrap_or(0);
            self.ranks[i] = self.rank_weights[i] + tail;
        }

        // Quartile thresholds from a descending-sorted copy (the
        // retained scratch vector). The boundaries are approximate for
        // tiny graphs (ties all land in the more critical bucket),
        // which errs on the side of not demoting work — only the
        // top/bottom-half split feeds lanes.
        if n > 0 {
            self.scratch.clear();
            self.scratch.extend_from_slice(&self.ranks);
            self.scratch.sort_unstable_by_key(|&r| Reverse(r));
            let th: [u64; 3] = [1usize, 2, 3].map(|k| self.scratch[(n * k / 4).min(n - 1)]);
            for (b, &r) in self.buckets.iter_mut().zip(self.ranks.iter()) {
                *b = th.iter().filter(|&&t| r < t).count() as u8;
            }
        }

        let ranks = &self.ranks;
        self.sources_desc.sort_unstable_by_key(|&i| (Reverse(ranks[i]), i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::injector::DEFAULT_LANE;

    /// CSR-ify an adjacency list for direct Schedule::build tests.
    fn csr(adj: &[Vec<usize>]) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let mut offsets = vec![0u32];
        let mut succ = Vec::new();
        let mut indeg = vec![0u32; adj.len()];
        for succs in adj {
            for &s in succs {
                succ.push(s as u32);
                indeg[s] += 1;
            }
            offsets.push(succ.len() as u32);
        }
        (offsets, succ, indeg)
    }

    #[test]
    fn chain_ranks_count_down_to_the_sink() {
        // 0 -> 1 -> 2 -> 3, unit weights: ranks 4, 3, 2, 1.
        let adj = vec![vec![1], vec![2], vec![3], vec![]];
        let (o, s, d) = csr(&adj);
        let sched = Schedule::build(&o, &s, &d, &[1, 1, 1, 1]);
        assert_eq!(sched.ranks, vec![4, 3, 2, 1]);
        assert_eq!(sched.sources, vec![0]);
        assert_eq!(sched.sources_desc, vec![0]);
    }

    #[test]
    fn weighted_diamond_rank_takes_the_heavy_arm() {
        // 0 -> {1 (w=10), 2 (w=1)} -> 3: the source's rank follows the
        // heavy arm.
        let adj = vec![vec![1, 2], vec![3], vec![3], vec![]];
        let (o, s, d) = csr(&adj);
        let sched = Schedule::build(&o, &s, &d, &[1, 10, 1, 2]);
        assert_eq!(sched.ranks[3], 2);
        assert_eq!(sched.ranks[1], 12);
        assert_eq!(sched.ranks[2], 3);
        assert_eq!(sched.ranks[0], 13);
    }

    #[test]
    fn sources_desc_orders_by_rank_then_index() {
        // Three independent chains of different lengths; source order
        // by descending rank, index breaking ties.
        let adj = vec![
            vec![3],   // 0: chain of 2 -> rank 2
            vec![],    // 1: isolated -> rank 1
            vec![4],   // 2: chain of 2 -> rank 2 (ties with 0)
            vec![],    // 3
            vec![],    // 4
        ];
        let (o, s, d) = csr(&adj);
        let sched = Schedule::build(&o, &s, &d, &[1; 5]);
        assert_eq!(sched.sources, vec![0, 1, 2]);
        assert_eq!(sched.sources_desc, vec![0, 2, 1]);
    }

    #[test]
    fn buckets_split_ranks_into_quartiles() {
        // A pure chain of 8: ranks 8..1, one node per bucket pair.
        let adj: Vec<Vec<usize>> =
            (0..8).map(|i| if i + 1 < 8 { vec![i + 1] } else { vec![] }).collect();
        let (o, s, d) = csr(&adj);
        let sched = Schedule::build(&o, &s, &d, &[1; 8]);
        // Descending ranks 8..=1; thresholds at sorted[2], [4], [6] =
        // 6, 4, 2. Buckets: rank >= 6 -> 0, >= 4 -> 1, >= 2 -> 2, else 3.
        assert_eq!(sched.buckets, vec![0, 0, 0, 1, 1, 2, 2, 3]);
        // Uniform ranks collapse into the most critical bucket.
        let adj = vec![vec![], vec![], vec![], vec![]];
        let (o, s, d) = csr(&adj);
        let sched = Schedule::build(&o, &s, &d, &[1; 4]);
        assert_eq!(sched.buckets, vec![0, 0, 0, 0]);
    }

    #[test]
    fn lane_composition_matches_the_doc_table() {
        use RunPriority::*;
        for (class, top, bottom) in [(High, 0, 1), (Normal, 1, 2), (Low, 2, 3)] {
            assert_eq!(lane_compose(class, Some(0)), top, "{class:?} q0");
            assert_eq!(lane_compose(class, Some(1)), top, "{class:?} q1");
            assert_eq!(lane_compose(class, Some(2)), bottom, "{class:?} q2");
            assert_eq!(lane_compose(class, Some(3)), bottom, "{class:?} q3");
            // No rank information: treated as critical.
            assert_eq!(lane_compose(class, None), top, "{class:?} unranked");
        }
        assert_eq!(DEFAULT_LANE, 1, "untagged submissions share the Normal-critical lane");
    }

    #[test]
    fn rerank_flips_the_critical_arm_in_place() {
        // 0 -> {1 (declared 10), 2 (declared 1)} -> 3; observation says
        // the light arm is actually the heavy one.
        let adj = vec![vec![1, 2], vec![3], vec![3], vec![]];
        let (o, s, d) = csr(&adj);
        let mut sched = Schedule::build(&o, &s, &d, &[1, 10, 1, 2]);
        assert!(sched.ranks[1] > sched.ranks[2]);
        let observed = [1u64, 1, 40, 2];
        sched.rerank_from(&o, &s, &|i| observed[i]);
        assert_eq!(sched.ranks[2], 42);
        assert_eq!(sched.ranks[1], 3);
        assert_eq!(sched.ranks[0], 43);
        assert_eq!(sched.rank_weights(), &observed[..]);
        // Buckets follow the new ranks: node 2 is now top-quartile.
        assert!(sched.buckets[2] < sched.buckets[1]);
    }

    #[test]
    fn rerank_reorders_independent_sources() {
        // Two independent chains: 0->2 and 1->3, equal declared
        // weights; observation makes chain 1 heavier.
        let adj = vec![vec![2], vec![3], vec![], vec![]];
        let (o, s, d) = csr(&adj);
        let mut sched = Schedule::build(&o, &s, &d, &[1; 4]);
        assert_eq!(sched.sources_desc, vec![0, 1]);
        sched.rerank_from(&o, &s, &|i| if i == 1 || i == 3 { 50 } else { 1 });
        assert_eq!(sched.sources_desc, vec![1, 0]);
        assert_eq!(sched.sources, vec![0, 1], "insertion-order sources untouched");
    }

    #[test]
    fn empty_graph_schedule_is_empty() {
        let sched = Schedule::build(&[0], &[], &[], &[]);
        assert!(sched.ranks.is_empty());
        assert!(sched.buckets.is_empty());
        assert!(sched.sources.is_empty());
        assert!(sched.sources_desc.is_empty());
    }
}
