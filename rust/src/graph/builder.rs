//! Task-graph construction: nodes, dependencies, validation.

use std::cell::UnsafeCell;
use std::sync::atomic::AtomicUsize;

use crate::pool::ThreadPool;

use super::executor::{run_graph, RunOptions};

/// Handle to a node of a [`TaskGraph`], returned by [`TaskGraph::add`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub(crate) usize);

/// Errors surfaced when validating or running a graph.
#[derive(Debug)]
pub enum GraphError {
    /// The dependency relation contains a cycle; the offending strongly
    /// connected component includes the listed node indices.
    Cycle {
        /// Indices of nodes left with nonzero in-degree by Kahn's algorithm.
        stuck: Vec<usize>,
    },
    /// One or more tasks panicked during the run. The graph still ran
    /// to completion (successors of a panicked node do run — counters
    /// would deadlock otherwise); the first panic is reported here.
    TaskPanicked {
        /// Index of the first panicking node.
        node: usize,
        /// Name of the node, if it was given one.
        name: Option<String>,
        /// Panic payload rendered to a string when possible.
        message: String,
    },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle { stuck } => {
                write!(f, "task graph contains a cycle involving nodes {stuck:?}")
            }
            GraphError::TaskPanicked { node, name, message } => match name {
                Some(n) => write!(f, "task {node} ({n}) panicked: {message}"),
                None => write!(f, "task {node} panicked: {message}"),
            },
        }
    }
}

impl std::error::Error for GraphError {}

/// One task of the graph. The closure lives in an `UnsafeCell` because
/// the execution protocol guarantees exclusive access (a node runs at
/// most once per run, and all predecessor completions happen-before it
/// via the `AcqRel` counter decrements), letting tasks be `FnMut` and
/// mutate captured state exactly like the paper's `std::function<void()>`.
pub(crate) struct Node {
    pub(crate) func: UnsafeCell<Box<dyn FnMut() + Send>>,
    pub(crate) successors: Vec<usize>,
    pub(crate) num_predecessors: usize,
    /// Uncompleted-predecessor count, reset before every run.
    pub(crate) pending: AtomicUsize,
    pub(crate) name: Option<String>,
}

// SAFETY: `func` is only touched by the one worker that executes the
// node in a given run (see executor.rs for the protocol argument).
unsafe impl Sync for Node {}

/// A collection of tasks and dependencies between them (paper §4.2).
///
/// ```
/// use scheduling::graph::TaskGraph;
/// use scheduling::pool::ThreadPool;
/// use std::sync::Arc;
/// use std::sync::atomic::{AtomicI32, Ordering::Relaxed};
///
/// // (a + b) * (c + d), the paper's worked example. Tasks are
/// // `'static`, so shared state lives in Arcs.
/// let state: Arc<[AtomicI32]> = (0..7).map(|_| AtomicI32::new(0)).collect();
/// let (a, b, c, d, sum_ab, sum_cd, product) = (0, 1, 2, 3, 4, 5, 6);
/// let mut tasks = TaskGraph::new();
/// let mk = |i: usize, v: i32, s: &Arc<[AtomicI32]>| {
///     let s = s.clone();
///     move || s[i].store(v, Relaxed)
/// };
/// let get_a = tasks.add(mk(a, 1, &state));
/// let get_b = tasks.add(mk(b, 2, &state));
/// let get_c = tasks.add(mk(c, 3, &state));
/// let get_d = tasks.add(mk(d, 4, &state));
/// let s = state.clone();
/// let get_sum_ab = tasks.add(move || s[sum_ab].store(s[a].load(Relaxed) + s[b].load(Relaxed), Relaxed));
/// let s = state.clone();
/// let get_sum_cd = tasks.add(move || s[sum_cd].store(s[c].load(Relaxed) + s[d].load(Relaxed), Relaxed));
/// let s = state.clone();
/// let get_product = tasks.add(move || s[product].store(s[sum_ab].load(Relaxed) * s[sum_cd].load(Relaxed), Relaxed));
/// tasks.succeed(get_sum_ab, &[get_a, get_b]);
/// tasks.succeed(get_sum_cd, &[get_c, get_d]);
/// tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);
///
/// let pool = ThreadPool::new(2);
/// tasks.run(&pool).unwrap();
/// assert_eq!(state[product].load(Relaxed), 21);
/// ```
#[derive(Default)]
pub struct TaskGraph {
    pub(crate) nodes: Vec<Node>,
    /// Cached cycle-check result; `None` after any mutation.
    validated: Option<Result<(), Vec<usize>>>,
}

impl TaskGraph {
    /// Creates an empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Creates an empty graph with room for `n` tasks.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            nodes: Vec::with_capacity(n),
            validated: None,
        }
    }

    /// Adds a task — a closure taking no arguments and returning
    /// nothing; use captures for inputs and outputs.
    pub fn add<F: FnMut() + Send + 'static>(&mut self, f: F) -> NodeId {
        self.add_boxed(Box::new(f), None)
    }

    /// Adds a named task (names show up in error messages and traces).
    pub fn add_named<F: FnMut() + Send + 'static>(&mut self, name: impl Into<String>, f: F) -> NodeId {
        self.add_boxed(Box::new(f), Some(name.into()))
    }

    fn add_boxed(&mut self, f: Box<dyn FnMut() + Send>, name: Option<String>) -> NodeId {
        self.validated = None;
        let id = self.nodes.len();
        self.nodes.push(Node {
            func: UnsafeCell::new(f),
            successors: Vec::new(),
            num_predecessors: 0,
            pending: AtomicUsize::new(0),
            name,
        });
        NodeId(id)
    }

    /// Declares that `task` runs after every task in `deps`
    /// (the paper's `task.Succeed(&dep1, &dep2, ...)`).
    ///
    /// # Panics
    /// If any id is out of bounds (ids from another graph) or if an
    /// edge would be a self-loop.
    pub fn succeed(&mut self, task: NodeId, deps: &[NodeId]) {
        self.validated = None;
        for &d in deps {
            assert!(d.0 < self.nodes.len() && task.0 < self.nodes.len(), "NodeId out of range");
            assert_ne!(d.0, task.0, "a task cannot depend on itself");
            self.nodes[d.0].successors.push(task.0);
            self.nodes[task.0].num_predecessors += 1;
        }
    }

    /// Declares that `task` runs before every task in `succs`
    /// (the dual of [`TaskGraph::succeed`]).
    pub fn precede(&mut self, task: NodeId, succs: &[NodeId]) {
        self.validated = None;
        for &s in succs {
            assert!(s.0 < self.nodes.len() && task.0 < self.nodes.len(), "NodeId out of range");
            assert_ne!(s.0, task.0, "a task cannot depend on itself");
            self.nodes[task.0].successors.push(s.0);
            self.nodes[s.0].num_predecessors += 1;
        }
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if the graph has no tasks.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Number of dependency edges.
    pub fn num_edges(&self) -> usize {
        self.nodes.iter().map(|n| n.successors.len()).sum()
    }

    /// Name of a node, if set.
    pub fn name(&self, id: NodeId) -> Option<&str> {
        self.nodes[id.0].name.as_deref()
    }

    /// Successor ids of a node (for tests and tooling).
    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes[id.0].successors.iter().map(|&i| NodeId(i)).collect()
    }

    /// In-degree of a node.
    pub fn num_predecessors(&self, id: NodeId) -> usize {
        self.nodes[id.0].num_predecessors
    }

    /// Renders the dependency structure as Graphviz DOT (nodes show
    /// names where given, indices otherwise) — for docs and debugging:
    /// `scheduling graph-demo --dot` or `dot -Tsvg graph.dot`.
    pub fn to_dot(&self) -> String {
        let mut out = String::from("digraph taskgraph {\n  rankdir=LR;\n  node [shape=box];\n");
        for (i, node) in self.nodes.iter().enumerate() {
            let label = node.name.as_deref().unwrap_or("");
            if label.is_empty() {
                out.push_str(&format!("  n{i};\n"));
            } else {
                let escaped = label.replace('"', "\\\"");
                out.push_str(&format!("  n{i} [label=\"{escaped}\"];\n"));
            }
        }
        for (i, node) in self.nodes.iter().enumerate() {
            for &s in &node.successors {
                out.push_str(&format!("  n{i} -> n{s};\n"));
            }
        }
        out.push_str("}\n");
        out
    }

    /// Validates acyclicity (Kahn's algorithm), caching the result
    /// until the graph is next mutated.
    pub fn validate(&mut self) -> Result<(), GraphError> {
        if self.validated.is_none() {
            self.validated = Some(self.kahn_check());
        }
        match self.validated.as_ref().unwrap() {
            Ok(()) => Ok(()),
            Err(stuck) => Err(GraphError::Cycle { stuck: stuck.clone() }),
        }
    }

    fn kahn_check(&self) -> Result<(), Vec<usize>> {
        let n = self.nodes.len();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.num_predecessors).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &s in &self.nodes[i].successors {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if seen == n {
            Ok(())
        } else {
            Err((0..n).filter(|&i| indeg[i] > 0).collect())
        }
    }

    /// Runs the graph on `pool`, blocking until every task has
    /// executed. The graph can be run again afterwards (counters are
    /// reset on every run; `FnMut` closures keep their state).
    ///
    /// Must be called from a non-worker thread (it blocks).
    pub fn run(&mut self, pool: &ThreadPool) -> Result<(), GraphError> {
        self.run_with_options(pool, RunOptions::default())
    }

    /// [`TaskGraph::run`] with explicit [`RunOptions`] (e.g. disabling
    /// inline continuation for the scheduling ablation).
    pub fn run_with_options(&mut self, pool: &ThreadPool, options: RunOptions) -> Result<(), GraphError> {
        self.validate()?;
        run_graph(self, pool, options)
    }
}

impl std::fmt::Debug for TaskGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TaskGraph")
            .field("tasks", &self.len())
            .field("edges", &self.num_edges())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_shape() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add_named("sink", || {});
        g.succeed(c, &[a, b]);
        assert_eq!(g.len(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.num_predecessors(c), 2);
        assert_eq!(g.successors(a), vec![c]);
        assert_eq!(g.name(c), Some("sink"));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn precede_is_dual_of_succeed() {
        let mut g1 = TaskGraph::new();
        let a1 = g1.add(|| {});
        let b1 = g1.add(|| {});
        g1.succeed(b1, &[a1]);

        let mut g2 = TaskGraph::new();
        let a2 = g2.add(|| {});
        let b2 = g2.add(|| {});
        g2.precede(a2, &[b2]);

        assert_eq!(g1.successors(a1), g2.successors(a2));
        assert_eq!(g1.num_predecessors(b1), g2.num_predecessors(b2));
    }

    #[test]
    fn cycle_detected() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add(|| {});
        g.succeed(b, &[a]);
        g.succeed(c, &[b]);
        g.succeed(a, &[c]); // a -> b -> c -> a
        match g.validate() {
            Err(GraphError::Cycle { stuck }) => {
                assert_eq!(stuck.len(), 3);
            }
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn diamond_is_acyclic() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add(|| {});
        let d = g.add(|| {});
        g.succeed(b, &[a]);
        g.succeed(c, &[a]);
        g.succeed(d, &[b, c]);
        assert!(g.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "cannot depend on itself")]
    fn self_loop_panics() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        g.succeed(a, &[a]);
    }

    #[test]
    fn dot_export_contains_nodes_and_edges() {
        let mut g = TaskGraph::new();
        let a = g.add_named("fetch \"data\"", || {});
        let b = g.add(|| {});
        g.succeed(b, &[a]);
        let dot = g.to_dot();
        assert!(dot.starts_with("digraph taskgraph {"));
        assert!(dot.contains("n0 [label=\"fetch \\\"data\\\"\"];"));
        assert!(dot.contains("n1;"));
        assert!(dot.contains("n0 -> n1;"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn validation_cache_invalidated_on_mutation() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        g.succeed(b, &[a]);
        assert!(g.validate().is_ok());
        g.succeed(a, &[b]); // now cyclic
        assert!(g.validate().is_err());
    }
}
