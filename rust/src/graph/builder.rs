//! Task-graph construction: nodes, dependencies, validation, and the
//! sealed CSR topology arena (PR 2).
//!
//! A graph is *built* as per-node adjacency `Vec`s (cheap to mutate)
//! and *run* from a [`Topology`]: one flattened successor arena in CSR
//! form plus a dense, cache-line-aligned array of pending counters.
//! The topology is derived lazily on first run (or eagerly via
//! [`TaskGraph::seal`]) and invalidated by any mutation, exactly like
//! the cached cycle-check result.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::obs::RunProfile;
use crate::pool::ThreadPool;
use crate::util::CachePadded;

use super::executor::{run_graph, run_graph_async, try_run_graph, RunHandle, RunOptions, RunState};
use super::schedule::Schedule;

/// Handle to a node of a [`TaskGraph`], returned by [`TaskGraph::add`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub(crate) usize);

/// Errors surfaced when validating or running a graph.
#[derive(Debug)]
pub enum GraphError {
    /// The dependency relation contains a cycle; the offending strongly
    /// connected component includes the listed node indices.
    Cycle {
        /// Indices of nodes left with nonzero in-degree by Kahn's algorithm.
        stuck: Vec<usize>,
    },
    /// A task panicked during the run, which **aborts** the run (PR 6):
    /// nodes not yet dispatched when the panic was recorded are
    /// cancelled (their closures never execute; their counters still
    /// drain, so the pool quiesces normally), the worker that caught
    /// the panic is quarantined-and-revived rather than lost, and the
    /// first panic payload is reported here. The graph un-poisons on
    /// its next run.
    NodePanicked {
        /// Index of the first panicking node.
        node: usize,
        /// Name of the node, if it was given one.
        name: Option<String>,
        /// Panic payload rendered to a string when possible.
        payload: String,
    },
    /// The run was cancelled — via [`crate::graph::RunHandle::cancel`]
    /// or a [`crate::graph::CancelToken`] passed through
    /// [`RunOptions::cancel_token`](crate::graph::RunOptions::cancel_token).
    /// Cancellation is cooperative and takes effect at node-dispatch
    /// boundaries: nodes already executing finish, unreached nodes are
    /// skipped (counters still drain, so quiescence and generation
    /// accounting stay exact).
    Cancelled,
    /// The run's [`RunOptions::deadline`](crate::graph::RunOptions::deadline)
    /// expired before completion. Enforced through the same cooperative
    /// cancel path as [`GraphError::Cancelled`].
    DeadlineExceeded,
    /// The pool's admission budget
    /// ([`crate::pool::PoolConfig::max_inflight_runs`] /
    /// [`crate::pool::PoolConfig::max_queued_tasks`]) is exhausted:
    /// [`TaskGraph::try_run`] refuses new runs instead of growing the
    /// queues without bound, and `Low`-class runs are shed first.
    Overloaded,
    /// The run's [`RunOptions::deadline`](crate::graph::RunOptions::deadline)
    /// cannot be met even before launch (PR 7): the pool's observed
    /// dispatch-queue delay ([`crate::pool::ThreadPool::queue_delay_ewma`])
    /// already exceeds the whole deadline, so admitting the run would
    /// only burn budget on work guaranteed to be aborted. Rejected at
    /// the admission seam **without** consuming an inflight slot — the
    /// serving tier's brownout policy (`serve/brownout.rs`) documents
    /// where this sits in the shed order.
    WouldMissDeadline,
    /// [`TaskGraph::run`] was called from inside a task of the pool it
    /// targets — whether that task was picked up by a worker thread or
    /// by a caller-assist helper. The run would need that very
    /// executor to make progress (and, without caller assistance,
    /// would block it outright), so this is rejected in **all** build
    /// profiles rather than deadlocking silently in release. Run
    /// graphs from external threads, or target a different pool.
    RunFromWorker,
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle { stuck } => {
                write!(f, "task graph contains a cycle involving nodes {stuck:?}")
            }
            GraphError::NodePanicked { node, name, payload } => match name {
                Some(n) => write!(f, "task {node} ({n}) panicked (run aborted): {payload}"),
                None => write!(f, "task {node} panicked (run aborted): {payload}"),
            },
            GraphError::Cancelled => write!(f, "graph run cancelled"),
            GraphError::DeadlineExceeded => write!(f, "graph run deadline exceeded"),
            GraphError::Overloaded => write!(
                f,
                "pool admission budget exhausted (max_inflight_runs / max_queued_tasks); \
                 retry later or raise the budget"
            ),
            GraphError::WouldMissDeadline => write!(
                f,
                "run rejected at admission: the pool's queue delay already exceeds \
                 the run's deadline (it would be aborted before finishing)"
            ),
            GraphError::RunFromWorker => write!(
                f,
                "TaskGraph::run called from a worker task of the target pool \
                 (would deadlock); run the graph from an external thread"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// One task of the graph. The closure lives in an `UnsafeCell` because
/// the execution protocol guarantees exclusive access (a node runs at
/// most once per run, and all predecessor completions happen-before it
/// via the `AcqRel` counter decrements), letting tasks be `FnMut` and
/// mutate captured state exactly like the paper's `std::function<void()>`.
pub(crate) struct Node {
    pub(crate) func: UnsafeCell<Box<dyn FnMut() + Send>>,
    pub(crate) successors: Vec<usize>,
    pub(crate) num_predecessors: usize,
    /// Uncompleted-predecessor count, reset before every run.
    pub(crate) pending: AtomicUsize,
    pub(crate) name: Option<String>,
    /// Cost weight for the critical-path analysis (PR 4): the node's
    /// contribution to the weighted longest-path-to-sink rank. Default
    /// 1 (every node equally expensive); set via
    /// [`TaskGraph::set_weight`] / [`TaskGraph::add_weighted`].
    pub(crate) weight: u32,
}

// SAFETY: `func` is only touched by the one worker that executes the
// node in a given run (see executor.rs for the protocol argument).
unsafe impl Sync for Node {}

/// Pending counters per 128-byte [`CachePadded`] block (4-byte
/// counters). The counter array is the only graph memory the executor
/// writes on the hot path; giving it whole cache lines of its own
/// means decrements never false-share with the cold node fields
/// (closures, names, successor `Vec` headers).
const PENDING_PER_LINE: usize = 32;

/// Observed-duration EWMA cells per 128-byte [`CachePadded`] block
/// (8-byte cells). Written once per node completion — far colder than
/// the pending counters, but still on the completion path, so they get
/// the same false-sharing isolation from the cold node fields.
const OBSERVED_PER_LINE: usize = 16;

/// Re-rank trigger (PR 8): a sealed graph's ranks are recomputed from
/// observed durations when some node's *share* of total observed time
/// differs from its share under the current rank weights by at least
/// this factor (in either direction). 2× is deliberately coarse —
/// scheduling is threshold-like (what matters is which arm looks
/// critical, not the exact ratio), and a coarse trigger keeps timing
/// jitter on micro-nodes from re-sorting the schedule every launch.
const RERANK_DRIFT_RATIO: f64 = 2.0;

/// The sealed, run-ready form of a graph's dependency structure
/// (PR 2 tentpole): a CSR successor arena plus dense pending counters.
///
/// * `offsets`/`succ_arena` — all per-node `successors: Vec<usize>`
///   flattened into one contiguous `u32` array; the executor walks
///   `succ_arena[offsets[i]..offsets[i+1]]` instead of chasing a
///   heap-scattered `Vec` per node.
/// * `pending` — the per-run uncompleted-predecessor counters in one
///   dense, cache-line-aligned allocation, so resetting them is a
///   single linear sweep and decrementing them touches no cold data.
/// * `sched` — the seal-time priority analysis (PR 4): per-node
///   critical-path ranks and rank buckets, plus the precomputed source
///   lists (insertion-ordered and rank-ordered) so a re-run submits its
///   source burst without building a fresh `Vec`.
///
/// Built on first run or by [`TaskGraph::seal`]; dropped by any
/// mutation (`add*`, `succeed`, `precede`, `set_weight`).
pub(crate) struct Topology {
    /// CSR row offsets; length `n + 1`.
    offsets: Vec<u32>,
    /// Flattened successor lists.
    succ_arena: Vec<u32>,
    /// In-degree of each node — the reset image for `pending`.
    init_pending: Vec<u32>,
    /// Dense per-node counters, grouped [`PENDING_PER_LINE`] to a
    /// padded line (see the const's docs).
    pending: Vec<CachePadded<[AtomicU32; PENDING_PER_LINE]>>,
    /// Seal-time priority analysis (PR 4): critical-path ranks,
    /// rank-quartile buckets, and the rank-ordered source list — a
    /// dense companion to `pending`, dropped with the topology on any
    /// mutation (see `graph/schedule.rs`).
    sched: Schedule,
    /// Per-node observed-duration EWMAs in nanoseconds (PR 8), 0 =
    /// never sampled. Written by the worker completing the node (one
    /// writer per node per run — runs of one graph are serialized, so
    /// a plain read-modify-write store is exact, atomics only for
    /// cross-run visibility) and folded into the ranks by
    /// [`Topology::maybe_rerank`] in the launch quiescent window.
    observed_ns: Vec<CachePadded<[AtomicU64; OBSERVED_PER_LINE]>>,
    /// Completed re-rank sweeps — diagnostics for tests, ablations,
    /// and the wire scrape endpoint.
    reranks: AtomicU64,
    /// Per-node execution spans of the most recent run (PR 9):
    /// start/end nanoseconds on the pool's observability epoch (0 =
    /// not executed this run) plus the executing worker lane. One
    /// writer per node per run (same argument as `observed_ns`);
    /// swept to zero in the launch quiescent window and folded into a
    /// [`crate::obs::RunProfile`] on demand. Plain dense arrays — the
    /// two stores per node ride the completion path that already
    /// writes `observed_ns`, and profile reads happen off-run.
    span_start: Vec<AtomicU64>,
    span_end: Vec<AtomicU64>,
    span_worker: Vec<AtomicU32>,
    /// Worker count of the pool that ran this graph last (PR 9): the
    /// denominator of the profile's scheduling efficiency. 0 until the
    /// first timed run.
    last_workers: AtomicUsize,
}

impl Topology {
    pub(crate) fn build(nodes: &[Node]) -> Self {
        let n = nodes.len();
        let edges: usize = nodes.iter().map(|x| x.successors.len()).sum();
        assert!(
            n < u32::MAX as usize && edges < u32::MAX as usize,
            "graph too large for the u32 CSR topology arena"
        );
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        let mut total = 0u32;
        for node in nodes {
            total += node.successors.len() as u32;
            offsets.push(total);
        }
        let mut succ_arena = Vec::with_capacity(edges);
        for node in nodes {
            succ_arena.extend(node.successors.iter().map(|&s| s as u32));
        }
        let lines = n.div_ceil(PENDING_PER_LINE);
        let init_pending: Vec<u32> = nodes.iter().map(|x| x.num_predecessors as u32).collect();
        let weights: Vec<u32> = nodes.iter().map(|x| x.weight).collect();
        let sched = Schedule::build(&offsets, &succ_arena, &init_pending, &weights);
        Self {
            offsets,
            succ_arena,
            init_pending,
            pending: (0..lines)
                .map(|_| CachePadded::new(std::array::from_fn(|_| AtomicU32::new(0))))
                .collect(),
            sched,
            observed_ns: (0..n.div_ceil(OBSERVED_PER_LINE))
                .map(|_| CachePadded::new(std::array::from_fn(|_| AtomicU64::new(0))))
                .collect(),
            reranks: AtomicU64::new(0),
            span_start: (0..n).map(|_| AtomicU64::new(0)).collect(),
            span_end: (0..n).map(|_| AtomicU64::new(0)).collect(),
            span_worker: (0..n).map(|_| AtomicU32::new(0)).collect(),
            last_workers: AtomicUsize::new(0),
        }
    }

    /// The seal-time priority schedule (ranks, buckets, ordered
    /// sources).
    #[inline]
    pub(crate) fn sched(&self) -> &Schedule {
        &self.sched
    }

    /// Successors of node `i` as a slice of the arena.
    #[inline]
    pub(crate) fn successors(&self, i: usize) -> &[u32] {
        &self.succ_arena[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Uncompleted-predecessor counter of node `i`.
    #[inline]
    pub(crate) fn pending(&self, i: usize) -> &AtomicU32 {
        &(*self.pending[i / PENDING_PER_LINE])[i % PENDING_PER_LINE]
    }

    /// Re-arms every counter for a new run: one linear sweep over the
    /// dense array. Relaxed is enough — the happens-before edge to the
    /// workers that will decrement these is the task submission that
    /// follows the reset.
    pub(crate) fn reset_pending(&self) {
        for (i, &init) in self.init_pending.iter().enumerate() {
            self.pending(i).store(init, Ordering::Relaxed);
        }
    }

    /// Node count this topology was built for.
    #[allow(dead_code)]
    pub(crate) fn node_count(&self) -> usize {
        self.init_pending.len()
    }

    /// Observed-duration EWMA cell of node `i` (nanoseconds; 0 = no
    /// sample yet).
    #[inline]
    pub(crate) fn observed(&self, i: usize) -> &AtomicU64 {
        &(*self.observed_ns[i / OBSERVED_PER_LINE])[i % OBSERVED_PER_LINE]
    }

    /// Folds one observed node duration into the EWMA (α = 1/4 — fast
    /// enough that two skewed re-runs dominate a wrong seal-time
    /// estimate, slow enough to shrug off a single preemption blip).
    /// First sample seeds; samples floor at 1 ns so "observed" is
    /// distinguishable from "never ran".
    #[inline]
    pub(crate) fn note_duration(&self, i: usize, ns: u64) {
        let cell = self.observed(i);
        let cur = cell.load(Ordering::Relaxed);
        let next = if cur == 0 { ns } else { cur - cur / 4 + ns / 4 };
        cell.store(next.max(1), Ordering::Relaxed);
    }

    /// Re-rank sweeps completed so far.
    #[inline]
    pub(crate) fn rerank_count(&self) -> u64 {
        self.reranks.load(Ordering::Relaxed)
    }

    /// Duration-feedback re-rank check (PR 8), called from the launch
    /// path's quiescent window (`&mut self` proves no run is reading
    /// the schedule). Skips until every node has at least one sample;
    /// then compares each node's share of total observed time against
    /// its share under the weights the current ranks encode, and when
    /// the worst-case ratio reaches [`RERANK_DRIFT_RATIO`] recomputes
    /// ranks, buckets, and the source order in place (allocation-free,
    /// so sealed re-runs stay zero-alloc). Returns whether a re-rank
    /// happened.
    pub(crate) fn maybe_rerank(&mut self) -> bool {
        let n = self.init_pending.len();
        if n == 0 {
            return false;
        }
        let weights = self.sched.rank_weights();
        let mut sum_obs = 0.0f64;
        let mut sum_cur = 0.0f64;
        for i in 0..n {
            let o = self.observed(i).load(Ordering::Relaxed);
            if o == 0 {
                return false; // e.g. last run was cancelled mid-flight
            }
            sum_obs += o as f64;
            sum_cur += weights[i] as f64;
        }
        if sum_cur <= 0.0 || sum_obs <= 0.0 {
            return false;
        }
        let mut drift = 1.0f64;
        for i in 0..n {
            let obs_share = self.observed(i).load(Ordering::Relaxed) as f64 / sum_obs;
            let cur_share = weights[i] as f64 / sum_cur;
            let ratio = obs_share / cur_share.max(f64::MIN_POSITIVE);
            drift = drift.max(ratio.max(1.0 / ratio.max(f64::MIN_POSITIVE)));
        }
        if drift < RERANK_DRIFT_RATIO {
            return false;
        }
        let Topology { sched, offsets, succ_arena, observed_ns, .. } = self;
        sched.rerank_from(offsets, succ_arena, &|i: usize| {
            (*observed_ns[i / OBSERVED_PER_LINE])[i % OBSERVED_PER_LINE].load(Ordering::Relaxed)
        });
        self.reranks.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Records the execution span of node `i` for the current run
    /// (PR 9): start/end in nanoseconds on the pool epoch (caller
    /// guarantees `start_ns >= 1`) and the executing worker lane.
    /// Relaxed stores — one writer per node per run, read only after
    /// the run completes.
    #[inline]
    pub(crate) fn record_span(&self, i: usize, start_ns: u64, end_ns: u64, worker: u32) {
        self.span_start[i].store(start_ns, Ordering::Relaxed);
        self.span_end[i].store(end_ns, Ordering::Relaxed);
        self.span_worker[i].store(worker, Ordering::Relaxed);
    }

    /// Clears all spans and stashes the worker count for the run about
    /// to launch. Called from the launch path's quiescent window (one
    /// linear sweep, allocation-free, so sealed re-runs stay
    /// zero-alloc).
    pub(crate) fn reset_spans(&self, workers: usize) {
        for s in &self.span_start {
            s.store(0, Ordering::Relaxed);
        }
        for e in &self.span_end {
            e.store(0, Ordering::Relaxed);
        }
        self.last_workers.store(workers, Ordering::Relaxed);
    }

    /// Folds the most recent run's spans into a [`RunProfile`], or
    /// `None` when no timed run has completed (spans are only written
    /// when the pool's histograms, flight recorder, or duration
    /// sampling are active).
    pub(crate) fn profile(&self) -> Option<RunProfile> {
        let workers = self.last_workers.load(Ordering::Relaxed);
        if workers == 0 {
            return None;
        }
        let starts: Vec<u64> = self.span_start.iter().map(|s| s.load(Ordering::Relaxed)).collect();
        let ends: Vec<u64> = self.span_end.iter().map(|e| e.load(Ordering::Relaxed)).collect();
        let lanes: Vec<u32> =
            self.span_worker.iter().map(|w| w.load(Ordering::Relaxed)).collect();
        RunProfile::compute(
            &starts,
            &ends,
            &lanes,
            |i| self.successors(i).iter().map(|&s| s as usize).collect(),
            &self.sched.ranks,
            workers,
        )
    }

    /// All graph edges as `(source, successor)` pairs — the flight
    /// dump's Chrome-trace converter uses these to draw flow arrows.
    pub(crate) fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut edges = Vec::with_capacity(self.succ_arena.len());
        for i in 0..self.init_pending.len() {
            for &s in self.successors(i) {
                edges.push((i as u32, s));
            }
        }
        edges
    }
}

/// A collection of tasks and dependencies between them (paper §4.2).
///
/// ```
/// use scheduling::graph::TaskGraph;
/// use scheduling::pool::ThreadPool;
/// use std::sync::Arc;
/// use std::sync::atomic::{AtomicI32, Ordering::Relaxed};
///
/// // (a + b) * (c + d), the paper's worked example. Tasks are
/// // `'static`, so shared state lives in Arcs.
/// let state: Arc<[AtomicI32]> = (0..7).map(|_| AtomicI32::new(0)).collect();
/// let (a, b, c, d, sum_ab, sum_cd, product) = (0, 1, 2, 3, 4, 5, 6);
/// let mut tasks = TaskGraph::new();
/// let mk = |i: usize, v: i32, s: &Arc<[AtomicI32]>| {
///     let s = s.clone();
///     move || s[i].store(v, Relaxed)
/// };
/// let get_a = tasks.add(mk(a, 1, &state));
/// let get_b = tasks.add(mk(b, 2, &state));
/// let get_c = tasks.add(mk(c, 3, &state));
/// let get_d = tasks.add(mk(d, 4, &state));
/// let s = state.clone();
/// let get_sum_ab = tasks.add(move || s[sum_ab].store(s[a].load(Relaxed) + s[b].load(Relaxed), Relaxed));
/// let s = state.clone();
/// let get_sum_cd = tasks.add(move || s[sum_cd].store(s[c].load(Relaxed) + s[d].load(Relaxed), Relaxed));
/// let s = state.clone();
/// let get_product = tasks.add(move || s[product].store(s[sum_ab].load(Relaxed) * s[sum_cd].load(Relaxed), Relaxed));
/// tasks.succeed(get_sum_ab, &[get_a, get_b]);
/// tasks.succeed(get_sum_cd, &[get_c, get_d]);
/// tasks.succeed(get_product, &[get_sum_ab, get_sum_cd]);
///
/// let pool = ThreadPool::new(2);
/// tasks.run(&pool).unwrap();
/// assert_eq!(state[product].load(Relaxed), 21);
/// ```
#[derive(Default)]
pub struct TaskGraph {
    pub(crate) nodes: Vec<Node>,
    /// Cached cycle-check result; `None` after any mutation.
    validated: Option<Result<(), Vec<usize>>>,
    /// Sealed CSR topology; `None` until first run / [`TaskGraph::seal`]
    /// and after any mutation. Boxed so its address is stable under
    /// moves of the `TaskGraph` itself: an in-flight run's header
    /// points at it, and a forgotten [`RunHandle`] releases the graph
    /// borrow early — a move runs no code, so only heap-pinned run
    /// structures (this box, the `nodes` buffer) are sound to point
    /// into (see executor.rs's protocol docs).
    pub(crate) topology: Option<Box<Topology>>,
    /// Run state reused across runs of a sealed graph, so a re-run
    /// performs zero heap allocations (see executor.rs). Dropped on
    /// mutation together with the topology.
    pub(crate) run_state: Option<Arc<RunState>>,
}

impl TaskGraph {
    /// Creates an empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Creates an empty graph with room for `n` tasks.
    pub fn with_capacity(n: usize) -> Self {
        Self {
            nodes: Vec::with_capacity(n),
            ..Self::default()
        }
    }

    /// Drops every derived structure (validation result, CSR topology,
    /// reusable run state) — called on any mutation.
    ///
    /// If a forgotten [`RunHandle`] (`mem::forget` skips its blocking
    /// `Drop`) left a run of this graph in flight, freeing the
    /// topology or node closures under running tasks would be
    /// use-after-free — so this first waits for that run to complete.
    /// In the normal handle lifecycle the check is two atomic loads.
    fn invalidate_caches(&mut self) {
        if let Some(state) = &self.run_state {
            state.wait_quiesce();
        }
        self.validated = None;
        self.topology = None;
        self.run_state = None;
    }

    /// Adds a task — a closure taking no arguments and returning
    /// nothing; use captures for inputs and outputs.
    pub fn add<F: FnMut() + Send + 'static>(&mut self, f: F) -> NodeId {
        self.add_boxed(Box::new(f), None, 1)
    }

    /// Adds a named task (names show up in error messages and traces).
    pub fn add_named<F: FnMut() + Send + 'static>(&mut self, name: impl Into<String>, f: F) -> NodeId {
        self.add_boxed(Box::new(f), Some(name.into()), 1)
    }

    /// Adds a task with an explicit cost weight for the critical-path
    /// analysis (PR 4): the seal-time rank of a node is its weight plus
    /// the heaviest downstream chain, and critical-path-first dispatch
    /// drains high-rank nodes first. [`TaskGraph::add`] is
    /// `add_weighted(1, f)`.
    pub fn add_weighted<F: FnMut() + Send + 'static>(&mut self, weight: u32, f: F) -> NodeId {
        self.add_boxed(Box::new(f), None, weight)
    }

    fn add_boxed(&mut self, f: Box<dyn FnMut() + Send>, name: Option<String>, weight: u32) -> NodeId {
        self.invalidate_caches();
        let id = self.nodes.len();
        self.nodes.push(Node {
            func: UnsafeCell::new(f),
            successors: Vec::new(),
            num_predecessors: 0,
            pending: AtomicUsize::new(0),
            name,
            weight,
        });
        NodeId(id)
    }

    /// Sets a node's cost weight (see [`TaskGraph::add_weighted`]).
    /// Like every mutation, this invalidates the sealed topology (the
    /// rank array depends on weights); the next run or
    /// [`TaskGraph::seal`] recomputes it.
    ///
    /// # Panics
    /// If `id` is out of bounds.
    pub fn set_weight(&mut self, id: NodeId, weight: u32) {
        assert!(id.0 < self.nodes.len(), "NodeId out of range");
        self.invalidate_caches();
        self.nodes[id.0].weight = weight;
    }

    /// A node's cost weight (default 1).
    ///
    /// # Panics
    /// If `id` is out of bounds (an id from another graph).
    pub fn weight(&self, id: NodeId) -> u32 {
        assert!(id.0 < self.nodes.len(), "NodeId out of range");
        self.nodes[id.0].weight
    }

    /// A node's critical-path rank — its weighted longest-path-to-sink
    /// (own weight included) — or `None` while the graph is unsealed
    /// (ranks are computed at seal time; see `graph/schedule.rs`).
    /// After a duration-feedback re-rank (PR 8) this reflects observed
    /// rather than declared weights; see [`TaskGraph::reranks`].
    ///
    /// # Panics
    /// If `id` is out of bounds (an id from another graph).
    pub fn rank(&self, id: NodeId) -> Option<u64> {
        assert!(id.0 < self.nodes.len(), "NodeId out of range");
        self.topology.as_ref().map(|t| t.sched().ranks[id.0])
    }

    /// How many duration-feedback re-ranks this sealed graph has
    /// performed (PR 8): launches recompute critical-path ranks from
    /// observed node durations when they drift ≥2× from the weights
    /// the current ranks encode
    /// ([`RunOptions::dynamic_rank`](crate::graph::RunOptions::dynamic_rank)
    /// opts a run out). Resets to 0 when a mutation drops the sealed
    /// topology.
    pub fn reranks(&self) -> u64 {
        self.topology.as_ref().map(|t| t.rerank_count()).unwrap_or(0)
    }

    /// The observed-duration EWMA of a node (PR 8) — the executor's
    /// measured execution time, smoothed across re-runs — or `None`
    /// while the graph is unsealed or the node has never completed.
    ///
    /// # Panics
    /// If `id` is out of bounds (an id from another graph).
    pub fn observed_duration(&self, id: NodeId) -> Option<Duration> {
        assert!(id.0 < self.nodes.len(), "NodeId out of range");
        let ns = self.topology.as_ref()?.observed(id.0).load(Ordering::Relaxed);
        (ns > 0).then(|| Duration::from_nanos(ns))
    }

    /// Scheduling profile of the most recent completed run (PR 9):
    /// observed critical path vs declared ranks, busy/idle makespan
    /// breakdown, and scheduling efficiency. `None` while the graph is
    /// unsealed, before any run, or when the pool that ran it had both
    /// its flight recorder and histograms disabled *and* the run
    /// opted out of duration sampling (no spans were recorded).
    ///
    /// Prefer [`RunHandle::profile`](crate::graph::RunHandle::profile)
    /// when you hold the handle — it is the same data without the
    /// borrow of the graph.
    pub fn last_profile(&self) -> Option<crate::obs::RunProfile> {
        self.topology.as_ref()?.profile()
    }

    /// Declares that `task` runs after every task in `deps`
    /// (the paper's `task.Succeed(&dep1, &dep2, ...)`).
    ///
    /// # Panics
    /// If any id is out of bounds (ids from another graph) or if an
    /// edge would be a self-loop.
    pub fn succeed(&mut self, task: NodeId, deps: &[NodeId]) {
        self.invalidate_caches();
        for &d in deps {
            assert!(d.0 < self.nodes.len() && task.0 < self.nodes.len(), "NodeId out of range");
            assert_ne!(d.0, task.0, "a task cannot depend on itself");
            self.nodes[d.0].successors.push(task.0);
            self.nodes[task.0].num_predecessors += 1;
        }
    }

    /// Declares that `task` runs before every task in `succs`
    /// (the dual of [`TaskGraph::succeed`]).
    pub fn precede(&mut self, task: NodeId, succs: &[NodeId]) {
        self.invalidate_caches();
        for &s in succs {
            assert!(s.0 < self.nodes.len() && task.0 < self.nodes.len(), "NodeId out of range");
            assert_ne!(s.0, task.0, "a task cannot depend on itself");
            self.nodes[task.0].successors.push(s.0);
            self.nodes[s.0].num_predecessors += 1;
        }
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if the graph has no tasks.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Number of dependency edges.
    pub fn num_edges(&self) -> usize {
        self.nodes.iter().map(|n| n.successors.len()).sum()
    }

    /// Name of a node, if set.
    pub fn name(&self, id: NodeId) -> Option<&str> {
        self.nodes[id.0].name.as_deref()
    }

    /// Successor ids of a node (for tests and tooling).
    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes[id.0].successors.iter().map(|&i| NodeId(i)).collect()
    }

    /// In-degree of a node.
    pub fn num_predecessors(&self, id: NodeId) -> usize {
        self.nodes[id.0].num_predecessors
    }

    /// Renders the dependency structure as Graphviz DOT (nodes show
    /// names where given, indices otherwise) — for docs and debugging:
    /// `scheduling graph-demo --dot` or `dot -Tsvg graph.dot`.
    pub fn to_dot(&self) -> String {
        let mut out = String::from("digraph taskgraph {\n  rankdir=LR;\n  node [shape=box];\n");
        for (i, node) in self.nodes.iter().enumerate() {
            let label = node.name.as_deref().unwrap_or("");
            if label.is_empty() {
                out.push_str(&format!("  n{i};\n"));
            } else {
                let escaped = label.replace('"', "\\\"");
                out.push_str(&format!("  n{i} [label=\"{escaped}\"];\n"));
            }
        }
        for (i, node) in self.nodes.iter().enumerate() {
            for &s in &node.successors {
                out.push_str(&format!("  n{i} -> n{s};\n"));
            }
        }
        out.push_str("}\n");
        out
    }

    /// Validates the graph and freezes its dependency structure into
    /// the CSR topology arena (flattened successor lists + dense
    /// pending counters + precomputed source list).
    ///
    /// Sealing is what makes repeated runs cheap: a sealed graph's
    /// second and later [`TaskGraph::run`] calls perform **zero heap
    /// allocations** and reset state with one linear counter sweep.
    /// Running an unsealed graph seals it implicitly on the first run;
    /// call this eagerly to move the (one-time, O(nodes + edges)) cost
    /// out of the measured path. Any mutation (`add*`, `succeed`,
    /// `precede`) un-seals the graph; the next run re-seals it.
    pub fn seal(&mut self) -> Result<(), GraphError> {
        self.validate()?;
        if self.topology.is_none() {
            self.topology = Some(Box::new(Topology::build(&self.nodes)));
        }
        Ok(())
    }

    /// True if the CSR topology is currently built (i.e. the graph has
    /// been sealed and not mutated since).
    pub fn is_sealed(&self) -> bool {
        self.topology.is_some()
    }

    /// Validates acyclicity (Kahn's algorithm), caching the result
    /// until the graph is next mutated.
    pub fn validate(&mut self) -> Result<(), GraphError> {
        if self.validated.is_none() {
            self.validated = Some(self.kahn_check());
        }
        match self.validated.as_ref().unwrap() {
            Ok(()) => Ok(()),
            Err(stuck) => Err(GraphError::Cycle { stuck: stuck.clone() }),
        }
    }

    fn kahn_check(&self) -> Result<(), Vec<usize>> {
        let n = self.nodes.len();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.num_predecessors).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &s in &self.nodes[i].successors {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if seen == n {
            Ok(())
        } else {
            Err((0..n).filter(|&i| indeg[i] > 0).collect())
        }
    }

    /// Runs the graph on `pool`, returning once every task has
    /// executed. The graph can be run again afterwards (counters are
    /// reset on every run; `FnMut` closures keep their state), and
    /// repeated runs of a sealed graph are allocation-free — see
    /// [`TaskGraph::seal`].
    ///
    /// By default the calling thread **assists** the run: it executes
    /// ready tasks from the pool's queues itself and parks only when
    /// there is nothing to take (disable with
    /// [`RunOptions::no_caller_assist`]). Calling this from a worker
    /// task of the same pool returns [`GraphError::RunFromWorker`].
    pub fn run(&mut self, pool: &ThreadPool) -> Result<(), GraphError> {
        self.run_with_options(pool, RunOptions::default())
    }

    /// [`TaskGraph::run`] with explicit [`RunOptions`] (e.g. disabling
    /// inline continuation for the scheduling ablation).
    pub fn run_with_options(&mut self, pool: &ThreadPool, options: RunOptions) -> Result<(), GraphError> {
        self.validate()?;
        run_graph(self, pool, options)
    }

    /// [`TaskGraph::run`] that **refuses instead of waiting** when the
    /// pool's admission budget
    /// ([`crate::pool::PoolConfig::max_inflight_runs`] /
    /// [`crate::pool::PoolConfig::max_queued_tasks`]) is exhausted,
    /// returning [`GraphError::Overloaded`] without submitting
    /// anything. On a pool with no budget configured this is exactly
    /// `run`.
    pub fn try_run(&mut self, pool: &ThreadPool) -> Result<(), GraphError> {
        self.try_run_with_options(pool, RunOptions::default())
    }

    /// [`TaskGraph::try_run`] with explicit [`RunOptions`].
    pub fn try_run_with_options(
        &mut self,
        pool: &ThreadPool,
        options: RunOptions,
    ) -> Result<(), GraphError> {
        self.validate()?;
        try_run_graph(self, pool, options)
    }

    /// Launches the graph on `pool` **without blocking**, returning a
    /// [`RunHandle`] that pins the graph borrow for the lifetime of
    /// the run (PR 3). One external thread can keep many graphs in
    /// flight by holding one handle per graph:
    ///
    /// ```
    /// use scheduling::graph::TaskGraph;
    /// use scheduling::pool::ThreadPool;
    /// use std::sync::Arc;
    /// use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
    ///
    /// let pool = ThreadPool::new(2);
    /// let hits = Arc::new(AtomicUsize::new(0));
    /// let mut graphs: Vec<TaskGraph> = (0..4)
    ///     .map(|_| {
    ///         let mut g = TaskGraph::new();
    ///         let h = hits.clone();
    ///         g.add(move || { h.fetch_add(1, Relaxed); });
    ///         g
    ///     })
    ///     .collect();
    /// // All four runs are in flight at once; waiting drains them.
    /// let handles: Vec<_> =
    ///     graphs.iter_mut().map(|g| g.run_async(&pool).unwrap()).collect();
    /// for h in handles {
    ///     h.wait().unwrap();
    /// }
    /// assert_eq!(hits.load(Relaxed), 4);
    /// ```
    ///
    /// Completion is observed through the handle (`is_done`,
    /// `try_wait`, `wait`, or `.await`); dropping the handle blocks
    /// until the run is quiescent. Sealed graphs re-launched through a
    /// handle stay zero-allocation exactly like blocking re-runs.
    /// Like [`TaskGraph::run`], calling this from inside a task of the
    /// same pool returns [`GraphError::RunFromWorker`].
    pub fn run_async(&mut self, pool: &ThreadPool) -> Result<RunHandle<'_>, GraphError> {
        self.run_async_with_options(pool, RunOptions::default())
    }

    /// [`TaskGraph::run_async`] with explicit [`RunOptions`].
    /// `no_state_reuse` and `no_caller_assist` are ignored for async
    /// runs (the handle always uses the graph-owned state slot, and
    /// handle waiters park instead of assisting — see [`RunOptions`]).
    pub fn run_async_with_options(
        &mut self,
        pool: &ThreadPool,
        options: RunOptions,
    ) -> Result<RunHandle<'_>, GraphError> {
        self.validate()?;
        run_graph_async(self, pool, options)
    }
}

impl Drop for TaskGraph {
    /// Waits for any still-in-flight run before the nodes and topology
    /// are freed. Reachable only through `mem::forget` of a
    /// [`RunHandle`] (a live handle borrows the graph, and both
    /// blocking runs and handle `Drop` return only at quiescence); in
    /// every normal lifecycle this is two atomic loads.
    fn drop(&mut self) {
        if let Some(state) = &self.run_state {
            state.wait_quiesce();
        }
    }
}

impl std::fmt::Debug for TaskGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TaskGraph")
            .field("tasks", &self.len())
            .field("edges", &self.num_edges())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_shape() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add_named("sink", || {});
        g.succeed(c, &[a, b]);
        assert_eq!(g.len(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.num_predecessors(c), 2);
        assert_eq!(g.successors(a), vec![c]);
        assert_eq!(g.name(c), Some("sink"));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn precede_is_dual_of_succeed() {
        let mut g1 = TaskGraph::new();
        let a1 = g1.add(|| {});
        let b1 = g1.add(|| {});
        g1.succeed(b1, &[a1]);

        let mut g2 = TaskGraph::new();
        let a2 = g2.add(|| {});
        let b2 = g2.add(|| {});
        g2.precede(a2, &[b2]);

        assert_eq!(g1.successors(a1), g2.successors(a2));
        assert_eq!(g1.num_predecessors(b1), g2.num_predecessors(b2));
    }

    #[test]
    fn cycle_detected() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add(|| {});
        g.succeed(b, &[a]);
        g.succeed(c, &[b]);
        g.succeed(a, &[c]); // a -> b -> c -> a
        match g.validate() {
            Err(GraphError::Cycle { stuck }) => {
                assert_eq!(stuck.len(), 3);
            }
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn diamond_is_acyclic() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add(|| {});
        let d = g.add(|| {});
        g.succeed(b, &[a]);
        g.succeed(c, &[a]);
        g.succeed(d, &[b, c]);
        assert!(g.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "cannot depend on itself")]
    fn self_loop_panics() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        g.succeed(a, &[a]);
    }

    #[test]
    fn dot_export_contains_nodes_and_edges() {
        let mut g = TaskGraph::new();
        let a = g.add_named("fetch \"data\"", || {});
        let b = g.add(|| {});
        g.succeed(b, &[a]);
        let dot = g.to_dot();
        assert!(dot.starts_with("digraph taskgraph {"));
        assert!(dot.contains("n0 [label=\"fetch \\\"data\\\"\"];"));
        assert!(dot.contains("n1;"));
        assert!(dot.contains("n0 -> n1;"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn seal_builds_csr_and_mutation_unseals() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        let c = g.add(|| {});
        g.succeed(c, &[a, b]);
        assert!(!g.is_sealed());
        g.seal().unwrap();
        assert!(g.is_sealed());
        {
            let t = g.topology.as_ref().unwrap();
            assert_eq!(t.node_count(), 3);
            assert_eq!(t.successors(0), &[2]);
            assert_eq!(t.successors(1), &[2]);
            assert_eq!(t.successors(2), &[] as &[u32]);
            assert_eq!(t.sched().sources, vec![0, 1]);
            t.reset_pending();
            assert_eq!(t.pending(0).load(Ordering::Relaxed), 0);
            assert_eq!(t.pending(2).load(Ordering::Relaxed), 2);
        }
        // Every mutation kind drops the topology.
        g.add(|| {});
        assert!(!g.is_sealed());
        g.seal().unwrap();
        g.succeed(NodeId(3), &[c]);
        assert!(!g.is_sealed());
        g.seal().unwrap();
        g.precede(a, &[NodeId(3)]);
        assert!(!g.is_sealed());
        // Sealing a cyclic graph fails and leaves it unsealed.
        g.succeed(a, &[c]); // adds c -> a, closing the a -> c -> a cycle
        assert!(g.seal().is_err());
        assert!(!g.is_sealed());
    }

    #[test]
    fn topology_pending_counters_span_many_lines() {
        // More nodes than one padded line holds, so indexing crosses
        // line boundaries.
        let mut g = TaskGraph::new();
        let ids: Vec<_> = (0..100).map(|_| g.add(|| {})).collect();
        for w in ids.windows(2) {
            g.succeed(w[1], &[w[0]]);
        }
        g.seal().unwrap();
        let t = g.topology.as_ref().unwrap();
        t.reset_pending();
        assert_eq!(t.pending(0).load(Ordering::Relaxed), 0);
        for i in 1..100 {
            assert_eq!(t.pending(i).load(Ordering::Relaxed), 1, "node {i}");
            assert_eq!(t.successors(i - 1), &[i as u32]);
        }
        assert_eq!(t.sched().sources, vec![0]);
    }

    #[test]
    fn weights_and_ranks_follow_seal_lifecycle() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let heavy = g.add_weighted(10, || {});
        let light = g.add(|| {});
        let sink = g.add(|| {});
        g.succeed(heavy, &[a]);
        g.succeed(light, &[a]);
        g.succeed(sink, &[heavy, light]);
        assert_eq!(g.weight(a), 1);
        assert_eq!(g.weight(heavy), 10);
        // Unsealed: no ranks yet.
        assert_eq!(g.rank(a), None);
        g.seal().unwrap();
        assert_eq!(g.rank(sink), Some(1));
        assert_eq!(g.rank(heavy), Some(11));
        assert_eq!(g.rank(light), Some(2));
        assert_eq!(g.rank(a), Some(12), "source rank follows the heavy arm");
        // set_weight un-seals (ranks depend on weights) and the next
        // seal recomputes.
        g.set_weight(light, 100);
        assert!(!g.is_sealed());
        assert_eq!(g.rank(a), None);
        g.seal().unwrap();
        assert_eq!(g.rank(a), Some(102));
    }

    #[test]
    #[should_panic(expected = "NodeId out of range")]
    fn set_weight_rejects_foreign_ids() {
        let mut g = TaskGraph::new();
        g.add(|| {});
        g.set_weight(NodeId(5), 2);
    }

    #[test]
    fn validation_cache_invalidated_on_mutation() {
        let mut g = TaskGraph::new();
        let a = g.add(|| {});
        let b = g.add(|| {});
        g.succeed(b, &[a]);
        assert!(g.validate().is_ok());
        g.succeed(a, &[b]); // now cyclic
        assert!(g.validate().is_err());
    }
}
