//! Data-parallel primitives over blocked index ranges (PR 10).
//!
//! [`parallel_for`] and [`parallel_reduce`] split an index range into
//! contiguous blocks — the block count follows Shoshany's heuristic of
//! `num_threads × oversubscription` (arXiv:2105.00613), floored by a
//! caller-supplied `grain` — and execute the blocks on the pool as one
//! shard-pinnable burst of inline tasks (each queued task captures a
//! single `Arc`, so the PR 1 inline `RawTask` cell applies and the
//! submission makes one batch publish + one batched wakeup).
//!
//! Scheduling is *claim-based* rather than pre-assigned: every helper
//! task and the calling thread loop on a shared claim counter, so
//!
//! * index coverage is exactly-once by construction (each block index
//!   is produced by one `fetch_add` winner);
//! * blocks load-balance dynamically — a worker stuck behind a slow
//!   block simply stops claiming while the others drain the rest;
//! * the caller participates, which makes nested use from inside a
//!   worker deadlock-free even on a one-thread pool: the caller claims
//!   every block itself and the queued helpers no-op.
//!
//! Cancellation and panics ride the PR 6 abort machinery in miniature:
//! a first-wins cause byte is checked at every block boundary, a
//! [`CancelToken`] flips it to *cancelled*, and a panicking body is
//! caught, recorded (first panic wins, with its block index), and
//! surfaced as [`GraphError::NodePanicked`] after the loop quiesces.
//! Like graph runs, a failed loop never tears down pool workers.
//!
//! [`TaskGraph::add_parallel_for`] is the graph-node form: it expands
//! the loop into `start → blocks → join` plain nodes at build time, so
//! a sealed graph re-runs the burst with zero allocations and the
//! blocks show up individually (named `{name}/b{i}[{lo}..{hi})`,
//! weighted by block length for PR 4 ranking) in `RunProfile` and
//! Chrome traces.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::pool::task::RawTask;
use crate::pool::ThreadPool;

use super::{CancelToken, GraphError, NodeId, TaskGraph};

/// Default blocks-per-worker multiplier: enough surplus blocks that a
/// straggler block cannot serialize the tail of the loop, few enough
/// that per-block overhead stays invisible next to real work.
pub const DEFAULT_OVERSUBSCRIPTION: usize = 4;

const CAUSE_NONE: u8 = 0;
const CAUSE_CANCEL: u8 = 1;
const CAUSE_PANIC: u8 = 2;

/// Tuning knobs for [`parallel_for_with`] / [`parallel_reduce_with`].
#[derive(Clone, Debug)]
pub struct ParOptions {
    /// Minimum indices per block (default 1). Raise it when the body
    /// is so cheap that per-block scheduling would dominate; the ABL-10
    /// bench sweeps this knob.
    pub grain: usize,
    /// Blocks-per-worker multiplier (default
    /// [`DEFAULT_OVERSUBSCRIPTION`]).
    pub oversubscription: usize,
    /// Pin the helper burst to one shard (PR 5 locality), as
    /// [`ThreadPool::submit_to_shard`] would.
    pub shard: Option<usize>,
    /// Cooperative cancellation, checked between blocks.
    pub cancel: Option<CancelToken>,
}

impl Default for ParOptions {
    fn default() -> Self {
        ParOptions {
            grain: 1,
            oversubscription: DEFAULT_OVERSUBSCRIPTION,
            shard: None,
            cancel: None,
        }
    }
}

impl ParOptions {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn grain(mut self, grain: usize) -> Self {
        self.grain = grain;
        self
    }

    pub fn oversubscription(mut self, oversubscription: usize) -> Self {
        self.oversubscription = oversubscription;
        self
    }

    pub fn shard(mut self, shard: usize) -> Self {
        self.shard = Some(shard);
        self
    }

    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }
}

/// `(block_size, num_blocks)` for `n` indices on `threads` workers.
fn split_blocks(n: usize, threads: usize, opts: &ParOptions) -> (usize, usize) {
    let desired = (threads.max(1) * opts.oversubscription.max(1)).max(1);
    let block = opts.grain.max(1).max((n + desired - 1) / desired);
    (block, (n + block - 1) / block)
}

/// Type-erased pointer to the caller-stack body closure. Sound to ship
/// across threads because the pointee is `Sync` (enforced by the
/// `F: Sync` bound where the pointer is created) and is only
/// dereferenced for claimed blocks, all of which complete before the
/// owning stack frame returns.
struct SendPtr(*const ());

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Shared state of one in-flight loop. Helper tasks hold it behind an
/// `Arc`; a helper that arrives after every block is claimed touches
/// only the atomics (never `body`), so helpers outliving the call —
/// still queued while the caller has already returned — are harmless.
struct ParCore {
    /// Next unclaimed block index; claimed by `fetch_add`.
    next: AtomicUsize,
    /// Blocks not yet finished; the decrement to zero notifies the
    /// caller (same finisher handshake as `pool::scope`).
    remaining: AtomicUsize,
    nblocks: usize,
    start: usize,
    block: usize,
    end: usize,
    /// First-wins abort cause (`CAUSE_*`), checked per block.
    cause: AtomicU8,
    cancel: Option<CancelToken>,
    /// Block index + rendered payload of the first panic.
    panic: Mutex<Option<(usize, String)>>,
    body: SendPtr,
    call: unsafe fn(*const (), Range<usize>),
    done_mutex: Mutex<()>,
    done_cv: Condvar,
}

fn render_payload(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic payload>".to_string())
}

/// Runs one claimed block: abort-cause check, body, finisher.
fn run_block(core: &ParCore, b: usize) {
    if core.cause.load(Ordering::Acquire) == CAUSE_NONE
        && core.cancel.as_ref().map_or(false, |t| t.is_cancelled())
    {
        let _ = core.cause.compare_exchange(
            CAUSE_NONE,
            CAUSE_CANCEL,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }
    if core.cause.load(Ordering::Acquire) == CAUSE_NONE {
        let lo = core.start + b * core.block;
        let hi = (lo + core.block).min(core.end);
        // SAFETY: `b < nblocks` (checked by the claim loop), so the
        // caller's stack frame — which owns the closure behind
        // `body` — is still alive: it cannot return until `remaining`
        // hits zero, and this block has not yet decremented it.
        let hit = catch_unwind(AssertUnwindSafe(|| unsafe { (core.call)(core.body.0, lo..hi) }));
        if let Err(payload) = hit {
            if core
                .cause
                .compare_exchange(CAUSE_NONE, CAUSE_PANIC, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let mut slot = core.panic.lock().unwrap_or_else(|e| e.into_inner());
                *slot = Some((b, render_payload(payload)));
            }
        }
    }
    if core.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Lock/unlock pairs with the caller's wait so the final
        // notify cannot slip between its counter check and its park.
        drop(core.done_mutex.lock().unwrap_or_else(|e| e.into_inner()));
        core.done_cv.notify_all();
    }
}

/// Claims and runs blocks until none are left. Shared by the helper
/// tasks and the calling thread.
fn drain(core: &ParCore) {
    loop {
        let b = core.next.fetch_add(1, Ordering::Relaxed);
        if b >= core.nblocks {
            return;
        }
        run_block(core, b);
    }
}

/// Runs `body` over every sub-range of `range`, split into blocks of
/// at least `grain` indices, in parallel on `pool`. Blocks cover the
/// range exactly once; the call returns when every block has finished.
///
/// The calling thread participates (it claims blocks like a worker),
/// so this is safe to call from inside a pool task — a nested loop on
/// a saturated or one-thread pool degrades to serial execution instead
/// of deadlocking.
///
/// # Errors
///
/// [`GraphError::NodePanicked`] if a body panicked (`node` is the
/// block index; remaining blocks are skipped), [`GraphError::Cancelled`]
/// if a [`ParOptions::cancel_token`] fired mid-loop. The pool survives
/// either outcome.
pub fn parallel_for<F>(
    pool: &ThreadPool,
    range: Range<usize>,
    grain: usize,
    body: F,
) -> Result<(), GraphError>
where
    F: Fn(Range<usize>) + Sync,
{
    parallel_for_with(pool, range, &ParOptions::new().grain(grain), body)
}

/// [`parallel_for`] with the full option set ([`ParOptions`]).
pub fn parallel_for_with<F>(
    pool: &ThreadPool,
    range: Range<usize>,
    opts: &ParOptions,
    body: F,
) -> Result<(), GraphError>
where
    F: Fn(Range<usize>) + Sync,
{
    let n = range.end.saturating_sub(range.start);
    if n == 0 {
        return Ok(());
    }
    let (block, nblocks) = split_blocks(n, pool.num_threads(), opts);

    /// Monomorphized un-eraser for `ParCore::call`.
    unsafe fn call_shim<F: Fn(Range<usize>) + Sync>(p: *const (), r: Range<usize>) {
        (*(p as *const F))(r);
    }

    let core = Arc::new(ParCore {
        next: AtomicUsize::new(0),
        remaining: AtomicUsize::new(nblocks),
        nblocks,
        start: range.start,
        block,
        end: range.end,
        cause: AtomicU8::new(CAUSE_NONE),
        cancel: opts.cancel.clone(),
        panic: Mutex::new(None),
        body: SendPtr(&body as *const F as *const ()),
        call: call_shim::<F>,
        done_mutex: Mutex::new(()),
        done_cv: Condvar::new(),
    });

    // One helper per surplus block, published as a single burst. Each
    // helper captures only the `Arc` (one word — stored inline in the
    // task cell, no per-task allocation).
    if nblocks > 1 {
        pool.inner().submit_job_batch_sharded(
            opts.shard,
            (1..nblocks).map(|_| {
                let core = core.clone();
                RawTask::closure(move || drain(&core))
            }),
        );
    }
    drain(&core);

    // Every block is claimed by now (the drain above only returns once
    // `next` passes `nblocks`); wait for claimed blocks still running
    // on workers. The caller ran at least one block itself, so on an
    // idle pool this wait is usually already satisfied.
    {
        let mut guard = core.done_mutex.lock().unwrap_or_else(|e| e.into_inner());
        while core.remaining.load(Ordering::Acquire) > 0 {
            guard = core
                .done_cv
                .wait(guard)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    match core.cause.load(Ordering::Acquire) {
        CAUSE_CANCEL => Err(GraphError::Cancelled),
        CAUSE_PANIC => {
            let taken = core.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
            let (b, payload) =
                taken.unwrap_or((0, "<panic payload missing>".to_string()));
            Err(GraphError::NodePanicked {
                node: b,
                name: None,
                payload,
            })
        }
        _ => Ok(()),
    }
}

/// Parallel reduction over `range`: each block folds its indices with
/// `body` starting from a clone of `identity`, and block results merge
/// through `join`. Blocks finish in a nondeterministic order, so
/// `join` must be associative and commutative (sums, min/max, unions —
/// not string concatenation).
pub fn parallel_reduce<T, B, J>(
    pool: &ThreadPool,
    range: Range<usize>,
    grain: usize,
    identity: T,
    body: B,
    join: J,
) -> Result<T, GraphError>
where
    T: Clone + Send,
    B: Fn(Range<usize>, T) -> T + Sync,
    J: Fn(T, T) -> T + Sync,
{
    parallel_reduce_with(pool, range, &ParOptions::new().grain(grain), identity, body, join)
}

/// [`parallel_reduce`] with the full option set ([`ParOptions`]).
pub fn parallel_reduce_with<T, B, J>(
    pool: &ThreadPool,
    range: Range<usize>,
    opts: &ParOptions,
    identity: T,
    body: B,
    join: J,
) -> Result<T, GraphError>
where
    T: Clone + Send,
    B: Fn(Range<usize>, T) -> T + Sync,
    J: Fn(T, T) -> T + Sync,
{
    let acc: Mutex<Option<T>> = Mutex::new(None);
    parallel_for_with(pool, range, opts, |r: Range<usize>| {
        let local = body(r, identity.clone());
        let mut slot = acc.lock().unwrap_or_else(|e| e.into_inner());
        *slot = Some(match slot.take() {
            Some(prev) => join(prev, local),
            None => local,
        });
    })?;
    let folded = acc.into_inner().unwrap_or_else(|e| e.into_inner());
    Ok(folded.unwrap_or(identity))
}

impl TaskGraph {
    /// Adds a data-parallel loop to the graph as a `start → blocks →
    /// join` fan-out/fan-in: `blocks` leaf nodes each running `body`
    /// over one contiguous sub-range, named `{name}/b{i}[{lo}..{hi})`
    /// and weighted by block length so PR 4 ranking and the PR 9
    /// profile/trace see them individually. Returns `(start, join)`
    /// for wiring into the surrounding graph.
    ///
    /// The expansion happens here, at build time — after [`seal`],
    /// re-runs submit the burst through the sealed CSR topology with
    /// zero allocations, like any other nodes.
    ///
    /// [`seal`]: TaskGraph::seal
    pub fn add_parallel_for<F>(
        &mut self,
        name: &str,
        range: Range<usize>,
        blocks: usize,
        body: F,
    ) -> (NodeId, NodeId)
    where
        F: Fn(Range<usize>) + Send + Sync + 'static,
    {
        let start = self.add_named(format!("{name}/start"), || {});
        let join = self.add_named(format!("{name}/join"), || {});
        let n = range.end.saturating_sub(range.start);
        if n == 0 {
            self.precede(start, &[join]);
            return (start, join);
        }
        let blocks = blocks.max(1).min(n);
        let block = (n + blocks - 1) / blocks;
        let body = Arc::new(body);
        let mut ids = Vec::with_capacity(blocks);
        let mut lo = range.start;
        let mut i = 0usize;
        while lo < range.end {
            let hi = (lo + block).min(range.end);
            let f = Arc::clone(&body);
            let id = self.add_named(format!("{name}/b{i}[{lo}..{hi})"), move || f(lo..hi));
            self.set_weight(id, (hi - lo).min(u32::MAX as usize) as u32);
            ids.push(id);
            lo = hi;
            i += 1;
        }
        self.precede(start, &ids);
        self.succeed(join, &ids);
        (start, join)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        parallel_for(&pool, 0..n, 1, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn grain_floors_block_size() {
        let (block, nblocks) = split_blocks(100, 4, &ParOptions::new().grain(40));
        assert_eq!(block, 40);
        assert_eq!(nblocks, 3);
        // Without a grain: threads × oversubscription blocks.
        let (block, nblocks) = split_blocks(1600, 4, &ParOptions::new());
        assert_eq!(nblocks, 16);
        assert_eq!(block, 100);
        // Tiny ranges never produce empty blocks.
        let (_, nblocks) = split_blocks(3, 8, &ParOptions::new());
        assert!(nblocks <= 3 && nblocks >= 1);
    }

    #[test]
    fn reduce_sums_the_range() {
        let pool = ThreadPool::new(4);
        let n = 5000u64;
        let sum = parallel_reduce(
            &pool,
            0..n as usize,
            64,
            0u64,
            |r, acc| acc + r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(sum, n * (n - 1) / 2);
    }

    #[test]
    fn empty_range_is_ok() {
        let pool = ThreadPool::new(2);
        parallel_for(&pool, 7..7, 1, |_| panic!("never called")).unwrap();
    }

    #[test]
    fn precancelled_token_cancels() {
        let pool = ThreadPool::new(2);
        let token = CancelToken::new();
        token.cancel();
        let opts = ParOptions::new().cancel_token(token);
        let err = parallel_for_with(&pool, 0..1000, &opts, |_| {}).unwrap_err();
        assert!(matches!(err, GraphError::Cancelled));
    }

    #[test]
    fn body_panic_surfaces_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let err = parallel_for(&pool, 0..100, 10, |r| {
            if r.contains(&42) {
                panic!("boom at 42");
            }
        })
        .unwrap_err();
        match err {
            GraphError::NodePanicked { payload, .. } => assert!(payload.contains("boom")),
            other => panic!("unexpected error: {other:?}"),
        }
        // The loop aborted cleanly; the pool still runs work.
        parallel_for(&pool, 0..100, 10, |_| {}).unwrap();
    }

    #[test]
    fn graph_node_form_runs_and_reruns() {
        let pool = ThreadPool::new(2);
        let n = 257;
        let hits: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let h = hits.clone();
        let mut g = TaskGraph::new();
        let (start, join) = g.add_parallel_for("loop", 0..n, 8, move |r| {
            for i in r {
                h[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        let pre = g.add(|| {});
        let post = g.add(|| {});
        g.precede(pre, &[start]);
        g.succeed(post, &[join]);
        g.seal().unwrap();
        for pass in 1..=3u32 {
            g.run(&pool).unwrap();
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == pass));
        }
    }
}
