//! Minimal argument parser.

use std::collections::{HashMap, HashSet};
use std::str::FromStr;

/// Argument-parsing errors.
#[derive(Debug, PartialEq, Eq)]
pub enum ArgError {
    /// A `--key` that expected a value hit the end of the argument list.
    MissingValue(String),
    /// A value failed to parse as the requested type.
    BadValue {
        /// Option name (without `--`).
        key: String,
        /// Raw value that failed to parse.
        value: String,
        /// Type name that was expected.
        expected: &'static str,
    },
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingValue(k) => write!(f, "--{k} expects a value"),
            ArgError::BadValue { key, value, expected } => {
                write!(f, "--{key}={value} is not a valid {expected}")
            }
        }
    }
}

impl std::error::Error for ArgError {}

/// Parsed arguments: positionals plus `--key value` / `--key=value`
/// options. Keys seen without a value become boolean flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    positional: Vec<String>,
    options: HashMap<String, String>,
    flags: HashSet<String>,
}

/// Option keys that take values (everything else starting with `--` is
/// treated as a boolean flag when no `=value` is attached).
const VALUE_KEYS: &[&str] = &[
    "threads", "executor", "n", "size", "depth", "layers", "width", "p", "seed", "work",
    "schedule", "tile", "config", "samples", "warmup", "repeat", "artifacts", "out",
];

impl Args {
    /// Parses from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, ArgError> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if VALUE_KEYS.contains(&key) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(key.to_string(), v);
                        }
                        None => return Err(ArgError::MissingValue(key.to_string())),
                    }
                } else {
                    out.flags.insert(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parses the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Self, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument by index.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// All positionals.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Raw option value.
    pub fn raw(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag presence (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.contains(key)
    }

    /// Typed option with default.
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> Result<T, ArgError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| ArgError::BadValue {
                key: key.to_string(),
                value: v.clone(),
                expected: std::any::type_name::<T>(),
            }),
        }
    }

    /// Typed option, `None` when absent.
    pub fn get_opt<T: FromStr>(&self, key: &str) -> Result<Option<T>, ArgError> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| ArgError::BadValue {
                    key: key.to_string(),
                    value: v.clone(),
                    expected: std::any::type_name::<T>(),
                }),
        }
    }

    /// Merges defaults from a config map (CLI wins).
    pub fn merge_defaults(&mut self, defaults: &HashMap<String, String>) {
        for (k, v) in defaults {
            self.options.entry(k.clone()).or_insert_with(|| v.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["bench", "fib", "--threads", "4", "--n=30", "--verbose"]);
        assert_eq!(a.positional(0), Some("bench"));
        assert_eq!(a.positional(1), Some("fib"));
        assert_eq!(a.get::<usize>("threads", 1).unwrap(), 4);
        assert_eq!(a.get::<u32>("n", 0).unwrap(), 30);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get::<usize>("threads", 7).unwrap(), 7);
        assert_eq!(a.get_opt::<usize>("threads").unwrap(), None);
    }

    #[test]
    fn bad_value_reports_key() {
        let a = parse(&["--threads", "lots"]);
        match a.get::<usize>("threads", 1) {
            Err(ArgError::BadValue { key, value, .. }) => {
                assert_eq!(key, "threads");
                assert_eq!(value, "lots");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
    }

    #[test]
    fn missing_value_detected() {
        let err = Args::parse(vec!["--threads".to_string()]).unwrap_err();
        assert_eq!(err, ArgError::MissingValue("threads".to_string()));
    }

    #[test]
    fn merge_defaults_cli_wins() {
        let mut a = parse(&["--threads", "2"]);
        let mut d = HashMap::new();
        d.insert("threads".to_string(), "8".to_string());
        d.insert("seed".to_string(), "42".to_string());
        a.merge_defaults(&d);
        assert_eq!(a.get::<usize>("threads", 0).unwrap(), 2);
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 42);
    }
}
