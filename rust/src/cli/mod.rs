//! Command-line and config-file parsing for the launcher binary.
//!
//! Hand-rolled (the offline vendor set has no clap): `--key value`,
//! `--key=value`, boolean `--flag`, positional args, plus an optional
//! `key = value` config file that CLI flags override.

mod args;
mod config;

pub use args::{ArgError, Args};
pub use config::Config;
