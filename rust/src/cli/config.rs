//! `key = value` config files (a TOML subset: comments, blank lines,
//! bare keys; no sections needed for a launcher this size).

use std::collections::HashMap;
use std::path::Path;

/// Parsed config file.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parses config text. Lines: `key = value`, `# comment`, blank.
    /// Values may be quoted with `"`.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let mut val = v.trim();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = &val[1..val.len() - 1];
            }
            values.insert(key.to_string(), val.to_string());
        }
        Ok(Self { values })
    }

    /// Loads and parses a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("reading {}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Raw value lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// The underlying map (for [`crate::cli::Args::merge_defaults`]).
    pub fn values(&self) -> &HashMap<String, String> {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let c = Config::parse(
            "# pool settings\n\
             threads = 4\n\
             executor = \"scheduling\"\n\
             \n\
             seed=42\n",
        )
        .unwrap();
        assert_eq!(c.get("threads"), Some("4"));
        assert_eq!(c.get("executor"), Some("scheduling"));
        assert_eq!(c.get("seed"), Some("42"));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn rejects_lines_without_equals() {
        assert!(Config::parse("threads 4").is_err());
        assert!(Config::parse("= 4").is_err());
    }

    #[test]
    fn quoted_values_unwrapped() {
        let c = Config::parse("name = \"hello world\"").unwrap();
        assert_eq!(c.get("name"), Some("hello world"));
    }
}
