//! The flight recorder: per-worker lock-free event rings.
//!
//! Every worker owns a fixed-capacity ring of compact binary events
//! (3 × `u64` words each); recording is one relaxed head bump plus
//! three relaxed stores and a monotonic clock read — a few
//! nanoseconds, **zero allocation** (proven by the `obs_alloc` test
//! tier), and no synchronization with other workers. Two extra lanes
//! follow the worker lanes: the caller-assist helper lane (mirroring
//! the pool's metrics layout) and an *external* lane shared by
//! non-worker threads (admission callers, the serving gate, the timer
//! thread), whose multi-writer head bump is a relaxed `fetch_add`.
//!
//! ## Overwrite semantics
//!
//! A ring keeps the **most recent `capacity` events per lane** and
//! silently overwrites the oldest beyond that — a flight recorder,
//! not a log: after an incident the dump answers "what were the last
//! few thousand things each worker did", never "everything since
//! boot". Lane head counters keep counting past capacity, so a dump
//! reports exactly how many events were overwritten. A dump taken
//! while workers are still recording is a best-effort snapshot: each
//! word of an event is individually untorn (they are plain atomics),
//! but an event racing the reader at the ring head may pair the
//! timestamp of one write with the payload of another. Dumps taken at
//! a quiescent point (test assertions, post-failure post-mortems) are
//! exact.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// What happened. Encoded in the high byte of an event's second word;
/// `0` is reserved for "slot never written".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// A graph node began executing (`a` = node id, `b` = run
    /// generation).
    TaskStart = 1,
    /// A graph node finished (`a` = node id, `b` = duration in ns).
    TaskEnd = 2,
    /// A steal succeeded (`a` = victim worker, `b` = extra tasks moved
    /// by the batched variant).
    Steal = 3,
    /// A steal attempt found the victim empty or lost the race
    /// (`a` = victim worker).
    StealFail = 4,
    /// The worker parked on its eventcount (start of an idle spell).
    Park = 5,
    /// The worker woke from a park.
    Wake = 6,
    /// Admission granted a run slot (`a` = priority class code,
    /// `b` = inflight runs after the grant).
    AdmitOk = 7,
    /// Admission blocked the caller until a slot freed (`a` = class
    /// code).
    AdmitBlocked = 8,
    /// Admission shed the run (`a` = class code).
    AdmitShed = 9,
    /// Admission rejected the run as deadline-infeasible (`b` =
    /// remaining budget in ns).
    AdmitDeadline = 10,
    /// A run aborted (`a` = cause code: 1 cancel, 2 deadline,
    /// 3 panic; `b` = run generation).
    Abort = 11,
    /// The serving gate scheduled a retry (`a` = tenant id, `b` =
    /// backoff in ns).
    RetrySched = 12,
    /// The brownout controller changed level (`a` = new level, `b` =
    /// previous level).
    Brownout = 13,
}

impl EventKind {
    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => Self::TaskStart,
            2 => Self::TaskEnd,
            3 => Self::Steal,
            4 => Self::StealFail,
            5 => Self::Park,
            6 => Self::Wake,
            7 => Self::AdmitOk,
            8 => Self::AdmitBlocked,
            9 => Self::AdmitShed,
            10 => Self::AdmitDeadline,
            11 => Self::Abort,
            12 => Self::RetrySched,
            13 => Self::Brownout,
            _ => return None,
        })
    }

    /// Short name used by the Chrome-trace converter and tests.
    pub fn name(self) -> &'static str {
        match self {
            Self::TaskStart => "task_start",
            Self::TaskEnd => "task_end",
            Self::Steal => "steal",
            Self::StealFail => "steal_fail",
            Self::Park => "park",
            Self::Wake => "wake",
            Self::AdmitOk => "admit_ok",
            Self::AdmitBlocked => "admit_blocked",
            Self::AdmitShed => "admit_shed",
            Self::AdmitDeadline => "admit_deadline",
            Self::Abort => "abort",
            Self::RetrySched => "retry_sched",
            Self::Brownout => "brownout",
        }
    }
}

/// One decoded event, as surfaced by [`FlightDump`].
#[derive(Debug, Clone, Copy)]
pub struct FlightEvent {
    /// Nanoseconds since the recorder's epoch (pool construction).
    pub t_ns: u64,
    /// Originating lane: worker index, the helper lane, or the
    /// external lane (see [`FlightRecorder::external_lane`]).
    pub lane: u16,
    /// What happened.
    pub kind: EventKind,
    /// First payload word (meaning depends on `kind`).
    pub a: u32,
    /// Second payload word (meaning depends on `kind`).
    pub b: u64,
}

/// One ring slot: three plain atomic words. `w0` (the timestamp,
/// written last / read first) doubles as the "slot is live" flag —
/// timestamps are clamped to ≥ 1 so a zero means "never written".
struct Slot {
    w0: AtomicU64,
    w1: AtomicU64,
    w2: AtomicU64,
}

struct Ring {
    /// Monotone event counter for this lane; slot = `head & mask`.
    head: AtomicUsize,
    slots: Box<[Slot]>,
    mask: usize,
}

impl Ring {
    fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(2);
        Self {
            head: AtomicUsize::new(0),
            slots: (0..cap)
                .map(|_| Slot {
                    w0: AtomicU64::new(0),
                    w1: AtomicU64::new(0),
                    w2: AtomicU64::new(0),
                })
                .collect(),
            mask: cap - 1,
        }
    }
}

/// The per-pool flight recorder. Owned (behind `Arc`) by the pool;
/// serve-layer components hold clones to record into the external
/// lane. See the module docs for the overwrite and torn-read
/// semantics.
pub struct FlightRecorder {
    epoch: Instant,
    lanes: Vec<Ring>,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("lanes", &self.lanes.len())
            .field("capacity", &(self.lanes.first().map_or(0, |r| r.mask + 1)))
            .finish()
    }
}

impl FlightRecorder {
    /// Creates a recorder with `worker_lanes` single-writer lanes
    /// (workers plus the helper lane, matching the pool's metrics
    /// layout) plus one shared external lane, each holding
    /// `capacity_per_lane` events (rounded up to a power of two).
    /// `epoch` anchors every timestamp — pass the pool's construction
    /// instant so flight timestamps align with run-profile spans.
    pub fn new(worker_lanes: usize, capacity_per_lane: usize, epoch: Instant) -> Self {
        Self {
            epoch,
            lanes: (0..worker_lanes + 1).map(|_| Ring::new(capacity_per_lane)).collect(),
        }
    }

    /// Index of the shared multi-writer lane for non-worker threads.
    pub fn external_lane(&self) -> usize {
        self.lanes.len() - 1
    }

    /// Records one event into `lane`. Lock-free, allocation-free; a
    /// few relaxed atomics plus one monotonic clock read. Out-of-range
    /// lanes clamp to the external lane rather than panic — the record
    /// path must never be able to take a worker down.
    #[inline]
    pub fn record(&self, lane: usize, kind: EventKind, a: u32, b: u64) {
        let lane = lane.min(self.lanes.len() - 1);
        let ring = &self.lanes[lane];
        let idx = ring.head.fetch_add(1, Ordering::Relaxed) & ring.mask;
        let slot = &ring.slots[idx];
        let t = (self.epoch.elapsed().as_nanos() as u64).max(1);
        slot.w1.store(((kind as u64) << 56) | ((lane as u64 & 0xffff) << 32) | a as u64, Ordering::Relaxed);
        slot.w2.store(b, Ordering::Relaxed);
        // Timestamp last with Release: a reader that observes w0 sees
        // the matching payload words (absent a ring-wrap race, which
        // the module docs call out as best-effort).
        slot.w0.store(t, Ordering::Release);
    }

    /// Convenience: records into the external lane.
    #[inline]
    pub fn record_external(&self, kind: EventKind, a: u32, b: u64) {
        self.record(self.external_lane(), kind, a, b);
    }

    /// Snapshots every lane into a time-sorted [`FlightDump`]. This
    /// allocates (it is the *dump* path, not the record path) and may
    /// observe torn events at a live ring head — see the module docs.
    pub fn dump(&self) -> FlightDump {
        let mut events = Vec::new();
        let mut recorded = 0u64;
        let mut overwritten = 0u64;
        for ring in &self.lanes {
            let head = ring.head.load(Ordering::Relaxed);
            recorded += head as u64;
            overwritten += head.saturating_sub(ring.mask + 1) as u64;
            for slot in ring.slots.iter() {
                let t = slot.w0.load(Ordering::Acquire);
                if t == 0 {
                    continue;
                }
                let w1 = slot.w1.load(Ordering::Relaxed);
                let b = slot.w2.load(Ordering::Relaxed);
                let Some(kind) = EventKind::from_u8((w1 >> 56) as u8) else {
                    continue;
                };
                events.push(FlightEvent {
                    t_ns: t,
                    lane: ((w1 >> 32) & 0xffff) as u16,
                    kind,
                    a: w1 as u32,
                    b,
                });
            }
        }
        events.sort_by_key(|e| e.t_ns);
        FlightDump { events, recorded, overwritten }
    }
}

/// A decoded, time-sorted snapshot of every lane's ring.
#[derive(Debug, Clone)]
pub struct FlightDump {
    /// All live events, sorted by timestamp.
    pub events: Vec<FlightEvent>,
    /// Total events ever recorded (including overwritten ones).
    pub recorded: u64,
    /// Events lost to ring overwrite (`recorded - retained`).
    pub overwritten: u64,
}

fn push_json_escaped(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

impl FlightDump {
    /// Converts the dump to Chrome-trace JSON (load in
    /// `chrome://tracing` or Perfetto). Task start/end pairs become
    /// duration (`ph:"X"`) events on the originating lane's track;
    /// everything else becomes an instant (`ph:"i"`) event.
    pub fn to_chrome_trace(&self) -> String {
        self.to_chrome_trace_with_edges(&[])
    }

    /// Like [`FlightDump::to_chrome_trace`], additionally emitting
    /// flow arrows (`ph:"s"`/`ph:"f"`) along the given graph edges
    /// `(pred, succ)`: each completed predecessor span points at each
    /// successor span of the same run generation, so the dependency
    /// structure is visible on the timeline.
    pub fn to_chrome_trace_with_edges(&self, edges: &[(u32, u32)]) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut sep = |out: &mut String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
        };
        // Open spans per (lane, node): TaskStart awaiting its TaskEnd.
        let mut open: Vec<(u16, u32, u64, u64)> = Vec::new(); // (lane, node, start_ns, gen)
        // Completed spans for flow binding: (node, gen) -> (start, end, lane).
        let mut spans: Vec<(u32, u64, u64, u64, u16)> = Vec::new();
        for e in &self.events {
            match e.kind {
                EventKind::TaskStart => open.push((e.lane, e.a, e.t_ns, e.b)),
                EventKind::TaskEnd => {
                    let found = open
                        .iter()
                        .rposition(|&(lane, node, _, _)| lane == e.lane && node == e.a);
                    if let Some(i) = found {
                        let (lane, node, start, gen) = open.swap_remove(i);
                        // TaskEnd.b is the duration; the recorded start
                        // timestamp wins for placement.
                        let end = start + e.b.max(e.t_ns.saturating_sub(start));
                        sep(&mut out);
                        out.push_str(&format!(
                            "{{\"name\":\"n{node}\",\"cat\":\"task\",\"ph\":\"X\",\"ts\":{}.{:03},\"dur\":{}.{:03},\"pid\":0,\"tid\":{lane},\"args\":{{\"node\":{node},\"gen\":{gen}}}}}",
                            start / 1000,
                            start % 1000,
                            (end - start) / 1000,
                            (end - start) % 1000,
                        ));
                        spans.push((node, gen, start, end, lane));
                    }
                }
                _ => {
                    sep(&mut out);
                    let mut name = String::new();
                    push_json_escaped(&mut name, e.kind.name());
                    out.push_str(&format!(
                        "{{\"name\":\"{name}\",\"cat\":\"sched\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{}.{:03},\"pid\":0,\"tid\":{},\"args\":{{\"a\":{},\"b\":{}}}}}",
                        e.t_ns / 1000,
                        e.t_ns % 1000,
                        e.lane,
                        e.a,
                        e.b,
                    ));
                }
            }
        }
        // Flow arrows along graph edges, per generation.
        let mut flow_id = 0u64;
        for &(pred, succ) in edges {
            for &(n1, g1, _, end1, lane1) in spans.iter().filter(|s| s.0 == pred) {
                for &(n2, g2, start2, _, lane2) in spans.iter().filter(|s| s.0 == succ) {
                    if g1 != g2 {
                        continue;
                    }
                    flow_id += 1;
                    let ts_s = end1.min(start2);
                    sep(&mut out);
                    out.push_str(&format!(
                        "{{\"name\":\"edge\",\"cat\":\"dep\",\"ph\":\"s\",\"id\":{flow_id},\"ts\":{}.{:03},\"pid\":0,\"tid\":{lane1},\"args\":{{\"from\":{n1}}}}}",
                        ts_s / 1000,
                        ts_s % 1000,
                    ));
                    sep(&mut out);
                    out.push_str(&format!(
                        "{{\"name\":\"edge\",\"cat\":\"dep\",\"ph\":\"f\",\"bp\":\"e\",\"id\":{flow_id},\"ts\":{}.{:03},\"pid\":0,\"tid\":{lane2},\"args\":{{\"to\":{n2}}}}}",
                        start2 / 1000,
                        start2 % 1000,
                    ));
                }
            }
        }
        out.push_str(&format!(
            "],\"otherData\":{{\"recorded\":{},\"overwritten\":{}}}}}",
            self.recorded, self.overwritten
        ));
        out
    }

    /// Events of one kind (test/tooling convenience).
    pub fn of_kind(&self, kind: EventKind) -> impl Iterator<Item = &FlightEvent> {
        self.events.iter().filter(move |e| e.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_decode_round_trip() {
        let r = FlightRecorder::new(2, 8, Instant::now());
        r.record(0, EventKind::TaskStart, 7, 42);
        r.record(0, EventKind::TaskEnd, 7, 1500);
        r.record(1, EventKind::Steal, 0, 3);
        r.record_external(EventKind::Brownout, 1, 0);
        let d = r.dump();
        assert_eq!(d.events.len(), 4);
        assert_eq!(d.recorded, 4);
        assert_eq!(d.overwritten, 0);
        let start = d.of_kind(EventKind::TaskStart).next().unwrap();
        assert_eq!((start.lane, start.a, start.b), (0, 7, 42));
        let brown = d.of_kind(EventKind::Brownout).next().unwrap();
        assert_eq!(brown.lane as usize, r.external_lane());
        // Sorted by time.
        assert!(d.events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_losses() {
        let r = FlightRecorder::new(1, 4, Instant::now());
        for i in 0..10u32 {
            r.record(0, EventKind::Park, i, 0);
        }
        let d = r.dump();
        // Capacity 4: only the 4 newest survive; 6 overwritten.
        let parks: Vec<u32> = d.of_kind(EventKind::Park).map(|e| e.a).collect();
        assert_eq!(parks.len(), 4);
        assert!(parks.iter().all(|&a| a >= 6), "oldest events must be gone: {parks:?}");
        assert_eq!(d.recorded, 10);
        assert_eq!(d.overwritten, 6);
    }

    #[test]
    fn chrome_trace_pairs_spans_and_draws_flows() {
        let r = FlightRecorder::new(1, 16, Instant::now());
        r.record(0, EventKind::TaskStart, 0, 1);
        r.record(0, EventKind::TaskEnd, 0, 1000);
        r.record(0, EventKind::TaskStart, 1, 1);
        r.record(0, EventKind::TaskEnd, 1, 1000);
        r.record(0, EventKind::Park, 0, 0);
        let json = r.dump().to_chrome_trace_with_edges(&[(0, 1)]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with('}'));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2, "{json}");
        assert_eq!(json.matches("\"ph\":\"i\"").count(), 1);
        // One edge, both spans present → one s/f flow pair.
        assert_eq!(json.matches("\"ph\":\"s\"").count(), 1);
        assert_eq!(json.matches("\"ph\":\"f\"").count(), 1);
        assert!(json.contains("\"overwritten\":0"));
    }

    #[test]
    fn out_of_range_lane_clamps_to_external() {
        let r = FlightRecorder::new(1, 8, Instant::now());
        r.record(999, EventKind::Wake, 0, 0);
        let d = r.dump();
        assert_eq!(d.events.len(), 1);
    }
}
