//! Log-bucketed atomic histograms.
//!
//! One series is a fixed array of [`BUCKETS`] `AtomicU64` counters
//! plus a running count and sum — no locks, no allocation after
//! construction, and recording is two relaxed RMWs (bucket + count)
//! plus one relaxed add for the sum, so a series can stay on in
//! release builds next to the PR-1 worker counters.
//!
//! Buckets are powers of two: bucket `i` (for `i > 0`) holds values
//! `v` with `2^(i-1) <= v < 2^i`, bucket 0 holds exactly `v == 0`,
//! and the last bucket absorbs everything from `2^(BUCKETS-2)` up.
//! Quantile queries return the *inclusive upper bound* of the bucket
//! containing the requested rank — a conservative (never
//! under-reporting) estimate with ≤ 2× resolution error, which is
//! exactly what the serve layer's p99 deadline-feasibility check
//! wants: better to reject a request a little early than to admit one
//! that will miss.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets per series (2^6 — covers 1 ns to ~146 years at
/// power-of-two resolution when values are nanoseconds).
pub const BUCKETS: usize = 64;

/// Bucket index of `v`: 0 for 0, otherwise `floor(log2(v)) + 1`
/// clamped into the array.
#[inline]
fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (the value a quantile query
/// reports when the rank lands in that bucket).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// A lock-free log-bucketed histogram. All methods take `&self`; any
/// thread may record concurrently (relaxed atomics — counts are exact,
/// cross-counter consistency is only approximate under concurrent
/// writes, which is fine for telemetry).
#[derive(Debug)]
pub struct Histogram {
    counts: Box<[AtomicU64; BUCKETS]>,
    total: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Creates an empty series (the only allocation this type ever
    /// performs).
    pub fn new() -> Self {
        Self {
            counts: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Records one value (relaxed; never allocates).
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Samples recorded so far (relaxed).
    #[inline]
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Takes a point-in-time copy of the series. Relaxed loads: under
    /// concurrent recording the copy is a consistent-enough view for
    /// telemetry (per-bucket counts are each exact as of their load).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
            count: self.total.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// An owned, mergeable copy of one histogram series.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (see the module docs for bounds).
    pub counts: [u64; BUCKETS],
    /// Total samples (sum of `counts` as of the snapshot).
    pub count: u64,
    /// Sum of all recorded values.
    pub sum: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self { counts: [0; BUCKETS], count: 0, sum: 0 }
    }
}

impl HistogramSnapshot {
    /// Folds another snapshot into this one (bucket-wise add) — how
    /// per-worker or per-tenant series aggregate into pool totals.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// True if no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The value at quantile `q` (0.0–1.0): the inclusive upper bound
    /// of the bucket containing the `ceil(q * count)`-th sample.
    /// Returns 0 for an empty series.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    /// Arithmetic mean of recorded values (0 for an empty series).
    pub fn mean(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // v always <= bucket_upper(bucket_of(v)).
        for v in [0u64, 1, 2, 3, 7, 8, 1000, 1 << 40, u64::MAX] {
            assert!(v <= bucket_upper(bucket_of(v)), "v={v}");
        }
    }

    #[test]
    fn quantile_is_conservative_upper_bound() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1100);
        // p50 lands in the bucket of 30 ([16,32) → upper 31).
        assert_eq!(s.quantile(0.5), 31);
        // p99 lands in the bucket of 1000 ([512,1024) → upper 1023).
        assert_eq!(s.quantile(0.99), 1023);
        assert!(s.quantile(0.99) >= 1000);
        assert_eq!(s.mean(), 220);
    }

    #[test]
    fn empty_series_report_zero() {
        let s = Histogram::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.99), 0);
        assert_eq!(s.mean(), 0);
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(5);
        a.record(100);
        b.record(5);
        b.record(1 << 20);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 4);
        assert_eq!(m.sum, 5 + 100 + 5 + (1 << 20));
        assert_eq!(m.counts[bucket_of(5)], 2);
        // The merged p99 must cover the largest contributor.
        assert!(m.quantile(0.99) >= (1 << 20));
    }
}
