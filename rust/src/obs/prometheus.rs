//! Prometheus text exposition: writer and strict validator.
//!
//! The writer produces the [text-based exposition format]: every
//! metric family gets `# HELP` and `# TYPE` lines before its samples,
//! histograms expand to cumulative `_bucket{le="..."}` samples ending
//! in `le="+Inf"` plus `_sum`/`_count`, and label values are escaped.
//! The validator re-checks all of that *strictly* — it is run in CI
//! against both the HTTP `/metrics` scrape and the in-band STATS v2
//! frame, so a malformed exposition can never ship silently.
//!
//! [text-based exposition format]:
//!     https://prometheus.io/docs/instrumenting/exposition_formats/

use super::histogram::{bucket_upper, HistogramSnapshot};

/// Incremental builder for one exposition document.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

impl PromWriter {
    /// Starts an empty document.
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Emits an unlabelled counter family with one sample.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name} {value}\n"));
    }

    /// Emits a counter family with one sample per label set. Labels
    /// are `(key, value)` pairs; values are escaped.
    pub fn counter_labeled(&mut self, name: &str, help: &str, samples: &[(&[(&str, &str)], u64)]) {
        self.header(name, help, "counter");
        for (labels, value) in samples {
            self.out.push_str(&format!("{name}{} {value}\n", render_labels(labels)));
        }
    }

    /// Emits an unlabelled gauge family with one sample.
    pub fn gauge(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name} {value}\n"));
    }

    /// Emits a gauge family with one sample per label set.
    pub fn gauge_labeled(&mut self, name: &str, help: &str, samples: &[(&[(&str, &str)], u64)]) {
        self.header(name, help, "gauge");
        for (labels, value) in samples {
            self.out.push_str(&format!("{name}{} {value}\n", render_labels(labels)));
        }
    }

    /// Emits one histogram family from a snapshot: cumulative
    /// `_bucket` samples (only buckets up to the highest occupied one,
    /// then `+Inf` — the cumulative property holds regardless), plus
    /// `_sum` and `_count`. Extra labels apply to every sample.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        snap: &HistogramSnapshot,
    ) {
        self.header(name, help, "histogram");
        self.histogram_samples(name, labels, snap);
    }

    /// Emits the samples of one histogram label set *without* the
    /// family header — for families with several label sets (e.g. one
    /// per tenant): call [`PromWriter::histogram`] for the first and
    /// this for the rest.
    pub fn histogram_samples(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        snap: &HistogramSnapshot,
    ) {
        let highest = snap
            .counts
            .iter()
            .rposition(|&c| c > 0)
            .unwrap_or(0);
        let mut cum = 0u64;
        for (i, &c) in snap.counts.iter().enumerate().take(highest + 1) {
            cum += c;
            let mut ls: Vec<(&str, String)> =
                labels.iter().map(|&(k, v)| (k, v.to_string())).collect();
            ls.push(("le", bucket_upper(i).to_string()));
            self.out.push_str(&format!("{name}_bucket{} {cum}\n", render_owned_labels(&ls)));
        }
        let mut inf: Vec<(&str, String)> =
            labels.iter().map(|&(k, v)| (k, v.to_string())).collect();
        inf.push(("le", "+Inf".to_string()));
        self.out
            .push_str(&format!("{name}_bucket{} {}\n", render_owned_labels(&inf), snap.count));
        self.out
            .push_str(&format!("{name}_sum{} {}\n", render_labels(labels), snap.sum));
        self.out
            .push_str(&format!("{name}_count{} {}\n", render_labels(labels), snap.count));
    }

    /// Finishes the document.
    pub fn finish(self) -> String {
        self.out
    }
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    render_owned_labels(
        &labels.iter().map(|&(k, v)| (k, v.to_string())).collect::<Vec<_>>(),
    )
}

fn render_owned_labels(labels: &[(&str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Strictly validates an exposition document. Returns the first
/// violation found:
///
/// * every sample's metric family must have a preceding `# TYPE`;
/// * histogram `_bucket` series must be cumulative (non-decreasing in
///   `le` order) and end in `le="+Inf"`;
/// * every histogram must carry `_sum` and `_count`, with the `+Inf`
///   bucket equal to `_count`;
/// * sample lines must parse as `name{labels} value`.
pub fn validate(text: &str) -> Result<(), String> {
    use std::collections::HashMap;
    let mut types: HashMap<String, String> = HashMap::new();
    // family -> label-set(minus le) -> (buckets in order, inf, sum, count)
    #[derive(Default)]
    struct HistState {
        buckets: Vec<u64>,
        inf: Option<u64>,
        sum: Option<u64>,
        count: Option<u64>,
    }
    let mut hists: HashMap<(String, String), HistState> = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().ok_or_else(|| format!("line {}: bare # TYPE", lineno + 1))?;
            let kind = it.next().ok_or_else(|| format!("line {}: # TYPE without kind", lineno + 1))?;
            types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        // Sample line: name[{labels}] value
        let (name_and_labels, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value: {line:?}", lineno + 1))?;
        let value: f64 = value
            .parse()
            .map_err(|_| format!("line {}: unparsable value: {line:?}", lineno + 1))?;
        let (name, labels) = match name_and_labels.split_once('{') {
            Some((n, rest)) => {
                let rest = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {}: unterminated labels: {line:?}", lineno + 1))?;
                (n, rest.to_string())
            }
            None => (name_and_labels, String::new()),
        };
        // Resolve the family: histogram samples use suffixed names.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                name.strip_suffix(suf)
                    .filter(|base| types.get(*base).is_some_and(|t| t == "histogram"))
                    .map(|base| (base, *suf))
            });
        match family {
            Some((base, suffix)) => {
                // Labels minus `le` identify the series.
                let mut le = None;
                let others: Vec<&str> = labels
                    .split(',')
                    .filter(|p| !p.is_empty())
                    .filter(|p| {
                        if let Some(v) = p.strip_prefix("le=") {
                            le = Some(v.trim_matches('"').to_string());
                            false
                        } else {
                            true
                        }
                    })
                    .collect();
                let key = (base.to_string(), others.join(","));
                let st = hists.entry(key).or_default();
                match suffix {
                    "_bucket" => {
                        let le = le.ok_or_else(|| {
                            format!("line {}: _bucket without le label", lineno + 1)
                        })?;
                        if le == "+Inf" {
                            st.inf = Some(value as u64);
                        } else {
                            if st.inf.is_some() {
                                return Err(format!(
                                    "line {}: bucket after le=\"+Inf\" in {base}",
                                    lineno + 1
                                ));
                            }
                            st.buckets.push(value as u64);
                        }
                    }
                    "_sum" => st.sum = Some(value as u64),
                    "_count" => st.count = Some(value as u64),
                    _ => unreachable!(),
                }
            }
            None => {
                if !types.contains_key(name) {
                    return Err(format!(
                        "line {}: sample {name:?} has no preceding # TYPE",
                        lineno + 1
                    ));
                }
            }
        }
    }
    for ((family, labels), st) in &hists {
        let what = if labels.is_empty() {
            family.clone()
        } else {
            format!("{family}{{{labels}}}")
        };
        let inf = st.inf.ok_or_else(|| format!("{what}: no le=\"+Inf\" bucket"))?;
        let count = st.count.ok_or_else(|| format!("{what}: missing _count"))?;
        st.sum.ok_or_else(|| format!("{what}: missing _sum"))?;
        if !st.buckets.windows(2).all(|w| w[0] <= w[1]) {
            return Err(format!("{what}: buckets not cumulative: {:?}", st.buckets));
        }
        if let Some(&last) = st.buckets.last() {
            if last > inf {
                return Err(format!("{what}: bucket {last} exceeds +Inf {inf}"));
            }
        }
        if inf != count {
            return Err(format!("{what}: le=\"+Inf\" ({inf}) != _count ({count})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    #[test]
    fn writer_output_validates() {
        let h = Histogram::new();
        for v in [100u64, 2000, 2000, 50_000] {
            h.record(v);
        }
        let mut w = PromWriter::new();
        w.counter("pool_tasks_total", "Tasks executed.", 42);
        w.gauge("brownout_level", "Current brownout level.", 1);
        w.counter_labeled(
            "tenant_completed",
            "Completed runs per tenant.",
            &[(&[("tenant", "gold")], 3), (&[("tenant", "silver")], 1)],
        );
        w.histogram("pool_queue_delay_ns", "Dispatch queue delay.", &[], &h.snapshot());
        let text = w.finish();
        assert!(text.contains("# TYPE pool_tasks_total counter"));
        assert!(text.contains("tenant_completed{tenant=\"gold\"} 3"));
        assert!(text.contains("le=\"+Inf\"} 4"));
        assert!(text.contains("pool_queue_delay_ns_count 4"));
        validate(&text).expect("writer output must be valid");
    }

    #[test]
    fn validator_rejects_untyped_samples() {
        let err = validate("orphan_metric 1\n").unwrap_err();
        assert!(err.contains("no preceding # TYPE"), "{err}");
    }

    #[test]
    fn validator_rejects_noncumulative_buckets() {
        let text = "\
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_bucket{le=\"2\"} 3
h_bucket{le=\"+Inf\"} 5
h_sum 9
h_count 5
";
        let err = validate(text).unwrap_err();
        assert!(err.contains("not cumulative"), "{err}");
    }

    #[test]
    fn validator_rejects_missing_inf_and_count_mismatch() {
        let no_inf = "\
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_sum 9
h_count 5
";
        assert!(validate(no_inf).unwrap_err().contains("+Inf"));
        let mismatch = "\
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_bucket{le=\"+Inf\"} 5
h_sum 9
h_count 6
";
        assert!(validate(mismatch).unwrap_err().contains("!= _count"));
    }

    #[test]
    fn labeled_histograms_validate_per_series() {
        let a = Histogram::new();
        a.record(10);
        let b = Histogram::new();
        b.record(999);
        b.record(5);
        let mut w = PromWriter::new();
        w.histogram("tenant_latency_ns", "Per-tenant run latency.", &[("tenant", "gold")], &a.snapshot());
        w.histogram_samples("tenant_latency_ns", &[("tenant", "silver")], &b.snapshot());
        validate(&w.finish()).expect("multi-series histogram must validate");
    }
}
