//! Unified observability layer (PR 9).
//!
//! Three pillars, all built on the crate's cache-padded relaxed-atomic
//! discipline so they can stay **on in release builds**:
//!
//! * [`FlightRecorder`] — per-worker fixed-capacity lock-free ring
//!   buffers of compact binary scheduler events (task start/end,
//!   steal, park/wake, admission verdicts, aborts, retries, brownout
//!   transitions). Recording is a few nanoseconds and allocation-free;
//!   rings overwrite their oldest events (see `flight.rs` for the
//!   exact overwrite/torn-read semantics). Dump on demand via
//!   `ThreadPool::flight_dump()`, over the wire with the `DUMP` frame,
//!   or automatically when a run fails with `NodePanicked` /
//!   `DeadlineExceeded`; dumps convert to Chrome-trace JSON (with flow
//!   arrows along graph edges).
//! * [`Histogram`] — log-bucketed (2^k buckets) atomic histograms with
//!   mergeable [`HistogramSnapshot`]s, used for queue delay, gate
//!   wait, node duration, and per-tenant run latency. The serve
//!   layer's SLO checks read p99 from these (EWMAs remain the
//!   cold-start fallback).
//! * [`RunProfile`] — post-run scheduling profiles (observed critical
//!   path vs declared ranks, busy/idle makespan breakdown, scheduling
//!   efficiency), surfaced through `RunHandle::profile()` and
//!   `TaskGraph::last_profile()`; plus [`PromWriter`]/[`validate`]
//!   for standards-compliant Prometheus text exposition on the wire
//!   metrics listener and STATS v2 frame.
//!
//! Both the recorder and the histograms can be disabled per pool via
//! `PoolConfig::flight_recorder` / `PoolConfig::histograms`; the
//! ABL-9 ablation arm measures the cost of leaving them on.

pub mod flight;
pub mod histogram;
pub mod profile;
pub mod prometheus;

pub use flight::{EventKind, FlightDump, FlightEvent, FlightRecorder};
pub use histogram::{bucket_upper, Histogram, HistogramSnapshot, BUCKETS};
pub use profile::RunProfile;
pub use prometheus::{validate, PromWriter};

/// Minimum samples a histogram needs before its p99 supersedes the
/// EWMA in SLO decisions (deadline feasibility, tenant demotion):
/// below this the bucket quantile is too coarse to trust and the
/// serve layer stays on its cold-start EWMA path.
pub const HIST_MIN_SAMPLES: u64 = 32;
