//! Post-run profiles: what a completed graph run actually did.
//!
//! The executor stamps per-node start/end timestamps (and the
//! executing worker) into seal-time arrays beside the CSR arena;
//! after a run completes, [`RunProfile::compute`] folds them into the
//! numbers a scheduling post-mortem needs: the **observed critical
//! path** (longest end-to-end chain along real dependency edges,
//! using measured durations — compare against the declared seal-time
//! rank to see how wrong the weights were), the **makespan
//! breakdown** (busy vs idle worker-time inside the run window), and
//! **scheduling efficiency** (busy-time ÷ workers × makespan — 1.0
//! means every worker was executing nodes for the whole run).

use std::time::Duration;

/// A profile of one completed graph run. Obtained from
/// `RunHandle::profile()` or `TaskGraph::last_profile()`; `None`
/// there means the run recorded no timing (timing rides the
/// `PoolConfig::histograms` toggle, or the run never executed a
/// node).
#[derive(Debug, Clone)]
pub struct RunProfile {
    /// Nodes that executed (and were timed) in the run.
    pub nodes: usize,
    /// Wall-clock span from the first node start to the last node end.
    pub makespan: Duration,
    /// Sum of all node execution spans (total busy worker-time).
    pub busy: Duration,
    /// `workers × makespan − busy`: worker-time inside the run window
    /// not spent executing nodes (stealing, parking, idling).
    pub idle: Duration,
    /// Workers the pool ran with (the denominator of efficiency).
    pub workers: usize,
    /// `busy ÷ (workers × makespan)`, in 0.0–1.0.
    pub scheduling_efficiency: f64,
    /// Observed critical path: the heaviest measured-duration chain
    /// along the graph's dependency edges.
    pub critical_path: Duration,
    /// Node ids along the observed critical path, in execution order.
    pub critical_path_nodes: Vec<usize>,
    /// The declared seal-time critical-path rank (weight units, not
    /// time) of the run's heaviest chain — what the scheduler
    /// *believed* the critical path was when it prioritized.
    pub declared_critical_rank: u64,
    /// Busy time per worker lane (index = worker; the last entry is
    /// the caller-assist helper lane).
    pub per_worker_busy: Vec<Duration>,
}

impl RunProfile {
    /// Builds a profile from per-node spans. `starts`/`ends` are
    /// nanosecond timestamps on a common epoch (0 = node never
    /// executed), `node_workers[i]` is the lane that executed node
    /// `i`, `successors(i)` yields the dependency edges, `ranks` the
    /// declared seal-time ranks, and `workers` the pool size
    /// (excluding the helper lane). Returns `None` when no node was
    /// timed.
    pub fn compute(
        starts: &[u64],
        ends: &[u64],
        node_workers: &[u32],
        successors: impl Fn(usize) -> Vec<usize>,
        ranks: &[u64],
        workers: usize,
    ) -> Option<RunProfile> {
        let n = starts.len();
        let executed: Vec<usize> =
            (0..n).filter(|&i| starts[i] > 0 && ends[i] >= starts[i]).collect();
        if executed.is_empty() {
            return None;
        }
        let first = executed.iter().map(|&i| starts[i]).min().unwrap();
        let last = executed.iter().map(|&i| ends[i]).max().unwrap();
        let makespan_ns = last - first;
        let mut busy_ns = 0u64;
        let mut per_worker = vec![0u64; workers + 1];
        for &i in &executed {
            let span = ends[i] - starts[i];
            busy_ns += span;
            let w = (node_workers[i] as usize).min(workers);
            per_worker[w] += span;
        }
        // Observed critical path: longest chain by measured duration,
        // over the DAG (memoized iterative DFS — an explicit stack, so
        // a 100k-node chain cannot overflow the thread stack).
        let mut best = vec![u64::MAX; n]; // MAX = unvisited
        let mut best_next = vec![usize::MAX; n];
        let span_of = |i: usize| {
            if starts[i] > 0 && ends[i] >= starts[i] {
                ends[i] - starts[i]
            } else {
                0
            }
        };
        let mut stack: Vec<(usize, bool)> = Vec::new();
        for &root in &executed {
            if best[root] != u64::MAX {
                continue;
            }
            stack.push((root, false));
            while let Some((i, expanded)) = stack.pop() {
                if best[i] != u64::MAX {
                    continue;
                }
                if expanded {
                    let mut down = 0u64;
                    let mut next = usize::MAX;
                    for s in successors(i) {
                        let d = best[s];
                        debug_assert_ne!(d, u64::MAX, "successor resolved before parent");
                        if d > down {
                            down = d;
                            next = s;
                        }
                    }
                    best[i] = span_of(i) + down;
                    best_next[i] = next;
                } else {
                    stack.push((i, true));
                    for s in successors(i) {
                        if best[s] == u64::MAX {
                            stack.push((s, false));
                        }
                    }
                }
            }
        }
        let mut cp_head = executed[0];
        let mut cp_ns = 0u64;
        for &i in &executed {
            if best[i] != u64::MAX && best[i] > cp_ns {
                cp_ns = best[i];
                cp_head = i;
            }
        }
        let mut critical_path_nodes = Vec::new();
        let mut cur = cp_head;
        while cur != usize::MAX {
            critical_path_nodes.push(cur);
            cur = best_next[cur];
        }
        let denom = (workers as u64).max(1) * makespan_ns;
        let efficiency = if denom == 0 { 1.0 } else { busy_ns as f64 / denom as f64 };
        Some(RunProfile {
            nodes: executed.len(),
            makespan: Duration::from_nanos(makespan_ns),
            busy: Duration::from_nanos(busy_ns),
            idle: Duration::from_nanos(denom.saturating_sub(busy_ns)),
            workers,
            scheduling_efficiency: efficiency,
            critical_path: Duration::from_nanos(cp_ns),
            critical_path_nodes,
            declared_critical_rank: ranks.iter().copied().max().unwrap_or(0),
            per_worker_busy: per_worker.into_iter().map(Duration::from_nanos).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond_profile_numbers() {
        // 0 -> {1, 2} -> 3; node 2 is the heavy arm.
        let starts = [100u64, 200, 200, 1300];
        let ends = [200u64, 400, 1200, 1400];
        let workers_of = [0u32, 0, 1, 0];
        let succ = |i: usize| -> Vec<usize> {
            match i {
                0 => vec![1, 2],
                1 | 2 => vec![3],
                _ => vec![],
            }
        };
        let ranks = [30u64, 20, 20, 10];
        let p = RunProfile::compute(&starts, &ends, &workers_of, succ, &ranks, 2).unwrap();
        assert_eq!(p.nodes, 4);
        assert_eq!(p.makespan, Duration::from_nanos(1300));
        // busy = 100 + 200 + 1000 + 100.
        assert_eq!(p.busy, Duration::from_nanos(1400));
        assert_eq!(p.idle, Duration::from_nanos(2 * 1300 - 1400));
        // Critical path runs through the heavy arm: 0 -> 2 -> 3.
        assert_eq!(p.critical_path_nodes, vec![0, 2, 3]);
        assert_eq!(p.critical_path, Duration::from_nanos(100 + 1000 + 100));
        assert_eq!(p.declared_critical_rank, 30);
        let eff = 1400.0 / (2.0 * 1300.0);
        assert!((p.scheduling_efficiency - eff).abs() < 1e-9);
        assert_eq!(p.per_worker_busy[0], Duration::from_nanos(400));
        assert_eq!(p.per_worker_busy[1], Duration::from_nanos(1000));
    }

    #[test]
    fn unexecuted_nodes_are_skipped() {
        // Node 1 never ran (cancelled mid-flight).
        let starts = [10u64, 0];
        let ends = [20u64, 0];
        let p = RunProfile::compute(&starts, &ends, &[0, 0], |_| vec![], &[1, 1], 1).unwrap();
        assert_eq!(p.nodes, 1);
        assert_eq!(p.makespan, Duration::from_nanos(10));
    }

    #[test]
    fn no_timing_yields_none() {
        assert!(RunProfile::compute(&[0, 0], &[0, 0], &[0, 0], |_| vec![], &[1, 1], 1).is_none());
    }
}
