//! Dependency-graph workloads: the GitHub benchmark set the paper
//! points to ("for more benchmark results, see the repository") —
//! linear chain, binary tree, graph traversal (layered random DAG),
//! and 2-D wavefront.
//!
//! Each workload is generated once as a [`Dag`] (adjacency lists) and
//! can then be materialized two ways:
//!
//! * [`Dag::to_task_graph`] — a [`TaskGraph`] for our pool, exercising
//!   the paper's §2.2 executor (inline continuations and all);
//! * [`Dag::run_countdown`] — closure-based execution on *any*
//!   [`Executor`]: every node carries an atomic predecessor counter and
//!   ready successors are resubmitted. This is how the baselines run
//!   graph workloads (and matches how Taskflow-style executors
//!   schedule graphs internally).
//!
//! Node bodies spin a configurable number of PRNG steps so benches can
//! sweep task granularity from "pure scheduling overhead" upward.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::baseline::Executor;
use crate::graph::TaskGraph;
use crate::util::Pcg32;

/// A directed acyclic dependency graph in adjacency-list form.
#[derive(Debug, Clone)]
pub struct Dag {
    /// `adj[i]` = successors of node `i`.
    pub adj: Vec<Vec<usize>>,
    /// Human-readable generator tag (for bench tables).
    pub kind: String,
}

/// Spins `steps` PRNG iterations — the per-node synthetic work.
#[inline]
pub fn busy_work(seed: u64, steps: u32) -> u64 {
    let mut x = seed | 1;
    for _ in 0..steps {
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        x = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
    }
    x
}

impl Dag {
    /// `n` tasks in a strict chain `0 -> 1 -> ... -> n-1`.
    pub fn linear_chain(n: usize) -> Self {
        let adj = (0..n).map(|i| if i + 1 < n { vec![i + 1] } else { vec![] }).collect();
        Self {
            adj,
            kind: format!("chain({n})"),
        }
    }

    /// Complete binary tree of the given depth (root = node 0,
    /// children of `i` are `2i+1`, `2i+2`): `2^depth - 1` nodes, edges
    /// from parent to child (fan-out workload).
    pub fn binary_tree(depth: u32) -> Self {
        let n = (1usize << depth) - 1;
        let adj = (0..n)
            .map(|i| {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut s = Vec::new();
                if l < n {
                    s.push(l);
                }
                if r < n {
                    s.push(r);
                }
                s
            })
            .collect();
        Self {
            adj,
            kind: format!("btree(d={depth})"),
        }
    }

    /// Layered random DAG ("graph traversal"): `layers × width` nodes;
    /// each node gets edges to a random subset of the next layer with
    /// probability `p`, plus one guaranteed edge so layers stay
    /// connected. Deterministic in `seed`.
    pub fn layered_random(layers: usize, width: usize, p: f64, seed: u64) -> Self {
        let n = layers * width;
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut rng = Pcg32::seeded(seed);
        for layer in 0..layers.saturating_sub(1) {
            for i in 0..width {
                let from = layer * width + i;
                let base = (layer + 1) * width;
                let guaranteed = base + rng.next_below(width as u32) as usize;
                adj[from].push(guaranteed);
                for j in 0..width {
                    let to = base + j;
                    if to != guaranteed && rng.next_f64() < p {
                        adj[from].push(to);
                    }
                }
            }
        }
        Self {
            adj,
            kind: format!("dag({layers}x{width},p={p})"),
        }
    }

    /// A chain of `k` diamonds: `a -> (b, c) -> d -> a' -> ...`
    /// (4k nodes) — mixes fan-out, fan-in, and inline-continuation
    /// hops in a tiny graph. This is the `graph_rerun` microbench
    /// workload (PR 2) and the zero-allocation test's shape.
    pub fn diamond_chain(diamonds: usize) -> Self {
        let n = diamonds * 4;
        let mut adj = vec![Vec::new(); n];
        for d in 0..diamonds {
            let a = 4 * d;
            adj[a].push(a + 1);
            adj[a].push(a + 2);
            adj[a + 1].push(a + 3);
            adj[a + 2].push(a + 3);
            if d + 1 < diamonds {
                adj[a + 3].push(a + 4);
            }
        }
        Self {
            adj,
            kind: format!("diamonds({diamonds})"),
        }
    }

    /// 2-D wavefront: a `g × g` grid where cell `(i, j)` depends on
    /// `(i-1, j)` and `(i, j-1)` — the classic dynamic-programming
    /// dependency pattern (Smith–Waterman, Cholesky tiles, ...).
    pub fn wavefront(g: usize) -> Self {
        let n = g * g;
        let mut adj = vec![Vec::new(); n];
        for i in 0..g {
            for j in 0..g {
                let from = i * g + j;
                if i + 1 < g {
                    adj[from].push((i + 1) * g + j);
                }
                if j + 1 < g {
                    adj[from].push(i * g + j + 1);
                }
            }
        }
        Self {
            adj,
            kind: format!("wavefront({g}x{g})"),
        }
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.adj.len()
    }

    /// True if the DAG has no nodes.
    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Edge count.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|s| s.len()).sum()
    }

    /// In-degrees.
    pub fn in_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.len()];
        for succs in &self.adj {
            for &s in succs {
                deg[s] += 1;
            }
        }
        deg
    }

    /// Materializes as a [`TaskGraph`] whose node `i` runs
    /// `busy_work(i, work_steps)` and bumps a shared completion
    /// counter. Returns `(graph, counter)`.
    pub fn to_task_graph(&self, work_steps: u32) -> (TaskGraph, Arc<AtomicUsize>) {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::with_capacity(self.len());
        let ids: Vec<_> = (0..self.len())
            .map(|i| {
                let counter = counter.clone();
                g.add(move || {
                    std::hint::black_box(busy_work(i as u64, work_steps));
                    counter.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        for (i, succs) in self.adj.iter().enumerate() {
            if !succs.is_empty() {
                let succ_ids: Vec<_> = succs.iter().map(|&s| ids[s]).collect();
                g.precede(ids[i], &succ_ids);
            }
        }
        // Seal eagerly: benches re-run these graphs, and sealing moves
        // the one-time CSR topology build out of the measured path. (A
        // cyclic Dag — not producible by our generators — just stays
        // unsealed; `run()` re-validates and reports the cycle.)
        let _ = g.seal();
        (g, counter)
    }

    /// Executes the DAG on any [`Executor`] via countdown closures:
    /// node bodies run `busy_work(i, work_steps)`; each completion
    /// decrements successors' counters and submits the ready ones.
    /// Returns the number of executed nodes (== `len()` on success).
    pub fn run_countdown(&self, ex: &Arc<dyn Executor>, work_steps: u32) -> usize {
        struct State {
            adj: Vec<Vec<usize>>,
            pending: Vec<AtomicUsize>,
            executed: AtomicUsize,
            work_steps: u32,
        }
        fn run_node(ex: Arc<dyn Executor>, st: Arc<State>, i: usize) {
            std::hint::black_box(busy_work(i as u64, st.work_steps));
            st.executed.fetch_add(1, Ordering::Relaxed);
            for &s in &st.adj[i] {
                if st.pending[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                    let (e, st2) = (ex.clone(), st.clone());
                    let e2 = e.clone();
                    e.submit_boxed(Box::new(move || run_node(e2, st2, s)));
                }
            }
        }

        let indeg = self.in_degrees();
        let st = Arc::new(State {
            adj: self.adj.clone(),
            pending: indeg.iter().map(|&d| AtomicUsize::new(d)).collect(),
            executed: AtomicUsize::new(0),
            work_steps,
        });
        for (i, &d) in indeg.iter().enumerate() {
            if d == 0 {
                let (e, st2) = (ex.clone(), st.clone());
                let e2 = e.clone();
                e.submit_boxed(Box::new(move || run_node(e2, st2, i)));
            }
        }
        ex.wait_idle();
        st.executed.load(Ordering::Relaxed)
    }

    /// Sequential execution of the same node bodies (the no-pool
    /// baseline for speedup columns).
    pub fn run_sequential(&self, work_steps: u32) -> u64 {
        let mut acc = 0u64;
        for i in 0..self.len() {
            acc = acc.wrapping_add(busy_work(i as u64, work_steps));
        }
        acc
    }
}

/// Checksum helper so benches can assert DAG executions did all work.
pub fn checksum(counter: &Arc<AtomicU64>) -> u64 {
    counter.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::all_executors;
    use crate::pool::ThreadPool;

    #[test]
    fn chain_shape() {
        let d = Dag::linear_chain(5);
        assert_eq!(d.len(), 5);
        assert_eq!(d.num_edges(), 4);
        assert_eq!(d.in_degrees(), vec![0, 1, 1, 1, 1]);
    }

    #[test]
    fn btree_shape() {
        let d = Dag::binary_tree(4);
        assert_eq!(d.len(), 15);
        assert_eq!(d.num_edges(), 14);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0);
        assert!(deg[1..].iter().all(|&x| x == 1));
    }

    #[test]
    fn diamond_chain_shape() {
        let d = Dag::diamond_chain(16);
        assert_eq!(d.len(), 64);
        // Per diamond: 4 internal edges; 15 chaining edges.
        assert_eq!(d.num_edges(), 16 * 4 + 15);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0); // the only source
        assert_eq!(deg[3], 2); // fan-in node
        assert_eq!(deg[4], 1); // next diamond's head
        let (mut g, counter) = d.to_task_graph(0);
        assert!(g.is_sealed(), "to_task_graph seals eagerly");
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 128);
    }

    #[test]
    fn wavefront_shape() {
        let d = Dag::wavefront(3);
        assert_eq!(d.len(), 9);
        // Interior edges: each cell except last row/col contributes 2,
        // boundary cells 1, corner 0: total 2*g*(g-1) = 12.
        assert_eq!(d.num_edges(), 12);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0); // (0,0)
        assert_eq!(deg[4], 2); // (1,1)
    }

    #[test]
    fn layered_random_is_deterministic_and_acyclic() {
        let a = Dag::layered_random(6, 8, 0.3, 42);
        let b = Dag::layered_random(6, 8, 0.3, 42);
        assert_eq!(a.adj, b.adj);
        let c = Dag::layered_random(6, 8, 0.3, 43);
        assert_ne!(a.adj, c.adj);
        // Edges only go to the next layer -> acyclic by construction.
        for (i, succs) in a.adj.iter().enumerate() {
            for &s in succs {
                assert_eq!(s / 8, i / 8 + 1);
            }
        }
        // Kahn agrees.
        let (mut g, _) = a.to_task_graph(0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn task_graph_executes_all_nodes() {
        let d = Dag::layered_random(5, 6, 0.4, 7);
        let (mut g, counter) = d.to_task_graph(10);
        let pool = ThreadPool::new(3);
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), d.len());
    }

    #[test]
    fn countdown_matches_on_all_executors() {
        let d = Dag::wavefront(6);
        for ex in all_executors(2) {
            assert_eq!(d.run_countdown(&ex, 5), d.len(), "{}", ex.name());
        }
    }

    #[test]
    fn chain_on_pool_via_graph() {
        let d = Dag::linear_chain(500);
        let (mut g, counter) = d.to_task_graph(0);
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn busy_work_scales() {
        // Just sanity: deterministic and different for different steps.
        assert_eq!(busy_work(1, 10), busy_work(1, 10));
        assert_ne!(busy_work(1, 10), busy_work(1, 11));
    }
}
