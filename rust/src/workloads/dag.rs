//! Dependency-graph workloads: the GitHub benchmark set the paper
//! points to ("for more benchmark results, see the repository") —
//! linear chain, binary tree, graph traversal (layered random DAG),
//! and 2-D wavefront.
//!
//! Each workload is generated once as a [`Dag`] (adjacency lists) and
//! can then be materialized two ways:
//!
//! * [`Dag::to_task_graph`] — a [`TaskGraph`] for our pool, exercising
//!   the paper's §2.2 executor (inline continuations and all);
//! * [`Dag::run_countdown`] — closure-based execution on *any*
//!   [`Executor`]: every node carries an atomic predecessor counter and
//!   ready successors are resubmitted. This is how the baselines run
//!   graph workloads (and matches how Taskflow-style executors
//!   schedule graphs internally).
//!
//! Node bodies spin a configurable number of PRNG steps so benches can
//! sweep task granularity from "pure scheduling overhead" upward.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::baseline::Executor;
use crate::graph::TaskGraph;
use crate::util::Pcg32;

/// A directed acyclic dependency graph in adjacency-list form.
#[derive(Debug, Clone)]
pub struct Dag {
    /// `adj[i]` = successors of node `i`.
    pub adj: Vec<Vec<usize>>,
    /// Human-readable generator tag (for bench tables).
    pub kind: String,
    /// Optional per-node cost weights (PR 4): scale each node's
    /// synthetic work *and* feed the task graph's critical-path ranks
    /// ([`crate::graph::TaskGraph::add_weighted`]). `None` means unit
    /// weights. Attach with [`Dag::with_weights`].
    pub weights: Option<Vec<u32>>,
}

/// Spins `steps` PRNG iterations — the per-node synthetic work.
#[inline]
pub fn busy_work(seed: u64, steps: u32) -> u64 {
    let mut x = seed | 1;
    for _ in 0..steps {
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        x = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
    }
    x
}

impl Dag {
    /// `n` tasks in a strict chain `0 -> 1 -> ... -> n-1`.
    pub fn linear_chain(n: usize) -> Self {
        let adj = (0..n).map(|i| if i + 1 < n { vec![i + 1] } else { vec![] }).collect();
        Self {
            adj,
            kind: format!("chain({n})"),
            weights: None,
        }
    }

    /// Complete binary tree of the given depth (root = node 0,
    /// children of `i` are `2i+1`, `2i+2`): `2^depth - 1` nodes, edges
    /// from parent to child (fan-out workload).
    pub fn binary_tree(depth: u32) -> Self {
        let n = (1usize << depth) - 1;
        let adj = (0..n)
            .map(|i| {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut s = Vec::new();
                if l < n {
                    s.push(l);
                }
                if r < n {
                    s.push(r);
                }
                s
            })
            .collect();
        Self {
            adj,
            kind: format!("btree(d={depth})"),
            weights: None,
        }
    }

    /// Layered random DAG ("graph traversal"): `layers × width` nodes;
    /// each node gets edges to a random subset of the next layer with
    /// probability `p`, plus one guaranteed edge so layers stay
    /// connected. Deterministic in `seed`.
    pub fn layered_random(layers: usize, width: usize, p: f64, seed: u64) -> Self {
        let n = layers * width;
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut rng = Pcg32::seeded(seed);
        for layer in 0..layers.saturating_sub(1) {
            for i in 0..width {
                let from = layer * width + i;
                let base = (layer + 1) * width;
                let guaranteed = base + rng.next_below(width as u32) as usize;
                adj[from].push(guaranteed);
                for j in 0..width {
                    let to = base + j;
                    if to != guaranteed && rng.next_f64() < p {
                        adj[from].push(to);
                    }
                }
            }
        }
        Self {
            adj,
            kind: format!("dag({layers}x{width},p={p})"),
            weights: None,
        }
    }

    /// A chain of `k` diamonds: `a -> (b, c) -> d -> a' -> ...`
    /// (4k nodes) — mixes fan-out, fan-in, and inline-continuation
    /// hops in a tiny graph. This is the `graph_rerun` microbench
    /// workload (PR 2) and the zero-allocation test's shape.
    pub fn diamond_chain(diamonds: usize) -> Self {
        let n = diamonds * 4;
        let mut adj = vec![Vec::new(); n];
        for d in 0..diamonds {
            let a = 4 * d;
            adj[a].push(a + 1);
            adj[a].push(a + 2);
            adj[a + 1].push(a + 3);
            adj[a + 2].push(a + 3);
            if d + 1 < diamonds {
                adj[a + 3].push(a + 4);
            }
        }
        Self {
            adj,
            kind: format!("diamonds({diamonds})"),
            weights: None,
        }
    }

    /// 2-D wavefront: a `g × g` grid where cell `(i, j)` depends on
    /// `(i-1, j)` and `(i, j-1)` — the classic dynamic-programming
    /// dependency pattern (Smith–Waterman, Cholesky tiles, ...).
    pub fn wavefront(g: usize) -> Self {
        let n = g * g;
        let mut adj = vec![Vec::new(); n];
        for i in 0..g {
            for j in 0..g {
                let from = i * g + j;
                if i + 1 < g {
                    adj[from].push((i + 1) * g + j);
                }
                if j + 1 < g {
                    adj[from].push(i * g + j + 1);
                }
            }
        }
        Self {
            adj,
            kind: format!("wavefront({g}x{g})"),
            weights: None,
        }
    }

    /// A skewed diamond (PR 4): one source fanning out to `width`
    /// single-node light branches **and** one `spine`-long chain, all
    /// joining in one sink. The spine head sits in the *middle* of the
    /// source's successor list, so shape-oblivious FIFO dispatch
    /// neither starts it first (inline continuation takes the first
    /// successor) nor last — the worst realistic case for makespan,
    /// which critical-path-first dispatch fixes once the spine carries
    /// heavy weights (attach them with [`Dag::with_weights`]; spine
    /// nodes are indices `width + 1 ..= width + spine`).
    ///
    /// `width + spine + 2` nodes: source 0, branches `1..=width`,
    /// spine `width + 1..=width + spine`, sink last.
    pub fn skewed_diamond(width: usize, spine: usize) -> Self {
        assert!(width >= 1 && spine >= 1, "skewed_diamond needs at least one branch and one spine node");
        let n = width + spine + 2;
        let sink = n - 1;
        let spine_head = width + 1;
        let mut adj = vec![Vec::new(); n];
        for b in 1..=width / 2 {
            adj[0].push(b);
        }
        adj[0].push(spine_head);
        for b in (width / 2 + 1)..=width {
            adj[0].push(b);
        }
        for b in 1..=width {
            adj[b].push(sink);
        }
        for s in spine_head..width + spine {
            adj[s].push(s + 1);
        }
        adj[width + spine].push(sink);
        Self {
            adj,
            kind: format!("skewed({width}w+{spine}s)"),
            weights: None,
        }
    }

    /// Attaches per-node cost weights generated by `weight_of(node)` —
    /// the priority bench's lever for non-uniform critical paths. The
    /// weights scale both the synthetic node work and the task graph's
    /// seal-time ranks (see [`Dag::to_task_graph`]).
    pub fn with_weights(mut self, weight_of: impl Fn(usize) -> u32) -> Self {
        self.weights = Some((0..self.len()).map(weight_of).collect());
        self
    }

    /// Cost weight of node `i` (1 unless [`Dag::with_weights`] was
    /// used).
    pub fn weight(&self, i: usize) -> u32 {
        self.weights.as_ref().map(|w| w[i]).unwrap_or(1)
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.adj.len()
    }

    /// True if the DAG has no nodes.
    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// Edge count.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|s| s.len()).sum()
    }

    /// In-degrees.
    pub fn in_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.len()];
        for succs in &self.adj {
            for &s in succs {
                deg[s] += 1;
            }
        }
        deg
    }

    /// Materializes as a [`TaskGraph`] whose node `i` runs
    /// `busy_work(i, weight(i) * work_steps)` and bumps a shared
    /// completion counter; node weights also become the graph's
    /// critical-path weights ([`TaskGraph::add_weighted`]). Returns
    /// `(graph, counter)`.
    pub fn to_task_graph(&self, work_steps: u32) -> (TaskGraph, Arc<AtomicUsize>) {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut g = TaskGraph::with_capacity(self.len());
        let ids: Vec<_> = (0..self.len())
            .map(|i| {
                let counter = counter.clone();
                let w = self.weight(i);
                let steps = work_steps.saturating_mul(w);
                g.add_weighted(w, move || {
                    std::hint::black_box(busy_work(i as u64, steps));
                    counter.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        for (i, succs) in self.adj.iter().enumerate() {
            if !succs.is_empty() {
                let succ_ids: Vec<_> = succs.iter().map(|&s| ids[s]).collect();
                g.precede(ids[i], &succ_ids);
            }
        }
        // Seal eagerly: benches re-run these graphs, and sealing moves
        // the one-time CSR topology build out of the measured path. (A
        // cyclic Dag — not producible by our generators — just stays
        // unsealed; `run()` re-validates and reports the cycle.)
        let _ = g.seal();
        (g, counter)
    }

    /// Executes the DAG on any [`Executor`] via countdown closures:
    /// node bodies run `busy_work(i, weight(i) * work_steps)` (the
    /// same per-node work as [`Dag::to_task_graph`], so weighted
    /// comparisons stay fair); each completion decrements successors'
    /// counters and submits the ready ones. Returns the number of
    /// executed nodes (== `len()` on success).
    pub fn run_countdown(&self, ex: &Arc<dyn Executor>, work_steps: u32) -> usize {
        struct State {
            adj: Vec<Vec<usize>>,
            pending: Vec<AtomicUsize>,
            executed: AtomicUsize,
            /// Per-node spin steps (`weight(i) * work_steps`).
            steps: Vec<u32>,
        }
        fn run_node(ex: Arc<dyn Executor>, st: Arc<State>, i: usize) {
            std::hint::black_box(busy_work(i as u64, st.steps[i]));
            st.executed.fetch_add(1, Ordering::Relaxed);
            for &s in &st.adj[i] {
                if st.pending[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                    let (e, st2) = (ex.clone(), st.clone());
                    let e2 = e.clone();
                    e.submit_boxed(Box::new(move || run_node(e2, st2, s)));
                }
            }
        }

        let indeg = self.in_degrees();
        let st = Arc::new(State {
            adj: self.adj.clone(),
            pending: indeg.iter().map(|&d| AtomicUsize::new(d)).collect(),
            executed: AtomicUsize::new(0),
            steps: (0..self.len()).map(|i| work_steps.saturating_mul(self.weight(i))).collect(),
        });
        for (i, &d) in indeg.iter().enumerate() {
            if d == 0 {
                let (e, st2) = (ex.clone(), st.clone());
                let e2 = e.clone();
                e.submit_boxed(Box::new(move || run_node(e2, st2, i)));
            }
        }
        ex.wait_idle();
        st.executed.load(Ordering::Relaxed)
    }

    /// Sequential execution of the same node bodies (the no-pool
    /// baseline for speedup columns).
    pub fn run_sequential(&self, work_steps: u32) -> u64 {
        let mut acc = 0u64;
        for i in 0..self.len() {
            acc = acc.wrapping_add(busy_work(i as u64, work_steps.saturating_mul(self.weight(i))));
        }
        acc
    }
}

/// Checksum helper so benches can assert DAG executions did all work.
pub fn checksum(counter: &Arc<AtomicU64>) -> u64 {
    counter.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::all_executors;
    use crate::pool::ThreadPool;

    #[test]
    fn chain_shape() {
        let d = Dag::linear_chain(5);
        assert_eq!(d.len(), 5);
        assert_eq!(d.num_edges(), 4);
        assert_eq!(d.in_degrees(), vec![0, 1, 1, 1, 1]);
    }

    #[test]
    fn btree_shape() {
        let d = Dag::binary_tree(4);
        assert_eq!(d.len(), 15);
        assert_eq!(d.num_edges(), 14);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0);
        assert!(deg[1..].iter().all(|&x| x == 1));
    }

    #[test]
    fn diamond_chain_shape() {
        let d = Dag::diamond_chain(16);
        assert_eq!(d.len(), 64);
        // Per diamond: 4 internal edges; 15 chaining edges.
        assert_eq!(d.num_edges(), 16 * 4 + 15);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0); // the only source
        assert_eq!(deg[3], 2); // fan-in node
        assert_eq!(deg[4], 1); // next diamond's head
        let (mut g, counter) = d.to_task_graph(0);
        assert!(g.is_sealed(), "to_task_graph seals eagerly");
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 128);
    }

    #[test]
    fn skewed_diamond_shape_and_weights() {
        let width = 6;
        let spine = 4;
        let d = Dag::skewed_diamond(width, spine).with_weights(|i| {
            if (width + 1..=width + spine).contains(&i) {
                8
            } else {
                1
            }
        });
        assert_eq!(d.len(), width + spine + 2);
        // Source fans out to every branch plus the spine head; the
        // spine head sits mid-list.
        assert_eq!(d.adj[0].len(), width + 1);
        assert_eq!(d.adj[0][width / 2], width + 1, "spine head is mid-list");
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0);
        assert_eq!(deg[d.len() - 1], width + 1, "sink joins every arm");
        assert_eq!(d.weight(1), 1);
        assert_eq!(d.weight(width + 1), 8);

        // Materialized: spine ranks dominate branch ranks.
        let (mut g, counter) = d.to_task_graph(0);
        assert!(g.is_sealed());
        use crate::graph::NodeId;
        let spine_head_rank = g.rank(NodeId(width + 1)).unwrap();
        let branch_rank = g.rank(NodeId(1)).unwrap();
        assert_eq!(branch_rank, 2); // branch + sink
        assert_eq!(spine_head_rank, 8 * spine as u64 + 1);
        assert_eq!(g.rank(NodeId(0)).unwrap(), spine_head_rank + 1);
        // And it runs exactly-once, twice.
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 2 * d.len());
        // Weighted countdown and sequential baselines agree on count.
        for ex in crate::baseline::all_executors(2) {
            assert_eq!(d.run_countdown(&ex, 1), d.len(), "{}", ex.name());
        }
        let _ = d.run_sequential(1);
    }

    #[test]
    fn wavefront_shape() {
        let d = Dag::wavefront(3);
        assert_eq!(d.len(), 9);
        // Interior edges: each cell except last row/col contributes 2,
        // boundary cells 1, corner 0: total 2*g*(g-1) = 12.
        assert_eq!(d.num_edges(), 12);
        let deg = d.in_degrees();
        assert_eq!(deg[0], 0); // (0,0)
        assert_eq!(deg[4], 2); // (1,1)
    }

    #[test]
    fn layered_random_is_deterministic_and_acyclic() {
        let a = Dag::layered_random(6, 8, 0.3, 42);
        let b = Dag::layered_random(6, 8, 0.3, 42);
        assert_eq!(a.adj, b.adj);
        let c = Dag::layered_random(6, 8, 0.3, 43);
        assert_ne!(a.adj, c.adj);
        // Edges only go to the next layer -> acyclic by construction.
        for (i, succs) in a.adj.iter().enumerate() {
            for &s in succs {
                assert_eq!(s / 8, i / 8 + 1);
            }
        }
        // Kahn agrees.
        let (mut g, _) = a.to_task_graph(0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn task_graph_executes_all_nodes() {
        let d = Dag::layered_random(5, 6, 0.4, 7);
        let (mut g, counter) = d.to_task_graph(10);
        let pool = ThreadPool::new(3);
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), d.len());
    }

    #[test]
    fn countdown_matches_on_all_executors() {
        let d = Dag::wavefront(6);
        for ex in all_executors(2) {
            assert_eq!(d.run_countdown(&ex, 5), d.len(), "{}", ex.name());
        }
    }

    #[test]
    fn chain_on_pool_via_graph() {
        let d = Dag::linear_chain(500);
        let (mut g, counter) = d.to_task_graph(0);
        let pool = ThreadPool::new(2);
        g.run(&pool).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn busy_work_scales() {
        // Just sanity: deterministic and different for different steps.
        assert_eq!(busy_work(1, 10), busy_work(1, 10));
        assert_ne!(busy_work(1, 10), busy_work(1, 11));
    }
}
