//! Many-graphs-in-flight driver for the async run-handle path (PR 3).
//!
//! The blocking `TaskGraph::run` lets one external thread drive
//! exactly one graph at a time; [`crate::graph::TaskGraph::run_async`]
//! removes that limit. [`MultiRun`] is the workload harness for it: it
//! owns N independent sealed diamond-chain graphs (the `graph_rerun`
//! microbench shape) and, each round, launches **all N** from the one
//! calling thread before waiting on any — so N runs are genuinely in
//! flight at once, round after round, with per-graph completion
//! counters to prove exactly-once execution afterwards.
//!
//! Used by the async series of `benches/graph_rerun.rs` and by the
//! `rust/tests/graph_async.rs` stress tier (which requires a single
//! thread to sustain ≥ 8 graphs in flight).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::graph::{wait_all, GraphError, RunOptions, TaskGraph};
use crate::pool::ThreadPool;

use super::dag::Dag;

/// Drives N independent diamond-chain graphs through `run_async` from
/// a single thread. See the module docs.
pub struct MultiRun {
    graphs: Vec<TaskGraph>,
    counters: Vec<Arc<AtomicUsize>>,
    nodes_per_graph: usize,
    rounds_done: usize,
}

impl MultiRun {
    /// Builds `num_graphs` sealed diamond-chain graphs of
    /// `4 * diamonds` nodes each; every node spins
    /// `busy_work(i, work_steps)` and bumps its graph's counter.
    pub fn new(num_graphs: usize, diamonds: usize, work_steps: u32) -> Self {
        let mut graphs = Vec::with_capacity(num_graphs);
        let mut counters = Vec::with_capacity(num_graphs);
        for _ in 0..num_graphs {
            let (g, counter) = Dag::diamond_chain(diamonds).to_task_graph(work_steps);
            graphs.push(g);
            counters.push(counter);
        }
        Self {
            graphs,
            counters,
            nodes_per_graph: diamonds * 4,
            rounds_done: 0,
        }
    }

    /// Number of graphs kept in flight per round.
    pub fn num_graphs(&self) -> usize {
        self.graphs.len()
    }

    /// Nodes per graph (each executes once per round).
    pub fn nodes_per_graph(&self) -> usize {
        self.nodes_per_graph
    }

    /// Rounds completed so far.
    pub fn rounds_done(&self) -> usize {
        self.rounds_done
    }

    /// One round: launches **every** graph (all handles live at once —
    /// `iter_mut` hands out disjoint borrows, so the borrow checker is
    /// satisfied that no two handles share a graph), then waits for
    /// them in launch order.
    pub fn run_round(&mut self, pool: &ThreadPool) -> Result<(), GraphError> {
        let handles = self
            .graphs
            .iter_mut()
            .map(|g| g.run_async(pool))
            .collect::<Result<Vec<_>, _>>()?;
        for h in handles {
            h.wait()?;
        }
        self.rounds_done += 1;
        Ok(())
    }

    /// Runs `rounds` rounds back to back.
    pub fn run_rounds(&mut self, pool: &ThreadPool, rounds: usize) -> Result<(), GraphError> {
        for _ in 0..rounds {
            self.run_round(pool)?;
        }
        Ok(())
    }

    /// One round with per-graph [`RunOptions`], cycled over the fleet
    /// (graph `i` launches with `options[i % options.len()]`) — the
    /// mixed-priority scenario: tag thirds of the fleet High / Normal /
    /// Low and watch per-class completion latency. The whole fleet is
    /// in flight at once and drained through [`wait_all`] (parked on
    /// the run eventcount, not spin-polled).
    ///
    /// # Panics
    /// If `options` is empty.
    pub fn run_round_with_options(
        &mut self,
        pool: &ThreadPool,
        options: &[RunOptions],
    ) -> Result<(), GraphError> {
        assert!(!options.is_empty(), "run_round_with_options needs at least one RunOptions");
        let mut handles = self
            .graphs
            .iter_mut()
            .enumerate()
            .map(|(i, g)| g.run_async_with_options(pool, options[i % options.len()].clone()))
            .collect::<Result<Vec<_>, _>>()?;
        wait_all(&mut handles)?;
        self.rounds_done += 1;
        Ok(())
    }

    /// Total node executions observed across all graphs so far.
    pub fn total_executions(&self) -> usize {
        self.counters.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// True if every graph has executed exactly
    /// `rounds_done * nodes_per_graph` nodes — the exactly-once
    /// invariant for the whole history of rounds.
    pub fn verify_exactly_once(&self) -> bool {
        let expect = self.rounds_done * self.nodes_per_graph;
        self.counters.iter().all(|c| c.load(Ordering::Relaxed) == expect)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RunPriority;

    #[test]
    fn rounds_keep_all_graphs_exactly_once() {
        let pool = ThreadPool::new(2);
        let mut mr = MultiRun::new(4, 4, 0);
        assert_eq!(mr.num_graphs(), 4);
        assert_eq!(mr.nodes_per_graph(), 16);
        mr.run_rounds(&pool, 5).unwrap();
        assert_eq!(mr.rounds_done(), 5);
        assert!(mr.verify_exactly_once());
        assert_eq!(mr.total_executions(), 4 * 16 * 5);
    }

    #[test]
    fn mixed_priority_rounds_stay_exactly_once() {
        // A 6-graph fleet launched as High/Normal/Low thirds, several
        // rounds: class tags are pure scheduling hints, so per-graph
        // exactly-once must hold regardless.
        let pool = ThreadPool::new(2);
        let mut mr = MultiRun::new(6, 4, 0);
        let classes: Vec<RunOptions> =
            [RunPriority::High, RunPriority::Normal, RunPriority::Low]
                .into_iter()
                .map(|c| RunOptions::new().priority(c))
                .collect();
        for _ in 0..4 {
            mr.run_round_with_options(&pool, &classes).unwrap();
        }
        assert_eq!(mr.rounds_done(), 4);
        assert!(mr.verify_exactly_once());
        assert_eq!(mr.total_executions(), 6 * 16 * 4);
    }
}
