//! Pipeline-parallel transformer-FFN inference as a task graph — the
//! second end-to-end three-layer workload (GPipe-style schedule on the
//! paper's executor).
//!
//! `stages` identical pre-LN FFN blocks process `microbatches`
//! micro-batches. Node `(s, m)` runs stage `s` on micro-batch `m` and
//! depends on `(s-1, m)` (data) and `(s, m-1)` (stage occupancy — each
//! stage's weights are used in micro-batch order, the classic pipeline
//! constraint). The dependency structure is exactly a wavefront, so
//! steady-state parallelism = min(stages, microbatches); every node
//! body executes the `transformer_ffn_64` AOT executable through PJRT.

use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use crate::graph::{RunOptions, TaskGraph, Tracer};
use crate::pool::ThreadPool;
use crate::runtime::{HostTensor, Registry};

/// Per-stage FFN parameters.
struct StageWeights {
    gamma: HostTensor,
    beta: HostTensor,
    w1: HostTensor,
    b1: HostTensor,
    w2: HostTensor,
    b2: HostTensor,
}

impl StageWeights {
    fn random(seed: u64, d: usize, hidden: usize) -> Self {
        Self {
            gamma: HostTensor::full(&[d], 1.0),
            beta: HostTensor::zeros(&[d]),
            w1: HostTensor::random(&[d, hidden], seed),
            b1: HostTensor::random(&[hidden], seed + 1),
            w2: HostTensor::random(&[hidden, d], seed + 2),
            b2: HostTensor::random(&[d], seed + 3),
        }
    }
}

/// Pipeline-parallel FFN inference runner (see module docs).
pub struct Pipeline {
    exe: Arc<crate::runtime::Executable>,
    stages: Vec<StageWeights>,
    batch: usize,
    d: usize,
}

impl Pipeline {
    /// Model dimensions of the `transformer_ffn_64` artifact.
    pub const BATCH: usize = 32;
    /// Feature dimension.
    pub const D: usize = 64;
    /// Hidden dimension.
    pub const HIDDEN: usize = 128;

    /// Builds a pipeline with `num_stages` random FFN stages.
    pub fn new(registry: &Registry, num_stages: usize) -> Result<Self> {
        let exe = registry
            .get("transformer_ffn_64")
            .context("transformer_ffn_64 artifact missing")?;
        Ok(Self {
            exe,
            stages: (0..num_stages)
                .map(|s| StageWeights::random(1000 + 10 * s as u64, Self::D, Self::HIDDEN))
                .collect(),
            batch: Self::BATCH,
            d: Self::D,
        })
    }

    /// Stage count.
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Host-only reference for a full micro-batch pass.
    pub fn forward_host(&self, x: &HostTensor) -> HostTensor {
        self.stages.iter().fold(x.clone(), |acc, w| stage_host(w, &acc))
    }

    /// Runs `microbatches` micro-batches through the pipeline on
    /// `pool`; returns the per-micro-batch outputs. Each graph node
    /// executes the FFN executable; `tracer` (optional) records the
    /// pipeline schedule for inspection.
    pub fn run(
        &self,
        pool: &ThreadPool,
        microbatches: usize,
        tracer: Option<Arc<Tracer>>,
    ) -> Result<Vec<HostTensor>> {
        let s_count = self.stages.len();
        // activations[m] holds micro-batch m's current tensor.
        let activations: Arc<Vec<Mutex<HostTensor>>> = Arc::new(
            (0..microbatches)
                .map(|m| Mutex::new(HostTensor::random(&[self.batch, self.d], 7 + m as u64)))
                .collect(),
        );
        let inputs: Vec<HostTensor> =
            (0..microbatches).map(|m| activations[m].lock().unwrap().clone()).collect();
        let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        let mut g = TaskGraph::with_capacity(s_count * microbatches);
        let mut ids = vec![vec![None; microbatches]; s_count];
        for s in 0..s_count {
            // Stage weights cloned once per stage, shared by its nodes.
            let w = &self.stages[s];
            let weights = Arc::new((
                w.gamma.clone(),
                w.beta.clone(),
                w.w1.clone(),
                w.b1.clone(),
                w.w2.clone(),
                w.b2.clone(),
            ));
            for m in 0..microbatches {
                let (exe, acts, errs, weights) =
                    (self.exe.clone(), activations.clone(), errors.clone(), weights.clone());
                let id = g.add_named(format!("s{s}m{m}"), move || {
                    let x = acts[m].lock().unwrap().clone();
                    match exe.run1(&[
                        x,
                        weights.0.clone(),
                        weights.1.clone(),
                        weights.2.clone(),
                        weights.3.clone(),
                        weights.4.clone(),
                        weights.5.clone(),
                    ]) {
                        Ok(y) => *acts[m].lock().unwrap() = y,
                        Err(e) => errs.lock().unwrap().push(format!("({s},{m}): {e:#}")),
                    }
                });
                ids[s][m] = Some(id);
            }
        }
        for s in 0..s_count {
            for m in 0..microbatches {
                let me = ids[s][m].unwrap();
                if s > 0 {
                    g.succeed(me, &[ids[s - 1][m].unwrap()]);
                }
                if m > 0 {
                    g.succeed(me, &[ids[s][m - 1].unwrap()]);
                }
            }
        }
        let mut options = RunOptions::new();
        if let Some(t) = tracer {
            options = options.with_tracer(t);
        }
        g.run_with_options(pool, options).map_err(|e| crate::anyhow!("{e}"))?;

        let errs = errors.lock().unwrap();
        crate::ensure!(errs.is_empty(), "stage failures: {errs:?}");
        drop(errs);

        // Verify micro-batch 0 against the host oracle.
        let got = activations[0].lock().unwrap().clone();
        let expected = self.forward_host(&inputs[0]);
        crate::ensure!(
            got.allclose(&expected, 2e-2, 2e-2),
            "pipeline output mismatch: max diff {}",
            got.max_abs_diff(&expected)
        );

        Ok((0..microbatches).map(|m| activations[m].lock().unwrap().clone()).collect())
    }
}

/// One FFN stage on the host: `x + mlp2(layernorm(x))` — the
/// verification oracle for the `transformer_ffn_64` executable.
fn stage_host(w: &StageWeights, x: &HostTensor) -> HostTensor {
    let d = w.gamma.data.len();
    let ln = HostTensor::from_fn(&x.shape.clone(), |idx| {
        let row = idx / d;
        let mut mu = 0.0f32;
        for j in 0..d {
            mu += x.data[row * d + j];
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for j in 0..d {
            let t = x.data[row * d + j] - mu;
            var += t * t;
        }
        var /= d as f32;
        let norm = (x.data[idx] - mu) / (var + 1e-5).sqrt();
        norm * w.gamma.data[idx % d] + w.beta.data[idx % d]
    });
    let gelu = |t: &HostTensor, b: &HostTensor| {
        let cols = b.data.len();
        HostTensor::from_fn(&t.shape.clone(), |idx| {
            let z = t.data[idx] + b.data[idx % cols];
            let inner = 0.797_884_6_f32 * (z + 0.044715 * z * z * z);
            0.5 * z * (1.0 + inner.tanh())
        })
    };
    let h = gelu(&ln.matmul_ref(&w.w1), &w.b1);
    let h = gelu(&h.matmul_ref(&w.w2), &w.b2);
    x.add_ref(&h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_oracle_zero_weights_is_identity() {
        // Zero weights -> gelu(0) = 0 -> every stage is the residual.
        let w = StageWeights {
            gamma: HostTensor::full(&[4], 1.0),
            beta: HostTensor::zeros(&[4]),
            w1: HostTensor::zeros(&[4, 8]),
            b1: HostTensor::zeros(&[8]),
            w2: HostTensor::zeros(&[8, 4]),
            b2: HostTensor::zeros(&[4]),
        };
        let x = HostTensor::random(&[2, 4], 1);
        let y = stage_host(&w, &x);
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn host_oracle_layernorm_statistics() {
        // Nonzero weights: check the layernorm part by making the MLP
        // identity-ish impossible, instead verify output differs and
        // is finite.
        let w = StageWeights::random(5, 8, 16);
        let x = HostTensor::random(&[4, 8], 2);
        let y = stage_host(&w, &x);
        assert_eq!(y.shape, x.shape);
        assert!(y.data.iter().all(|v| v.is_finite()));
        assert!(y.max_abs_diff(&x) > 1e-3, "stage should transform the input");
    }

    #[test]
    fn stage_weights_deterministic() {
        let a = StageWeights::random(9, 8, 16);
        let b = StageWeights::random(9, 8, 16);
        assert_eq!(a.w1, b.w1);
        assert_eq!(a.b2, b.b2);
    }
}
