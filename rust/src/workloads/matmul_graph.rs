//! Blocked matrix multiplication as a task graph whose node bodies run
//! AOT-compiled XLA executables — the three-layer composition proof
//! (L3 pool → L2 jax graph → L1 Pallas kernel, Python nowhere at
//! runtime).
//!
//! `C = A @ B` is tiled into a `t × t` grid of `tile × tile` blocks.
//! One graph node per output tile `C[i][j]` runs the K-loop
//! `sum_k A[i][k] @ B[k][j]` by invoking the `matmul_tile_<tile>`
//! executable (which wraps the Pallas tiled-matmul kernel) `t` times.
//! An optional wavefront mode chains tiles diagonally — same compute,
//! dependency-bound schedule — to exercise the §2.2 executor on a
//! realistic dependency pattern.
//!
//! Since PR 10 the tile kernel is pluggable: [`BlockedMatmul::new_host`]
//! builds the same graph with the cache-blocked host kernel
//! ([`HostTensor::matmul_blocked_acc`]) in the node bodies, so the
//! workload runs — and benches — without `make artifacts`, and the
//! PJRT and host paths share one schedule.

use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use crate::graph::TaskGraph;
use crate::pool::ThreadPool;
use crate::runtime::{HostTensor, Registry};

/// Splits a `(t*tile) × (t*tile)` matrix into row-major tiles.
pub fn split_tiles(m: &HostTensor, tile: usize) -> Vec<Vec<HostTensor>> {
    assert_eq!(m.shape.len(), 2);
    let (rows, cols) = (m.shape[0], m.shape[1]);
    assert_eq!(rows % tile, 0);
    assert_eq!(cols % tile, 0);
    let (tr, tc) = (rows / tile, cols / tile);
    (0..tr)
        .map(|bi| {
            (0..tc)
                .map(|bj| {
                    HostTensor::from_fn(&[tile, tile], |idx| {
                        let (i, j) = (idx / tile, idx % tile);
                        m.data[(bi * tile + i) * cols + (bj * tile + j)]
                    })
                })
                .collect()
        })
        .collect()
}

/// Reassembles tiles into one matrix.
pub fn join_tiles(tiles: &[Vec<HostTensor>]) -> HostTensor {
    let tr = tiles.len();
    let tc = tiles[0].len();
    let tile = tiles[0][0].shape[0];
    HostTensor::from_fn(&[tr * tile, tc * tile], |idx| {
        let cols = tc * tile;
        let (i, j) = (idx / cols, idx % cols);
        tiles[i / tile][j / tile].data[(i % tile) * tile + (j % tile)]
    })
}

/// Schedule shape for the blocked matmul graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulSchedule {
    /// All output tiles independent (embarrassingly parallel).
    Independent,
    /// Tile `(i, j)` additionally waits for `(i-1, j)` and `(i, j-1)`
    /// — a wavefront, exercising dependency chains.
    Wavefront,
}

/// What runs inside a `C[i][j]` node's K-loop.
#[derive(Clone)]
enum TileKernel {
    /// AOT-compiled PJRT executable (`matmul_tile_<tile>`).
    Pjrt(Arc<crate::runtime::Executable>),
    /// Cache-blocked host kernel ([`HostTensor::matmul_blocked_acc`]).
    Host,
}

/// Blocked matmul runner; holds the tiles and the tile kernel.
pub struct BlockedMatmul {
    a_tiles: Arc<Vec<Vec<HostTensor>>>,
    b_tiles: Arc<Vec<Vec<HostTensor>>>,
    t: usize,
    tile: usize,
    kernel: TileKernel,
}

impl BlockedMatmul {
    /// Prepares a `t × t`-tile multiplication of `a @ b` using the
    /// `matmul_tile_<tile>` artifact from `registry`.
    pub fn new(registry: &Registry, a: &HostTensor, b: &HostTensor, tile: usize) -> Result<Self> {
        let exe = registry
            .get(&format!("matmul_tile_{tile}"))
            .context("matmul tile kernel not in registry")?;
        Self::with_kernel(a, b, tile, TileKernel::Pjrt(exe))
    }

    /// Like [`new`](BlockedMatmul::new), but the K-loop runs the
    /// cache-blocked host kernel — no artifacts or PJRT required.
    pub fn new_host(a: &HostTensor, b: &HostTensor, tile: usize) -> Result<Self> {
        Self::with_kernel(a, b, tile, TileKernel::Host)
    }

    fn with_kernel(a: &HostTensor, b: &HostTensor, tile: usize, kernel: TileKernel) -> Result<Self> {
        assert_eq!(a.shape, b.shape, "square blocked matmul only");
        assert_eq!(a.shape[0], a.shape[1]);
        let t = a.shape[0] / tile;
        crate::ensure!(t >= 1 && a.shape[0] % tile == 0, "matrix not divisible into {tile}-tiles");
        Ok(Self {
            a_tiles: Arc::new(split_tiles(a, tile)),
            b_tiles: Arc::new(split_tiles(b, tile)),
            t,
            tile,
            kernel,
        })
    }

    /// Number of graph nodes a run creates.
    pub fn num_tasks(&self) -> usize {
        self.t * self.t
    }

    /// Builds and runs the task graph on `pool`; returns `C = A @ B`.
    pub fn run(&self, pool: &ThreadPool, schedule: MatmulSchedule) -> Result<HostTensor> {
        let t = self.t;
        let tile = self.tile;
        let out: Arc<Vec<Vec<Mutex<Option<HostTensor>>>>> =
            Arc::new((0..t).map(|_| (0..t).map(|_| Mutex::new(None)).collect()).collect());
        let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        let mut g = TaskGraph::with_capacity(t * t);
        let mut ids = vec![vec![None; t]; t];
        for i in 0..t {
            for j in 0..t {
                let (a_tiles, b_tiles) = (self.a_tiles.clone(), self.b_tiles.clone());
                let (out, errors, kernel) = (out.clone(), errors.clone(), self.kernel.clone());
                let id = g.add_named(format!("C[{i}][{j}]"), move || {
                    let mut acc = HostTensor::zeros(&[tile, tile]);
                    for k in 0..t {
                        // acc = a[i][k] @ b[k][j] + acc — one K step.
                        match &kernel {
                            TileKernel::Pjrt(exe) => {
                                // One executable call per step (the L1
                                // kernel fuses the add).
                                match exe.run1(&[
                                    a_tiles[i][k].clone(),
                                    b_tiles[k][j].clone(),
                                    acc.clone(),
                                ]) {
                                    Ok(next) => acc = next,
                                    Err(e) => {
                                        errors
                                            .lock()
                                            .unwrap()
                                            .push(format!("tile ({i},{j}) k={k}: {e:#}"));
                                        return;
                                    }
                                }
                            }
                            TileKernel::Host => a_tiles[i][k].matmul_blocked_acc(
                                &b_tiles[k][j],
                                &mut acc,
                                crate::runtime::MATMUL_TILE,
                            ),
                        }
                    }
                    *out[i][j].lock().unwrap() = Some(acc);
                });
                ids[i][j] = Some(id);
            }
        }
        if schedule == MatmulSchedule::Wavefront {
            for i in 0..t {
                for j in 0..t {
                    let me = ids[i][j].unwrap();
                    if i > 0 {
                        g.succeed(me, &[ids[i - 1][j].unwrap()]);
                    }
                    if j > 0 {
                        g.succeed(me, &[ids[i][j - 1].unwrap()]);
                    }
                }
            }
        }
        g.run(pool).map_err(|e| crate::anyhow!("graph run failed: {e}"))?;

        let errs = errors.lock().unwrap();
        crate::ensure!(errs.is_empty(), "kernel failures: {errs:?}");
        drop(errs);

        let tiles: Vec<Vec<HostTensor>> = (0..t)
            .map(|i| {
                (0..t)
                    .map(|j| out[i][j].lock().unwrap().take().expect("tile not produced"))
                    .collect()
            })
            .collect();
        Ok(join_tiles(&tiles))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip() {
        let m = HostTensor::random(&[8, 8], 3);
        let tiles = split_tiles(&m, 4);
        assert_eq!(tiles.len(), 2);
        assert_eq!(tiles[0].len(), 2);
        assert_eq!(tiles[0][0].shape, vec![4, 4]);
        let back = join_tiles(&tiles);
        assert_eq!(back, m);
    }

    #[test]
    fn split_respects_layout() {
        // 4x4 with distinct values; check a specific tile element.
        let m = HostTensor::from_fn(&[4, 4], |i| i as f32);
        let tiles = split_tiles(&m, 2);
        // tile (1,0) holds rows 2..4, cols 0..2 -> flat indices 8,9,12,13
        assert_eq!(tiles[1][0].data, vec![8.0, 9.0, 12.0, 13.0]);
    }

    #[test]
    fn blocked_matmul_against_reference_tiles_only() {
        // Pure host check of the tiling algebra (no artifacts needed):
        // sum over k of a[i][k] @ b[k][j] equals the (i,j) tile of a@b.
        let a = HostTensor::random(&[6, 6], 1);
        let b = HostTensor::random(&[6, 6], 2);
        let at = split_tiles(&a, 3);
        let bt = split_tiles(&b, 3);
        let mut ct: Vec<Vec<HostTensor>> = (0..2)
            .map(|_| (0..2).map(|_| HostTensor::zeros(&[3, 3])).collect())
            .collect();
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    ct[i][j] = ct[i][j].add_ref(&at[i][k].matmul_ref(&bt[k][j]));
                }
            }
        }
        let c = join_tiles(&ct);
        let expected = a.matmul_ref(&b);
        assert!(c.allclose(&expected, 1e-5, 1e-5), "diff={}", c.max_abs_diff(&expected));
    }

    #[test]
    fn host_kernel_blocked_matmul_end_to_end() {
        // The PR 10 artifact-free path: same graph, host tile kernel.
        let a = HostTensor::random(&[12, 12], 5);
        let b = HostTensor::random(&[12, 12], 6);
        let mm = BlockedMatmul::new_host(&a, &b, 4).unwrap();
        assert_eq!(mm.num_tasks(), 9);
        let pool = ThreadPool::new(3);
        let expected = a.matmul_ref(&b);
        for sched in [MatmulSchedule::Independent, MatmulSchedule::Wavefront] {
            let c = mm.run(&pool, sched).unwrap();
            assert!(
                c.allclose(&expected, 1e-4, 1e-5),
                "{sched:?} diff={}",
                c.max_abs_diff(&expected)
            );
        }
    }
}
