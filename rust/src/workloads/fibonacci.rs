//! Recursive Fibonacci without memoization — the paper's §3 benchmark.
//!
//! "A simple recursive function to calculate Fibonacci numbers without
//! memoization, taken from Taskflow examples, can be used to evaluate
//! performance when running a large number of tasks." Every call
//! `fib(n)` with `n >= 2` spawns two child tasks; leaves (`n < 2`)
//! contribute their value to an atomic accumulator, whose final value
//! is `fib(n)` (each unit of the result arrives via exactly one leaf).
//! The workload is pure scheduling overhead: ~`2·fib(n)` tasks that do
//! no work, which is precisely what Fig. 1 (wall) and Fig. 2 (CPU)
//! measure.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::baseline::Executor;

/// Plain single-threaded recursive fib — the correctness oracle.
pub fn fib_reference(n: u32) -> u64 {
    if n < 2 {
        n as u64
    } else {
        fib_reference(n - 1) + fib_reference(n - 2)
    }
}

/// Number of tasks `run_fib(n)` spawns: the call-tree size
/// `T(n) = T(n-1) + T(n-2) + 1`, i.e. `2·fib(n+1) - 1`.
pub fn fib_task_count(n: u32) -> u64 {
    2 * fib_reference(n + 1) - 1
}

fn spawn_fib(ex: Arc<dyn Executor>, n: u32, acc: Arc<AtomicU64>) {
    if n < 2 {
        acc.fetch_add(n as u64, Ordering::Relaxed);
        return;
    }
    let (ex1, acc1) = (ex.clone(), acc.clone());
    let ex1c = ex1.clone();
    ex.submit_boxed(Box::new(move || spawn_fib(ex1c, n - 1, acc1)));
    let ex2c = ex.clone();
    ex.submit_boxed(Box::new(move || spawn_fib(ex2c, n - 2, acc)));
}

/// Computes `fib(n)` on `ex` by spawning the full recursive call tree
/// as tasks, then waiting for quiescence. Returns the computed value
/// (callers assert it equals [`fib_reference`]).
pub fn run_fib(ex: &Arc<dyn Executor>, n: u32) -> u64 {
    let acc = Arc::new(AtomicU64::new(0));
    let (ex0, acc0) = (ex.clone(), acc.clone());
    let ex0c = ex0.clone();
    ex0.submit_boxed(Box::new(move || spawn_fib(ex0c, n, acc0)));
    ex.wait_idle();
    acc.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::all_executors;

    #[test]
    fn reference_values() {
        let expected = [0u64, 1, 1, 2, 3, 5, 8, 13, 21, 34, 55];
        for (n, &e) in expected.iter().enumerate() {
            assert_eq!(fib_reference(n as u32), e);
        }
        assert_eq!(fib_reference(20), 6765);
    }

    #[test]
    fn task_count_formula() {
        // T(0)=1, T(1)=1, T(2)=3, T(3)=5, T(4)=9
        assert_eq!(fib_task_count(0), 1);
        assert_eq!(fib_task_count(1), 1);
        assert_eq!(fib_task_count(2), 3);
        assert_eq!(fib_task_count(3), 5);
        assert_eq!(fib_task_count(4), 9);
    }

    #[test]
    fn pool_computes_fib_correctly() {
        let ex: Arc<dyn Executor> = Arc::new(crate::pool::ThreadPool::new(2));
        for n in [0u32, 1, 5, 12, 16] {
            assert_eq!(run_fib(&ex, n), fib_reference(n), "fib({n})");
        }
    }

    #[test]
    fn all_executors_agree_on_fib_10() {
        for ex in all_executors(2) {
            assert_eq!(run_fib(&ex, 10), 55, "{}", ex.name());
        }
    }
}
