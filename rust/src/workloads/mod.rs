//! Benchmark workload generators (paper §3 + the GitHub benchmark set).
//!
//! * [`fibonacci`] — recursive fib without memoization, the paper's
//!   headline benchmark for "a large number of tasks".
//! * [`dag`] — dependency-graph workloads: linear chain, binary tree,
//!   layered random DAG (graph traversal), and 2-D wavefront, with both
//!   a [`crate::graph::TaskGraph`] construction and a generic
//!   countdown-closure runner usable on any [`crate::baseline::Executor`].
//! * [`matmul_graph`] — blocked matrix multiplication as a task graph
//!   whose node bodies execute AOT-compiled XLA executables through
//!   [`crate::runtime`] (the three-layer composition).
//! * [`multi_run`] — N sealed diamond-chain graphs kept in flight from
//!   one thread through async run handles (the `graph_rerun` async
//!   series and the concurrency-test tier's stress workload).

pub mod dag;
pub mod fibonacci;
pub mod matmul_graph;
pub mod multi_run;
pub mod pipeline;

pub use dag::Dag;
pub use multi_run::MultiRun;
pub use pipeline::Pipeline;
pub use fibonacci::{fib_reference, fib_task_count, run_fib};
