//! Minimal `anyhow`-style error handling, implemented from scratch so
//! the crate stays std-only (the build is fully offline and the real
//! `anyhow` crate is not in the vendor set).
//!
//! The API mirrors the subset of `anyhow` the crate uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain;
//! * [`Result`] — `Result<T, Error>` alias;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`;
//! * [`crate::anyhow!`], [`crate::bail!`], [`crate::ensure!`] macros.
//!
//! `Display` prints the outermost context; the alternate form (`{:#}`)
//! prints the whole chain separated by `: `, matching `anyhow`'s
//! rendering closely enough for log output and tests.

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    /// `chain[0]` is the most recently attached context; the last
    /// element is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Creates an error from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wraps this error with an outer context message.
    pub fn context(mut self, message: impl fmt::Display) -> Self {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The root cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Mirrors anyhow: any std error converts into `Error` (and `Error`
// itself deliberately does NOT implement `std::error::Error`, which is
// what makes this blanket impl coherent).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attaches a context message, turning the failure into [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Constructs an [`Error`] from a format string or displayable value
/// (the in-crate stand-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Returns early with an [`Error`] built like [`crate::anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Returns early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(format!("{}", inner().unwrap_err()).contains("invalid digit"));
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: u32) -> Result<u32> {
            crate::ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                crate::bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(fails(5).unwrap(), 5);
        assert_eq!(format!("{}", fails(12).unwrap_err()), "n too big: 12");
        assert_eq!(format!("{}", fails(3).unwrap_err()), "three is right out");
        let e = crate::anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("root"));
    }
}
