//! Small self-contained utilities shared across the crate.
//!
//! Everything here is implemented from scratch against `std` only (the
//! build is fully offline): a fast PRNG for victim selection and workload
//! generation, a cache-line-padded wrapper to prevent false sharing on
//! hot atomics, process-CPU-time measurement for the Fig. 2
//! reproduction, and an `anyhow`-style [`error`] module for the
//! runtime/CLI layers.

mod cache_padded;
mod cpu_time;
pub mod error;
mod rng;

pub use cache_padded::CachePadded;
pub use cpu_time::{process_cpu_time, thread_count, ProcStat};
pub use error::{Context, Error};
pub use rng::{Pcg32, XorShift64Star};
