//! Cache-line padding to avoid false sharing between hot atomics.

use std::ops::{Deref, DerefMut};

/// Pads and aligns a value to 128 bytes.
///
/// 128 rather than 64 because modern x86 prefetches cache lines in pairs
/// (the "spatial prefetcher"), and aarch64 big cores use 128-byte lines;
/// this matches what crossbeam and Folly do. The `top`/`bottom` indices of
/// the Chase–Lev deque and the per-worker metrics blocks are the primary
/// users: placing `top` and `bottom` on the same line would make every
/// steal invalidate the owner's line on push/pop.
#[derive(Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wraps `value` in a padded cell.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Consumes the wrapper, returning the inner value.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_128() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 128);
        assert_eq!(std::mem::size_of::<CachePadded<u8>>(), 128);
    }

    #[test]
    fn deref_roundtrip() {
        let mut c = CachePadded::new(41usize);
        *c += 1;
        assert_eq!(*c, 42);
        assert_eq!(c.into_inner(), 42);
    }

    #[test]
    fn adjacent_cells_do_not_share_lines() {
        let pair = [CachePadded::new(0u64), CachePadded::new(0u64)];
        let a = &*pair[0] as *const u64 as usize;
        let b = &*pair[1] as *const u64 as usize;
        assert!(b - a >= 128);
    }
}
