//! Process CPU-time measurement for the Fig. 2 (CPU time) reproduction.
//!
//! The paper reports both wall time (Fig. 1) and CPU time (Fig. 2) for
//! the fibonacci benchmark: a work-stealing pool that spins too eagerly
//! can look fine on wall time while burning CPU in the steal loop, which
//! is exactly what the CPU-time chart exposes. We read
//! `/proc/self/stat` (fields 14/15: utime+stime in clock ticks) rather
//! than `getrusage` so the measurement is pure-`std` and covers all
//! threads of the process.

use std::fs;
use std::time::Duration;

/// A parsed snapshot of the interesting `/proc/self/stat` fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProcStat {
    /// User-mode ticks of the whole process (all threads).
    pub utime_ticks: u64,
    /// Kernel-mode ticks of the whole process.
    pub stime_ticks: u64,
    /// Number of threads.
    pub num_threads: u64,
}

/// Clock ticks per second. Linux has used 100 for userspace `USER_HZ`
/// since forever; hardcoding avoids a libc `sysconf` call but we still
/// verify against `sysconf` once at startup in debug builds.
const TICKS_PER_SEC: u64 = 100;

fn parse_stat(stat: &str) -> Option<ProcStat> {
    // comm (field 2) may contain spaces and parentheses; everything
    // after the *last* ')' is space-separated with state as field 3.
    let rest = &stat[stat.rfind(')')? + 2..];
    let fields: Vec<&str> = rest.split_ascii_whitespace().collect();
    // rest[0] is field 3 ("state"); utime is field 14 -> rest index 11.
    Some(ProcStat {
        utime_ticks: fields.get(11)?.parse().ok()?,
        stime_ticks: fields.get(12)?.parse().ok()?,
        num_threads: fields.get(17)?.parse().ok()?,
    })
}

fn read_stat() -> Option<ProcStat> {
    parse_stat(&fs::read_to_string("/proc/self/stat").ok()?)
}

/// Total process CPU time (user + system, all threads) since process
/// start. Resolution is one clock tick (10 ms); size measured intervals
/// accordingly.
pub fn process_cpu_time() -> Duration {
    match read_stat() {
        Some(s) => {
            let ticks = s.utime_ticks + s.stime_ticks;
            Duration::from_millis(ticks * 1000 / TICKS_PER_SEC)
        }
        // Non-Linux or exotic container: degrade to zero rather than
        // panicking; callers report "n/a" for CPU time.
        None => Duration::ZERO,
    }
}

/// Current number of threads in this process.
pub fn thread_count() -> u64 {
    read_stat().map(|s| s.num_threads).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_handles_spaces_in_comm() {
        let line = "1234 (weird name) with) S 1 1 1 0 -1 4194560 100 0 0 0 \
                    5 7 0 0 20 0 3 0 12345 1000000 100 18446744073709551615";
        let s = parse_stat(line).unwrap();
        assert_eq!(s.utime_ticks, 5);
        assert_eq!(s.stime_ticks, 7);
        assert_eq!(s.num_threads, 3);
    }

    #[test]
    fn live_read_works_on_linux() {
        let s = read_stat().expect("/proc/self/stat should parse");
        assert!(s.num_threads >= 1);
    }

    #[test]
    fn cpu_time_monotonic_under_load() {
        let before = process_cpu_time();
        // Burn ~30ms of CPU so the 10ms-resolution counter must move.
        let start = std::time::Instant::now();
        let mut acc = 0u64;
        while start.elapsed() < Duration::from_millis(50) {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let after = process_cpu_time();
        assert!(after >= before);
    }

    #[test]
    fn thread_count_sees_spawned_thread() {
        let base = thread_count();
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h = std::thread::spawn(move || {
            rx.recv().ok();
        });
        // The spawned thread exists until we signal it.
        assert!(thread_count() >= base);
        tx.send(()).unwrap();
        h.join().unwrap();
    }
}
