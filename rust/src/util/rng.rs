//! Small fast PRNGs.
//!
//! Used for steal-victim randomization in the pool hot path (xorshift —
//! one xor-shift chain, no multiplication on the wakeup path) and for
//! reproducible workload generation in `workloads::graph_traversal`
//! (PCG32 — better statistical quality, streamable).

/// `xorshift64*` — 64-bit state, passes BigCrush except binary-rank.
///
/// Good enough for picking steal victims; the quality requirement there
/// is only "don't always hammer the same queue".
#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    /// Creates a generator from a nonzero seed (0 is mapped to a fixed
    /// odd constant).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Seeds from the address of a stack local plus a counter — cheap
    /// per-thread seeding without global state.
    pub fn from_entropy() -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static CTR: AtomicU64 = AtomicU64::new(0x243F_6A88_85A3_08D3);
        let x = CTR.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
        let local = 0u8;
        Self::new(x ^ (&local as *const u8 as u64))
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform-ish value in `0..n` (n > 0) via the widening-multiply
    /// trick (Lemire); bias is negligible for victim selection.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (((self.next_u64() >> 32) * n as u64) >> 32) as usize
    }
}

/// PCG32 (XSH-RR 64/32) — the reference "small fast good" generator.
///
/// Deterministic across platforms; used wherever a workload must be
/// reproducible from a seed recorded in EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6_364_136_223_846_793_005;

    /// Creates a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Creates a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Unbiased uniform value in `0..n` (n > 0), rejection-sampled.
    pub fn next_below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform float in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_nonzero_and_varied() {
        let mut r = XorShift64Star::new(1);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn xorshift_zero_seed_ok() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift64Star::new(42);
        for n in 1..=17usize {
            for _ in 0..100 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn pcg_reference_vector() {
        // First outputs of PCG32 with seed=42, stream=54 from the PCG
        // reference implementation (pcg32_random_r demo).
        let mut r = Pcg32::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c_02b7, 0x7b47_f409, 0xba1d_3330, 0x83d2_f293, 0xbfa4_784b, 0xcbed_606e,
        ];
        for e in expected {
            assert_eq!(r.next_u32(), e);
        }
    }

    #[test]
    fn pcg_deterministic_per_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_next_below_unbiased_range() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pcg_f64_in_unit_interval() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
