//! `scheduling` launcher: run workloads, inspect artifacts, and smoke
//! the full stack from one binary.
//!
//! ```text
//! scheduling run fib        --n 25 --threads 4 --executor scheduling
//! scheduling run chain      --size 65536 --threads 4
//! scheduling run wavefront  --size 32 --threads 4 --work 100
//! scheduling run matmul     --size 256 --tile 64 --schedule wavefront
//! scheduling graph-demo     # the paper's (a+b)*(c+d) example
//! scheduling artifacts      # list compiled XLA artifacts
//! scheduling info           # testbed + pool configuration report
//! ```

use std::sync::Arc;
use std::time::Instant;

use scheduling::baseline::{all_executors, executor_by_name};
use scheduling::util::error::{Context, Result};
use scheduling::{anyhow, bail, ensure};
use scheduling::cli::{Args, Config};
use scheduling::graph::Dataflow;
use scheduling::pool::ThreadPool;
use scheduling::runtime::{find_artifacts_dir, HostTensor, Registry, Runtime};
use scheduling::util::{process_cpu_time, thread_count};
use scheduling::workloads::matmul_graph::{BlockedMatmul, MatmulSchedule};
use scheduling::workloads::{fib_reference, fib_task_count, run_fib, Dag};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env().map_err(|e| anyhow!("{e}"))?;
    if let Some(path) = args.raw("config").map(str::to_string) {
        let config = Config::load(&path).map_err(|e| anyhow!("{e}"))?;
        args.merge_defaults(config.values());
    }
    match args.positional(0) {
        Some("run") => cmd_run(&args),
        Some("graph-demo") => cmd_graph_demo(&args),
        Some("artifacts") => cmd_artifacts(),
        Some("kernel-lat") => cmd_kernel_lat(&args),
        Some("info") => cmd_info(&args),
        Some(other) => bail!("unknown command {other:?}; try `scheduling info`"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "scheduling — work-stealing thread pool + task graphs (Puyda 2024 reproduction)\n\
         \n\
         commands:\n\
           run fib|chain|btree|dag|wavefront|matmul   run a workload\n\
           graph-demo                                 paper §4.2 example\n\
           artifacts                                  list AOT artifacts\n\
           info                                       testbed report\n\
         \n\
         common flags: --threads N --executor scheduling|taskflow|mutex|spawn\n\
         workload flags: --n --size --depth --work --tile --schedule --seed --config FILE"
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let workload = args.positional(1).context("run: missing workload name")?;
    let threads = args.get("threads", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))?;
    let executor_name = args.raw("executor").unwrap_or("scheduling").to_string();
    let work: u32 = args.get("work", 0)?;

    let wall_start = Instant::now();
    let cpu_start = process_cpu_time();
    match workload {
        "fib" => {
            let n: u32 = args.get("n", 25)?;
            let ex = executor_by_name(&executor_name, threads)
                .with_context(|| format!("unknown executor {executor_name:?}"))?;
            let got = run_fib(&ex, n);
            let expected = fib_reference(n);
            ensure!(got == expected, "fib mismatch: {got} != {expected}");
            println!("fib({n}) = {got} via {} ({} tasks)", ex.name(), fib_task_count(n));
        }
        "chain" | "btree" | "dag" | "wavefront" => {
            let dag = build_dag(workload, args)?;
            let ex = executor_by_name(&executor_name, threads)
                .with_context(|| format!("unknown executor {executor_name:?}"))?;
            let executed = if executor_name == "scheduling" {
                // Native path: the §2.2 graph executor.
                let pool = ThreadPool::new(threads);
                let (mut g, counter) = dag.to_task_graph(work);
                let mut options = scheduling::graph::RunOptions::new();
                let tracer = if args.flag("trace") {
                    let t = Arc::new(scheduling::graph::Tracer::new());
                    options = options.with_tracer(t.clone());
                    Some(t)
                } else {
                    None
                };
                g.run_with_options(&pool, options).map_err(|e| anyhow!("{e}"))?;
                println!("{}", pool.metrics());
                if let Some(t) = tracer {
                    let out = args.raw("out").unwrap_or("trace.json").to_string();
                    std::fs::write(&out, t.to_chrome_trace())?;
                    println!("{}", t.ascii_gantt(72));
                    println!("chrome trace written to {out} (open in chrome://tracing)");
                }
                counter.load(std::sync::atomic::Ordering::Relaxed)
            } else {
                dag.run_countdown(&ex, work)
            };
            ensure!(executed == dag.len(), "executed {executed} of {} nodes", dag.len());
            println!(
                "{} [{} nodes, {} edges] on {} ({} threads): all nodes executed",
                dag.kind,
                dag.len(),
                dag.num_edges(),
                executor_name,
                threads
            );
        }
        "matmul" => {
            let size: usize = args.get("size", 256)?;
            let tile: usize = args.get("tile", 64)?;
            let schedule = match args.raw("schedule").unwrap_or("independent") {
                "wavefront" => MatmulSchedule::Wavefront,
                _ => MatmulSchedule::Independent,
            };
            let (c, expected) = run_matmul(size, tile, threads, schedule)?;
            let diff = c.max_abs_diff(&expected);
            ensure!(diff < 1e-3, "matmul verification failed: max diff {diff}");
            println!("matmul {size}x{size} tile={tile} verified (max diff {diff:.2e})");
        }
        other => bail!("unknown workload {other:?}"),
    }
    println!(
        "wall {:.3}s  cpu {:.3}s  threads(process) {}",
        wall_start.elapsed().as_secs_f64(),
        process_cpu_time().saturating_sub(cpu_start).as_secs_f64(),
        thread_count()
    );
    Ok(())
}

fn build_dag(kind: &str, args: &Args) -> Result<Dag> {
    Ok(match kind {
        "chain" => Dag::linear_chain(args.get("size", 65536)?),
        "btree" => Dag::binary_tree(args.get("depth", 16)?),
        "dag" => Dag::layered_random(
            args.get("layers", 64)?,
            args.get("width", 64)?,
            args.get("p", 0.15f64)?,
            args.get("seed", 42)?,
        ),
        "wavefront" => Dag::wavefront(args.get("size", 32)?),
        _ => unreachable!(),
    })
}

fn run_matmul(size: usize, tile: usize, threads: usize, schedule: MatmulSchedule) -> Result<(HostTensor, HostTensor)> {
    let runtime = Arc::new(Runtime::cpu()?);
    let registry = Registry::open_default(runtime)?;
    let a = HostTensor::random(&[size, size], 1);
    let b = HostTensor::random(&[size, size], 2);
    let mm = BlockedMatmul::new(&registry, &a, &b, tile)?;
    let pool = ThreadPool::new(threads);
    let c = mm.run(&pool, schedule)?;
    Ok((c, a.matmul_ref(&b)))
}

fn cmd_graph_demo(args: &Args) -> Result<()> {
    // The paper's §4.2 worked example, via the typed dataflow layer.
    let threads = args.get("threads", 2)?;
    let pool = ThreadPool::new(threads);
    let mut df = Dataflow::new();
    let a = df.node("get_a", || 1);
    let b = df.node("get_b", || 2);
    let c = df.node("get_c", || 3);
    let d = df.node("get_d", || 4);
    let ab = df.node2("a+b", &a, &b, |x, y| x + y);
    let cd = df.node2("c+d", &c, &d, |x, y| x + y);
    let product = df.node2("(a+b)*(c+d)", &ab, &cd, |x, y| x * y);
    df.run(&pool).map_err(|e| anyhow!("{e}"))?;
    println!("(a+b)*(c+d) = {}", product.take().map_err(|e| anyhow!("{e}"))?);
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let dir = find_artifacts_dir().context("no artifacts found — run `make artifacts`")?;
    println!("artifacts at {}", dir.display());
    let runtime = Arc::new(Runtime::cpu()?);
    let registry = Registry::open(runtime, &dir)?;
    for name in registry.names() {
        let e = registry.entry(name).unwrap();
        let ins: Vec<String> = e.inputs.iter().map(|s| s.render()).collect();
        let outs: Vec<String> = e.outputs.iter().map(|s| s.render()).collect();
        println!("  {name}: ({}) -> ({})  [{}]", ins.join(", "), outs.join(", "), e.file);
    }
    Ok(())
}

/// Per-call latency of every registered executable (perf-pass tool:
/// isolates PJRT dispatch + literal conversion from pool overhead).
fn cmd_kernel_lat(args: &Args) -> Result<()> {
    let repeat: usize = args.get("repeat", 50)?;
    let runtime = Arc::new(Runtime::cpu()?);
    let registry = Registry::open_default(runtime)?;
    println!("{:<20} {:>12} {:>12} {:>12}", "kernel", "mean", "min", "max");
    for name in registry.names() {
        let entry = registry.entry(name).unwrap().clone();
        let exe = registry.get(name)?;
        let inputs: Vec<HostTensor> = entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| HostTensor::random(&s.dims, i as u64 + 1))
            .collect();
        exe.run(&inputs)?; // warm
        let mut samples = Vec::with_capacity(repeat);
        for _ in 0..repeat {
            let t0 = Instant::now();
            exe.run(&inputs)?;
            samples.push(t0.elapsed());
        }
        let mean: std::time::Duration =
            samples.iter().sum::<std::time::Duration>() / samples.len() as u32;
        println!(
            "{:<20} {:>12} {:>12} {:>12}",
            name,
            format!("{:.2?}", mean),
            format!("{:.2?}", samples.iter().min().unwrap()),
            format!("{:.2?}", samples.iter().max().unwrap())
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("scheduling v{}", env!("CARGO_PKG_VERSION"));
    println!("hardware threads: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    println!("process threads:  {}", thread_count());
    let threads = args.get("threads", 2)?;
    println!("\nexecutors at --threads {threads}:");
    for ex in all_executors(threads) {
        println!("  {} ({} workers)", ex.name(), ex.num_threads());
    }
    match find_artifacts_dir() {
        Some(d) => println!("\nartifacts: {}", d.display()),
        None => println!("\nartifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
