//! PJRT client wrapper: compile HLO text once, execute many times from
//! worker threads.
//!
//! Two build modes behind one API:
//!
//! * **`pjrt` feature on** — the real implementation, backed by the
//!   `xla` crate (xla_extension bindings). Not in the offline vendor
//!   set; enabling the feature requires adding the dependency by hand
//!   (see `Cargo.toml`).
//! * **default (stub)** — [`Runtime::cpu`] succeeds (so probing code
//!   and `scheduling info` work), but compiling or executing a kernel
//!   returns a clear "built without the `pjrt` feature" error. All
//!   artifact-dependent tests skip themselves when no artifacts
//!   directory exists, so `cargo test` stays green on a stub build.

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Executable, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{Executable, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::Path;
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::runtime::tensor::HostTensor;
    use crate::util::error::{Context, Result};

    /// Wrapper around the PJRT CPU client.
    ///
    /// Create one per process and share it (`Arc<Runtime>`); executables
    /// compiled from it can be executed concurrently from pool workers.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    // SAFETY: the PJRT CPU client is thread-safe (PJRT C API contract:
    // PjRtClient/PjRtLoadedExecutable::Execute are thread-compatible for
    // concurrent Execute calls); the Rust wrapper just doesn't declare it.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}

    impl Runtime {
        /// Creates a PJRT CPU client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client })
        }

        /// Platform string (e.g. "cpu") — handy for logs.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Loads HLO **text** (see module docs for why text, not proto)
        /// and compiles it into an [`Executable`].
        pub fn load_hlo_text(&self, path: impl AsRef<Path>, name: impl Into<String>) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text at {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable {
                exe,
                name: name.into(),
                executions: AtomicU64::new(0),
            })
        }
    }

    /// A compiled XLA computation, executable from any thread.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
        executions: AtomicU64,
    }

    // SAFETY: see Runtime — concurrent Execute on a PJRT CPU loaded
    // executable is supported; each call gets its own output buffers.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        /// The registry/debug name.
        pub fn name(&self) -> &str {
            &self.name
        }

        /// How many times `run` has completed (metrics).
        pub fn executions(&self) -> u64 {
            self.executions.load(Ordering::Relaxed)
        }

        /// Executes with host-tensor inputs and fetches host-tensor
        /// outputs. The computation was lowered with `return_tuple=True`,
        /// so the single result literal is a tuple; each element becomes
        /// one output tensor.
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    // Single-copy path: build the literal directly from the
                    // host bytes (vec1 + reshape would copy twice — see
                    // EXPERIMENTS.md §Perf L-runtime).
                    let bytes = unsafe {
                        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &t.shape,
                        bytes,
                    )
                    .with_context(|| format!("creating input literal {:?}", t.shape))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = out.to_tuple().context("decomposing result tuple")?;
            let tensors = parts
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape().context("output shape")?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<f32>().context("output data")?;
                    Ok(HostTensor::from_vec(&dims, data))
                })
                .collect::<Result<Vec<_>>>()?;
            self.executions.fetch_add(1, Ordering::Relaxed);
            Ok(tensors)
        }

        /// Like [`Executable::run`] but returns only the first output
        /// (the common single-output case).
        pub fn run1(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
            let mut outs = self.run(inputs)?;
            crate::ensure!(!outs.is_empty(), "{} returned no outputs", self.name);
            Ok(outs.swap_remove(0))
        }
    }

    impl std::fmt::Debug for Executable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executable")
                .field("name", &self.name)
                .field("executions", &self.executions())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use std::path::Path;

    use crate::runtime::tensor::HostTensor;
    use crate::util::error::Result;

    const UNAVAILABLE: &str =
        "compiled kernels unavailable: built without the `pjrt` feature (see runtime/client.rs)";

    /// Stub stand-in for the PJRT CPU client (see module docs).
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Succeeds so probing code can construct a runtime; any attempt
        /// to compile a kernel through it fails with a clear error.
        pub fn cpu() -> Result<Self> {
            Ok(Self { _private: () })
        }

        /// Platform string, marked as the stub backend.
        pub fn platform(&self) -> String {
            "cpu-stub".to_string()
        }

        /// Always fails: compiling HLO needs the `pjrt` feature.
        pub fn load_hlo_text(&self, path: impl AsRef<Path>, name: impl Into<String>) -> Result<Executable> {
            let _ = (path.as_ref(), name.into());
            Err(crate::anyhow!(UNAVAILABLE))
        }
    }

    /// Stub executable; never actually constructed (loading fails), but
    /// the type must exist for the registry/workload signatures.
    pub struct Executable {
        name: String,
    }

    impl Executable {
        /// The registry/debug name.
        pub fn name(&self) -> &str {
            &self.name
        }

        /// How many times `run` has completed — always 0 on the stub.
        pub fn executions(&self) -> u64 {
            0
        }

        /// Always fails on the stub build.
        pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            Err(crate::anyhow!(UNAVAILABLE))
        }

        /// Always fails on the stub build.
        pub fn run1(&self, _inputs: &[HostTensor]) -> Result<HostTensor> {
            Err(crate::anyhow!(UNAVAILABLE))
        }
    }

    impl std::fmt::Debug for Executable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executable").field("name", &self.name).finish()
        }
    }
}
