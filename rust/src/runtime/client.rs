//! PJRT client wrapper: compile HLO text once, execute many times from
//! worker threads.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use super::tensor::HostTensor;

/// Wrapper around the PJRT CPU client.
///
/// Create one per process and share it (`Arc<Runtime>`); executables
/// compiled from it can be executed concurrently from pool workers.
pub struct Runtime {
    client: xla::PjRtClient,
}

// SAFETY: the PJRT CPU client is thread-safe (PJRT C API contract:
// PjRtClient/PjRtLoadedExecutable::Execute are thread-compatible for
// concurrent Execute calls); the Rust wrapper just doesn't declare it.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Creates a PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform string (e.g. "cpu") — handy for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Loads HLO **text** (see module docs for why text, not proto)
    /// and compiles it into an [`Executable`].
    pub fn load_hlo_text(&self, path: impl AsRef<Path>, name: impl Into<String>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: name.into(),
            executions: AtomicU64::new(0),
        })
    }
}

/// A compiled XLA computation, executable from any thread.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    executions: AtomicU64,
}

// SAFETY: see Runtime — concurrent Execute on a PJRT CPU loaded
// executable is supported; each call gets its own output buffers.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// The registry/debug name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// How many times `run` has completed (metrics).
    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// Executes with host-tensor inputs and fetches host-tensor
    /// outputs. The computation was lowered with `return_tuple=True`,
    /// so the single result literal is a tuple; each element becomes
    /// one output tensor.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                // Single-copy path: build the literal directly from the
                // host bytes (vec1 + reshape would copy twice — see
                // EXPERIMENTS.md §Perf L-runtime).
                let bytes = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.shape,
                    bytes,
                )
                .with_context(|| format!("creating input literal {:?}", t.shape))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        let tensors = parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("output shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().context("output data")?;
                Ok(HostTensor::from_vec(&dims, data))
            })
            .collect::<Result<Vec<_>>>()?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        Ok(tensors)
    }

    /// Like [`Executable::run`] but returns only the first output
    /// (the common single-output case).
    pub fn run1(&self, inputs: &[HostTensor]) -> Result<HostTensor> {
        let mut outs = self.run(inputs)?;
        anyhow::ensure!(!outs.is_empty(), "{} returned no outputs", self.name);
        Ok(outs.swap_remove(0))
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("name", &self.name)
            .field("executions", &self.executions())
            .finish()
    }
}
