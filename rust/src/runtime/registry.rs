//! Artifact registry: the manifest written by `python/compile/aot.py`
//! plus an executable cache.
//!
//! Manifest format (`artifacts/manifest.tsv`, tab-separated, one row
//! per compiled computation — deliberately trivial to parse with no
//! JSON dependency):
//!
//! ```text
//! name <TAB> file <TAB> inputs <TAB> outputs
//! matmul_tile_64 <TAB> matmul_tile_64.hlo.txt <TAB> f32[64,64];f32[64,64] <TAB> f32[64,64]
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use super::client::{Executable, Runtime};

/// Shape spec for one argument: dtype (always f32 today) and dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    /// Element type name as written by aot.py (e.g. "f32").
    pub dtype: String,
    /// Dimension sizes.
    pub dims: Vec<usize>,
}

impl ArgSpec {
    fn parse(s: &str) -> Result<Self> {
        // "f32[64,64]" or "f32[]" (scalar)
        let open = s.find('[').with_context(|| format!("bad arg spec {s:?}"))?;
        let close = s.rfind(']').with_context(|| format!("bad arg spec {s:?}"))?;
        let dtype = s[..open].to_string();
        let inner = &s[open + 1..close];
        let dims = if inner.is_empty() {
            vec![]
        } else {
            inner
                .split(',')
                .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim in {s:?}")))
                .collect::<Result<_>>()?
        };
        if dtype.is_empty() {
            crate::bail!("missing dtype in arg spec {s:?}");
        }
        Ok(Self { dtype, dims })
    }

    /// Renders back to `f32[64,64]` form.
    pub fn render(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype, dims.join(","))
    }
}

/// One manifest row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Registry key (e.g. "matmul_tile_64").
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Input argument specs, in call order.
    pub inputs: Vec<ArgSpec>,
    /// Output specs.
    pub outputs: Vec<ArgSpec>,
}

fn parse_specs(field: &str) -> Result<Vec<ArgSpec>> {
    if field.trim().is_empty() {
        return Ok(vec![]);
    }
    field.split(';').map(|s| ArgSpec::parse(s.trim())).collect()
}

/// Parses the manifest text (exposed for unit tests).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactEntry>> {
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            crate::bail!("manifest line {}: expected 4 tab-separated columns, got {}", lineno + 1, cols.len());
        }
        entries.push(ArtifactEntry {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            inputs: parse_specs(cols[2]).with_context(|| format!("line {}", lineno + 1))?,
            outputs: parse_specs(cols[3]).with_context(|| format!("line {}", lineno + 1))?,
        });
    }
    Ok(entries)
}

/// Loads the manifest, compiles on first use, caches executables.
pub struct Registry {
    runtime: Arc<Runtime>,
    dir: PathBuf,
    entries: HashMap<String, ArtifactEntry>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Registry {
    /// Opens the registry at `dir` (must contain `manifest.tsv`).
    pub fn open(runtime: Arc<Runtime>, dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let entries = parse_manifest(&text)?
            .into_iter()
            .map(|e| (e.name.clone(), e))
            .collect();
        Ok(Self {
            runtime,
            dir,
            entries,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Opens the registry at the auto-discovered artifacts dir.
    pub fn open_default(runtime: Arc<Runtime>) -> Result<Self> {
        let dir = super::find_artifacts_dir()
            .context("artifacts directory not found — run `make artifacts` first")?;
        Self::open(runtime, dir)
    }

    /// Names of all registered computations, sorted.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Metadata for one entry.
    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Returns the compiled executable for `name`, compiling and
    /// caching it on first use. Thread-safe; the brief double-compile
    /// window under a race is benign (last one wins the cache slot).
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .entries
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}; known: {:?}", self.names()))?;
        let exe = Arc::new(
            self.runtime
                .load_hlo_text(self.dir.join(&entry.file), name.to_string())?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compiles everything (startup-time warm).
    pub fn warm_all(&self) -> Result<()> {
        for name in self.names() {
            self.get(name)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_spec_roundtrip() {
        let s = ArgSpec::parse("f32[64,128]").unwrap();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.dims, vec![64, 128]);
        assert_eq!(s.render(), "f32[64,128]");
        let scalar = ArgSpec::parse("f32[]").unwrap();
        assert!(scalar.dims.is_empty());
        assert_eq!(scalar.render(), "f32[]");
    }

    #[test]
    fn arg_spec_rejects_garbage() {
        assert!(ArgSpec::parse("f32").is_err());
        assert!(ArgSpec::parse("[1,2]").is_err());
        assert!(ArgSpec::parse("f32[a]").is_err());
    }

    #[test]
    fn manifest_parse() {
        let text = "# comment\n\
                    matmul\tmatmul.hlo.txt\tf32[8,8];f32[8,8]\tf32[8,8]\n\
                    \n\
                    scale\tscale.hlo.txt\tf32[4]\tf32[4];f32[]\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "matmul");
        assert_eq!(entries[0].inputs.len(), 2);
        assert_eq!(entries[1].outputs.len(), 2);
        assert_eq!(entries[1].outputs[1].dims.len(), 0);
    }

    #[test]
    fn manifest_rejects_bad_columns() {
        assert!(parse_manifest("just_a_name\n").is_err());
        assert!(parse_manifest("a\tb\tc\n").is_err());
    }
}
