//! PJRT runtime: loads AOT-compiled HLO (produced by
//! `python/compile/aot.py`) and executes it from the Rust request path.
//!
//! Python runs exactly once, at build time (`make artifacts`); this
//! module is the only consumer of its output. The interchange format is
//! **HLO text** (not a serialized `HloModuleProto`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that the crate's xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids and round-trips
//! cleanly (see `/opt/xla-example/README.md` and DESIGN.md).
//!
//! * [`tensor`] — host-side `f32` tensors and reference math.
//! * [`client`] — PJRT CPU client wrapper + compiled [`Executable`].
//! * [`registry`] — loads `artifacts/manifest.tsv`, compiles every
//!   kernel once, and hands out shared executables by name.

pub mod client;
pub mod registry;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use registry::{ArtifactEntry, Registry};
pub use tensor::{HostTensor, MATMUL_TILE};

/// Default artifacts directory, relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locates the artifacts directory: `$SCHEDULING_ARTIFACTS` if set,
/// else walks up from the current directory looking for
/// `artifacts/manifest.tsv` (so tests work from the target dir too).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("SCHEDULING_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.tsv").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join(DEFAULT_ARTIFACTS_DIR);
        if candidate.join("manifest.tsv").exists() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}
