//! Host-side tensors: the boundary type between the coordinator and
//! the PJRT executables, plus reference math for end-to-end checks.
//!
//! Since PR 10 this is also where the *fast* host compute lives:
//! cache-blocked kernels beside the naive oracles. [`matmul_blocked`]
//! tiles the i-k-j loop nest so one `MATMUL_TILE²` panel of B stays in
//! L1 while a panel of rows streams through it (the innermost j-loop
//! is written over exact-length slices so LLVM vectorizes it into FMA
//! lanes — the register-blocked micro-kernel), [`stencil_step`] is a
//! 5-point average, and both have `parallel_for`-powered `_par`
//! variants that split output rows across the pool as one blocked
//! burst. Every fast path has an `allclose` oracle: `matmul_ref` for
//! the matmuls, the serial stencil for the parallel one.
//!
//! [`matmul_blocked`]: HostTensor::matmul_blocked
//! [`stencil_step`]: HostTensor::stencil_step

use std::ops::Range;

use crate::graph::{parallel_for, GraphError};
use crate::pool::ThreadPool;
use crate::util::Pcg32;

/// Default square tile edge for the blocked matmul: a 64×64 `f32`
/// panel is 16 KiB, so one B panel plus the active A/C rows fit in a
/// 32 KiB L1. The compute bench sweeps this knob via
/// [`HostTensor::matmul_blocked_tiled`].
pub const MATMUL_TILE: usize = 64;

/// Raw mutable base pointer smuggled into `parallel_for` bodies. The
/// parallel kernels hand each block a *disjoint* row range of the
/// output, so concurrent writes through this pointer never alias.
#[derive(Clone, Copy)]
struct SendMutPtr(*mut f32);

unsafe impl Send for SendMutPtr {}
unsafe impl Sync for SendMutPtr {}

/// Accumulates `c += a @ b` for an `m × k` row-panel `a` against the
/// full `k × n` matrix `b`, tiled over k and j. Shared by the serial
/// and parallel entry points (the parallel one calls it per row-block).
fn matmul_acc_panel(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, tile: usize) {
    let tile = tile.max(8);
    for kk in (0..k).step_by(tile) {
        let k_end = (kk + tile).min(k);
        for jj in (0..n).step_by(tile) {
            let j_end = (jj + tile).min(n);
            for i in 0..m {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut c[i * n + jj..i * n + j_end];
                for p in kk..k_end {
                    let a_ip = a_row[p];
                    let b_row = &b[p * n + jj..p * n + j_end];
                    // Exact-length slice pair: vectorizes to FMA lanes.
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += a_ip * bv;
                    }
                }
            }
        }
    }
}

/// One 5-point stencil row: `out[j] = (c + up + down + left + right)/5`
/// for interior j, boundary columns copied through.
fn stencil_row(up: &[f32], cur: &[f32], down: &[f32], out: &mut [f32]) {
    let n = cur.len();
    out[0] = cur[0];
    if n > 1 {
        out[n - 1] = cur[n - 1];
    }
    for j in 1..n.saturating_sub(1) {
        out[j] = (cur[j] + up[j] + down[j] + cur[j - 1] + cur[j + 1]) * 0.2;
    }
}

/// A dense row-major `f32` tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major data; `len == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// A tensor filled with zeros.
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// A tensor filled with `value`.
    pub fn full(shape: &[usize], value: f32) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![value; shape.iter().product()],
        }
    }

    /// Builds from a function of the flat index.
    pub fn from_fn(shape: &[usize], f: impl Fn(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: (0..n).map(f).collect(),
        }
    }

    /// Deterministic uniform values in `[-1, 1)` from a seed.
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect(),
        }
    }

    /// Wraps existing data (checks the element count).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length does not match shape {shape:?}"
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for zero-element tensors.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element at a 2-D index (panics unless rank 2).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Reference matmul `self @ rhs` (rank-2 only) — the oracle for the
    /// PJRT matmul kernels.
    pub fn matmul_ref(&self, rhs: &HostTensor) -> HostTensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "inner dims mismatch");
        let mut out = HostTensor::zeros(&[m, n]);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[i * n + j] += a * rhs.data[p * n + j];
                }
            }
        }
        out
    }

    /// Elementwise sum (shapes must match).
    pub fn add_ref(&self, rhs: &HostTensor) -> HostTensor {
        assert_eq!(self.shape, rhs.shape);
        HostTensor::from_vec(
            &self.shape,
            self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        )
    }

    /// Largest absolute elementwise difference.
    pub fn max_abs_diff(&self, rhs: &HostTensor) -> f32 {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// True if all elements are within `atol + rtol * |expected|`.
    pub fn allclose(&self, expected: &HostTensor, rtol: f32, atol: f32) -> bool {
        if self.shape != expected.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&expected.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Sum of all elements (for cheap end-to-end checksums).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    fn matmul_dims(&self, rhs: &HostTensor) -> (usize, usize, usize) {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "inner dims mismatch");
        (m, k, n)
    }

    /// Cache-blocked serial matmul `self @ rhs` with the default
    /// [`MATMUL_TILE`]. Same contract as [`matmul_ref`], much faster
    /// on matrices that outgrow L1.
    ///
    /// [`matmul_ref`]: HostTensor::matmul_ref
    pub fn matmul_blocked(&self, rhs: &HostTensor) -> HostTensor {
        self.matmul_blocked_tiled(rhs, MATMUL_TILE)
    }

    /// [`matmul_blocked`](HostTensor::matmul_blocked) with an explicit
    /// tile edge (the ABL tile sweep's knob).
    pub fn matmul_blocked_tiled(&self, rhs: &HostTensor, tile: usize) -> HostTensor {
        let (m, _, n) = self.matmul_dims(rhs);
        let mut out = HostTensor::zeros(&[m, n]);
        self.matmul_blocked_acc(rhs, &mut out, tile);
        out
    }

    /// Blocked matmul into an existing buffer (zeroed first): the
    /// allocation-free form the inplace dataflow nodes use.
    pub fn matmul_blocked_into(&self, rhs: &HostTensor, out: &mut HostTensor) {
        let (m, _, n) = self.matmul_dims(rhs);
        assert_eq!(out.shape, &[m, n], "output shape mismatch");
        out.data.fill(0.0);
        self.matmul_blocked_acc(rhs, out, MATMUL_TILE);
    }

    /// Accumulating blocked matmul `out += self @ rhs` — the K-loop
    /// building block for tiled graph matmuls
    /// (`workloads::BlockedMatmul`'s host kernel).
    pub fn matmul_blocked_acc(&self, rhs: &HostTensor, out: &mut HostTensor, tile: usize) {
        let (m, k, n) = self.matmul_dims(rhs);
        assert_eq!(out.shape, &[m, n], "output shape mismatch");
        matmul_acc_panel(&self.data, &rhs.data, &mut out.data, m, k, n, tile);
    }

    /// Parallel cache-blocked matmul: output rows are split into
    /// blocks (Shoshany's `threads × oversubscription` heuristic) and
    /// each block runs the serial panel kernel on the pool. Results
    /// are bit-identical to [`matmul_blocked`] — the reduction order
    /// per element is unchanged; only row ownership moves.
    pub fn matmul_blocked_par(
        &self,
        rhs: &HostTensor,
        pool: &ThreadPool,
    ) -> Result<HostTensor, GraphError> {
        let (m, k, n) = self.matmul_dims(rhs);
        let mut out = HostTensor::zeros(&[m, n]);
        {
            let out_ptr = SendMutPtr(out.data.as_mut_ptr());
            let (a, b) = (&self.data, &rhs.data);
            parallel_for(pool, 0..m, 1, move |rows: Range<usize>| {
                let a_panel = &a[rows.start * k..rows.end * k];
                // SAFETY: `parallel_for` hands out disjoint row
                // ranges, so blocks write non-overlapping slices of
                // `out`, which outlives the loop (parallel_for joins
                // before this function returns).
                let c_panel = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(rows.start * n), rows.len() * n)
                };
                matmul_acc_panel(a_panel, b, c_panel, rows.len(), k, n, MATMUL_TILE);
            })?;
        }
        Ok(out)
    }

    /// One serial 5-point stencil step (rank-2): interior cells become
    /// the average of themselves and their 4 neighbours, boundary
    /// cells copy through. Its own oracle — the parallel variant must
    /// match it bit-exactly.
    pub fn stencil_step(&self) -> HostTensor {
        let mut out = HostTensor::zeros(&self.shape);
        self.stencil_step_into(&mut out);
        out
    }

    /// [`stencil_step`](HostTensor::stencil_step) into an existing
    /// buffer (the inplace dataflow form).
    pub fn stencil_step_into(&self, out: &mut HostTensor) {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(out.shape, self.shape, "output shape mismatch");
        let (m, n) = (self.shape[0], self.shape[1]);
        if m == 0 || n == 0 {
            return;
        }
        for i in 0..m {
            let cur = &self.data[i * n..(i + 1) * n];
            if i == 0 || i == m - 1 {
                out.data[i * n..(i + 1) * n].copy_from_slice(cur);
                continue;
            }
            let up = &self.data[(i - 1) * n..i * n];
            let down = &self.data[(i + 1) * n..(i + 2) * n];
            stencil_row(up, cur, down, &mut out.data[i * n..(i + 1) * n]);
        }
    }

    /// Parallel 5-point stencil step: rows are split across the pool;
    /// each block reads its row-range plus one halo row on either side
    /// and writes its own rows only. Bit-identical to
    /// [`stencil_step`](HostTensor::stencil_step).
    pub fn stencil_step_par(
        &self,
        pool: &ThreadPool,
        out: &mut HostTensor,
    ) -> Result<(), GraphError> {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(out.shape, self.shape, "output shape mismatch");
        let (m, n) = (self.shape[0], self.shape[1]);
        if m == 0 || n == 0 {
            return Ok(());
        }
        let out_ptr = SendMutPtr(out.data.as_mut_ptr());
        let src = &self.data;
        parallel_for(pool, 0..m, 1, move |rows: Range<usize>| {
            for i in rows {
                let cur = &src[i * n..(i + 1) * n];
                // SAFETY: row `i` belongs to exactly one block (the
                // blocks partition `0..m`), and `out` outlives the
                // joined loop.
                let out_row =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
                if i == 0 || i == m - 1 {
                    out_row.copy_from_slice(cur);
                    continue;
                }
                let up = &src[(i - 1) * n..i * n];
                let down = &src[(i + 1) * n..(i + 2) * n];
                stencil_row(up, cur, down, out_row);
            }
        })
    }
}

impl std::fmt::Display for HostTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "HostTensor{:?} (sum={:.4})", self.shape, self.sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let z = HostTensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert!(z.data.iter().all(|&x| x == 0.0));
        let f = HostTensor::full(&[2], 3.5);
        assert_eq!(f.data, vec![3.5, 3.5]);
        let g = HostTensor::from_fn(&[3], |i| i as f32);
        assert_eq!(g.data, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = HostTensor::random(&[10, 10], 5);
        let b = HostTensor::random(&[10, 10], 5);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&x| (-1.0..1.0).contains(&x)));
        assert_ne!(a, HostTensor::random(&[10, 10], 6));
    }

    #[test]
    #[should_panic(expected = "does not match shape")]
    fn from_vec_checks_len() {
        HostTensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = HostTensor::random(&[4, 4], 1);
        let eye = HostTensor::from_fn(&[4, 4], |i| if i / 4 == i % 4 { 1.0 } else { 0.0 });
        assert!(a.matmul_ref(&eye).allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known() {
        let a = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_ref(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
        assert_eq!(c.at2(1, 0), 7.0);
    }

    #[test]
    fn allclose_and_diff() {
        let a = HostTensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = HostTensor::from_vec(&[2], vec![1.0 + 1e-7, 2.0 - 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        assert!(a.max_abs_diff(&b) < 1e-6);
        let c = HostTensor::from_vec(&[2], vec![1.1, 2.0]);
        assert!(!c.allclose(&a, 1e-5, 1e-6));
    }

    #[test]
    fn add_ref_works() {
        let a = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        assert_eq!(a.add_ref(&b).data, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn blocked_matmul_matches_reference() {
        // Odd sizes exercise the partial-tile edges.
        for &(m, k, n) in &[(1, 1, 1), (7, 5, 9), (64, 64, 64), (65, 33, 70), (128, 96, 100)] {
            let a = HostTensor::random(&[m, k], 11);
            let b = HostTensor::random(&[k, n], 13);
            let oracle = a.matmul_ref(&b);
            assert!(a.matmul_blocked(&b).allclose(&oracle, 1e-4, 1e-5), "{m}x{k}x{n}");
            for tile in [8, 16, 37] {
                assert!(
                    a.matmul_blocked_tiled(&b, tile).allclose(&oracle, 1e-4, 1e-5),
                    "{m}x{k}x{n} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn blocked_matmul_into_and_acc_reuse_buffers() {
        let a = HostTensor::random(&[33, 17], 3);
        let b = HostTensor::random(&[17, 29], 4);
        let oracle = a.matmul_ref(&b);
        let mut out = HostTensor::full(&[33, 29], 42.0); // stale contents must be cleared
        a.matmul_blocked_into(&b, &mut out);
        assert!(out.allclose(&oracle, 1e-4, 1e-5));
        // The accumulating form adds on top: running it once more on
        // the same buffer doubles the result.
        a.matmul_blocked_acc(&b, &mut out, MATMUL_TILE);
        let doubled = HostTensor::from_fn(&[33, 29], |i| 2.0 * oracle.data[i]);
        assert!(out.allclose(&doubled, 1e-4, 1e-4));
    }

    #[test]
    fn parallel_matmul_matches_blocked_bit_exactly() {
        let pool = ThreadPool::new(4);
        for &(m, k, n) in &[(5, 64, 31), (64, 64, 64), (130, 50, 71)] {
            let a = HostTensor::random(&[m, k], 21);
            let b = HostTensor::random(&[k, n], 22);
            let serial = a.matmul_blocked(&b);
            let par = a.matmul_blocked_par(&b, &pool).unwrap();
            assert_eq!(par.data, serial.data, "{m}x{k}x{n}");
            assert!(par.allclose(&a.matmul_ref(&b), 1e-4, 1e-5));
        }
    }

    #[test]
    fn stencil_serial_and_parallel_agree() {
        let pool = ThreadPool::new(4);
        for &(m, n) in &[(1, 1), (2, 2), (3, 7), (64, 64), (65, 129)] {
            let grid = HostTensor::random(&[m, n], 7);
            let serial = grid.stencil_step();
            // Boundaries copy through.
            assert_eq!(serial.data[..n], grid.data[..n]);
            let mut par = HostTensor::zeros(&[m, n]);
            grid.stencil_step_par(&pool, &mut par).unwrap();
            assert_eq!(par.data, serial.data, "{m}x{n}");
        }
        // A uniform field is a fixed point of the averaging step.
        let flat = HostTensor::full(&[8, 8], 1.5);
        assert!(flat.stencil_step().allclose(&flat, 0.0, 1e-6));
    }
}
