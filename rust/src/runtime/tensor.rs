//! Host-side tensors: the boundary type between the coordinator and
//! the PJRT executables, plus reference math for end-to-end checks.

use crate::util::Pcg32;

/// A dense row-major `f32` tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major data; `len == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// A tensor filled with zeros.
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// A tensor filled with `value`.
    pub fn full(shape: &[usize], value: f32) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![value; shape.iter().product()],
        }
    }

    /// Builds from a function of the flat index.
    pub fn from_fn(shape: &[usize], f: impl Fn(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: (0..n).map(f).collect(),
        }
    }

    /// Deterministic uniform values in `[-1, 1)` from a seed.
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect(),
        }
    }

    /// Wraps existing data (checks the element count).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length does not match shape {shape:?}"
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for zero-element tensors.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element at a 2-D index (panics unless rank 2).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Reference matmul `self @ rhs` (rank-2 only) — the oracle for the
    /// PJRT matmul kernels.
    pub fn matmul_ref(&self, rhs: &HostTensor) -> HostTensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "inner dims mismatch");
        let mut out = HostTensor::zeros(&[m, n]);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[i * n + j] += a * rhs.data[p * n + j];
                }
            }
        }
        out
    }

    /// Elementwise sum (shapes must match).
    pub fn add_ref(&self, rhs: &HostTensor) -> HostTensor {
        assert_eq!(self.shape, rhs.shape);
        HostTensor::from_vec(
            &self.shape,
            self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        )
    }

    /// Largest absolute elementwise difference.
    pub fn max_abs_diff(&self, rhs: &HostTensor) -> f32 {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// True if all elements are within `atol + rtol * |expected|`.
    pub fn allclose(&self, expected: &HostTensor, rtol: f32, atol: f32) -> bool {
        if self.shape != expected.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&expected.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Sum of all elements (for cheap end-to-end checksums).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }
}

impl std::fmt::Display for HostTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "HostTensor{:?} (sum={:.4})", self.shape, self.sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let z = HostTensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert!(z.data.iter().all(|&x| x == 0.0));
        let f = HostTensor::full(&[2], 3.5);
        assert_eq!(f.data, vec![3.5, 3.5]);
        let g = HostTensor::from_fn(&[3], |i| i as f32);
        assert_eq!(g.data, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = HostTensor::random(&[10, 10], 5);
        let b = HostTensor::random(&[10, 10], 5);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&x| (-1.0..1.0).contains(&x)));
        assert_ne!(a, HostTensor::random(&[10, 10], 6));
    }

    #[test]
    #[should_panic(expected = "does not match shape")]
    fn from_vec_checks_len() {
        HostTensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = HostTensor::random(&[4, 4], 1);
        let eye = HostTensor::from_fn(&[4, 4], |i| if i / 4 == i % 4 { 1.0 } else { 0.0 });
        assert!(a.matmul_ref(&eye).allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known() {
        let a = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_ref(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
        assert_eq!(c.at2(1, 0), 7.0);
    }

    #[test]
    fn allclose_and_diff() {
        let a = HostTensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = HostTensor::from_vec(&[2], vec![1.0 + 1e-7, 2.0 - 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        assert!(a.max_abs_diff(&b) < 1e-6);
        let c = HostTensor::from_vec(&[2], vec![1.1, 2.0]);
        assert!(!c.allclose(&a, 1e-5, 1e-6));
    }

    #[test]
    fn add_ref_works() {
        let a = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        assert_eq!(a.add_ref(&b).data, vec![11.0, 22.0, 33.0]);
    }
}
