//! Comparator executors for the paper's benchmarks (§3).
//!
//! The paper benchmarks its pool against Taskflow. The authors'
//! testbed and the C++ library are not available here, so we implement
//! the comparators in-crate (see DESIGN.md §5 Substitutions):
//!
//! * [`TaskflowLike`] — a work-stealing executor built on the
//!   *fence-based* Chase–Lev deque plus a bounded steal loop, the
//!   algorithmic core of Taskflow's executor. This is the stand-in for
//!   the paper's Taskflow series in Fig. 1/Fig. 2.
//! * [`MutexPool`] — the classic single-queue pool every work-stealing
//!   paper implicitly compares against: one mutex-protected FIFO, one
//!   condvar.
//! * [`SpawnPool`] — thread-per-task, the §1 anti-pattern (creation/
//!   destruction overhead), included to reproduce the motivation.
//!
//! All executors (including [`crate::pool::ThreadPool`]) are unified
//! behind the object-safe [`Executor`] trait so benches can sweep them.

mod mutex_pool;
mod spawn_pool;
mod taskflow_like;

pub use mutex_pool::MutexPool;
pub use spawn_pool::SpawnPool;
pub use taskflow_like::TaskflowLike;

use std::sync::Arc;

/// Object-safe common interface over all executors.
pub trait Executor: Send + Sync + 'static {
    /// Submits a boxed task.
    fn submit_boxed(&self, f: Box<dyn FnOnce() + Send + 'static>);
    /// Blocks until all submitted work (transitively) has finished.
    fn wait_idle(&self);
    /// Short display name for benchmark tables.
    fn name(&self) -> &'static str;
    /// Worker count (1 for SpawnPool: conceptually unbounded).
    fn num_threads(&self) -> usize;
}

/// Convenience: generic submit over any `Arc<dyn Executor>`.
pub fn submit<F: FnOnce() + Send + 'static>(ex: &Arc<dyn Executor>, f: F) {
    ex.submit_boxed(Box::new(f));
}

impl Executor for crate::pool::ThreadPool {
    fn submit_boxed(&self, f: Box<dyn FnOnce() + Send + 'static>) {
        self.submit(f);
    }

    fn wait_idle(&self) {
        crate::pool::ThreadPool::wait_idle(self);
    }

    fn name(&self) -> &'static str {
        "scheduling"
    }

    fn num_threads(&self) -> usize {
        crate::pool::ThreadPool::num_threads(self)
    }
}

/// Builds every executor at a given thread count, in the order used by
/// the benchmark tables: ours, taskflow-proxy, mutex queue, spawn.
pub fn all_executors(num_threads: usize) -> Vec<Arc<dyn Executor>> {
    vec![
        Arc::new(crate::pool::ThreadPool::new(num_threads)),
        Arc::new(TaskflowLike::new(num_threads)),
        Arc::new(MutexPool::new(num_threads)),
        Arc::new(SpawnPool::new()),
    ]
}

/// Builds an executor by name (CLI: `--executor scheduling|taskflow|mutex|spawn`).
pub fn executor_by_name(name: &str, num_threads: usize) -> Option<Arc<dyn Executor>> {
    match name {
        "scheduling" => Some(Arc::new(crate::pool::ThreadPool::new(num_threads))),
        "taskflow" | "taskflow-like" => Some(Arc::new(TaskflowLike::new(num_threads))),
        "mutex" | "mutex-pool" => Some(Arc::new(MutexPool::new(num_threads))),
        "spawn" | "spawn-per-task" => Some(Arc::new(SpawnPool::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn smoke(ex: Arc<dyn Executor>) {
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let count = count.clone();
            submit(&ex, move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        ex.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 64, "{}", ex.name());
    }

    #[test]
    fn every_executor_runs_tasks() {
        for ex in all_executors(2) {
            smoke(ex);
        }
    }

    #[test]
    fn executor_by_name_resolves() {
        for name in ["scheduling", "taskflow", "mutex", "spawn"] {
            assert!(executor_by_name(name, 1).is_some(), "{name}");
        }
        assert!(executor_by_name("nope", 1).is_none());
    }

    #[test]
    fn recursive_submission_through_trait() {
        for ex in all_executors(2) {
            let count = Arc::new(AtomicUsize::new(0));
            fn fanout(ex: Arc<dyn Executor>, count: Arc<AtomicUsize>, depth: u32) {
                count.fetch_add(1, Ordering::Relaxed);
                if depth == 0 {
                    return;
                }
                for _ in 0..2 {
                    let (e, c) = (ex.clone(), count.clone());
                    let e2 = e.clone();
                    e.submit_boxed(Box::new(move || fanout(e2, c, depth - 1)));
                }
            }
            fanout(ex.clone(), count.clone(), 5);
            ex.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), (1 << 6) - 1, "{}", ex.name());
        }
    }
}
