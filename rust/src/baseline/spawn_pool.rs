//! Thread-per-task "pool" — the §1 anti-pattern, for the motivation
//! row of the benchmark tables.
//!
//! Every submit spawns (and eventually joins) an OS thread. The paper's
//! introduction names exactly the two failure modes this exhibits:
//! context-switch pressure when thread count exceeds the hardware, and
//! per-task creation/destruction overhead. Benches cap its workload
//! sizes so the suite still finishes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

struct Shared {
    active: AtomicUsize,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
}

/// See module docs.
pub struct SpawnPool {
    shared: Arc<Shared>,
}

impl Default for SpawnPool {
    fn default() -> Self {
        Self::new()
    }
}

impl SpawnPool {
    /// Creates the pool (no threads are kept around).
    pub fn new() -> Self {
        Self {
            shared: Arc::new(Shared {
                active: AtomicUsize::new(0),
                idle_mutex: Mutex::new(()),
                idle_cv: Condvar::new(),
            }),
        }
    }

    /// Spawns a detached thread for `f`. Under spawn storms the OS can
    /// transiently refuse new threads (EAGAIN) — exactly the §1
    /// failure mode this baseline exists to demonstrate — so refusal
    /// is retried with backoff rather than panicking the benchmark.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let shared = self.shared.clone();
        shared.active.fetch_add(1, Ordering::SeqCst);
        // The body lives in an Arc so a failed spawn (which consumes
        // its shim closure) leaves it intact for the retry.
        let body = Arc::new(Mutex::new(Some(move || {
            let _ = catch_unwind(AssertUnwindSafe(f));
            if shared.active.fetch_sub(1, Ordering::SeqCst) == 1 {
                drop(shared.idle_mutex.lock().unwrap());
                shared.idle_cv.notify_all();
            }
        })));
        let mut backoff_us = 50u64;
        loop {
            let b = body.clone();
            let shim = move || {
                if let Some(f) = b.lock().unwrap().take() {
                    f();
                }
            };
            match std::thread::Builder::new().spawn(shim) {
                Ok(_) => return,
                Err(_) if backoff_us < 2_000_000 => {
                    // Thread creation refused; wait for some threads to
                    // retire and retry (this is the measured overhead).
                    std::thread::sleep(std::time::Duration::from_micros(backoff_us));
                    backoff_us *= 2;
                }
                Err(e) => panic!("thread spawn failed permanently: {e}"),
            }
        }
    }

    /// Blocks until all spawned threads have finished.
    pub fn wait_idle(&self) {
        let mut g = self.shared.idle_mutex.lock().unwrap();
        while self.shared.active.load(Ordering::SeqCst) != 0 {
            g = self.shared.idle_cv.wait(g).unwrap();
        }
    }
}

impl Drop for SpawnPool {
    fn drop(&mut self) {
        self.wait_idle();
    }
}

impl super::Executor for SpawnPool {
    fn submit_boxed(&self, f: Box<dyn FnOnce() + Send + 'static>) {
        self.submit(f);
    }

    fn wait_idle(&self) {
        SpawnPool::wait_idle(self);
    }

    fn name(&self) -> &'static str {
        "spawn-per-task"
    }

    fn num_threads(&self) -> usize {
        1 // conceptually unbounded; reported as 1 for table layout
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_tasks_and_waits() {
        let pool = SpawnPool::new();
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn nested_spawns_counted() {
        let pool = Arc::new(SpawnPool::new());
        let count = Arc::new(AtomicUsize::new(0));
        let (p, c) = (pool.clone(), count.clone());
        pool.submit(move || {
            for _ in 0..4 {
                let c2 = c.clone();
                p.submit(move || {
                    c2.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }
}
