//! Centralized single-queue thread pool — the classic baseline.
//!
//! One `Mutex<VecDeque>` shared by all workers, one condvar. Every
//! submit and every dequeue serializes on the same lock, so throughput
//! collapses as task granularity shrinks — the contention problem that
//! motivates per-worker deques (paper §2.1). Appears in Fig. 1/Fig. 2
//! reproductions as the "mutex-pool" series.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    /// Submitted-but-unfinished count, for `wait_idle`.
    pending: AtomicUsize,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
    shutdown: AtomicBool,
}

/// See module docs.
pub struct MutexPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl MutexPool {
    /// Creates a pool with `num_threads` workers (clamped to >= 1).
    pub fn new(num_threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            pending: AtomicUsize::new(0),
            idle_mutex: Mutex::new(()),
            idle_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let threads = (0..num_threads.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mutex-pool-{i}"))
                    .spawn(move || worker(shared))
                    .expect("spawn failed")
            })
            .collect();
        Self { shared, threads }
    }

    /// Submits a task.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.available.notify_one();
    }

    /// Blocks until all submitted work has finished.
    pub fn wait_idle(&self) {
        let mut g = self.shared.idle_mutex.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            g = self.shared.idle_cv.wait(g).unwrap();
        }
    }
}

fn worker(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        let Some(task) = task else { return };
        let _ = catch_unwind(AssertUnwindSafe(task));
        if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            drop(shared.idle_mutex.lock().unwrap());
            shared.idle_cv.notify_all();
        }
    }
}

impl Drop for MutexPool {
    fn drop(&mut self) {
        // Drain: workers exit only once the queue is empty AND shutdown
        // is set (the pop check precedes the shutdown check).
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl super::Executor for MutexPool {
    fn submit_boxed(&self, f: Box<dyn FnOnce() + Send + 'static>) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(f);
        self.shared.available.notify_one();
    }

    fn wait_idle(&self) {
        MutexPool::wait_idle(self);
    }

    fn name(&self) -> &'static str {
        "mutex-pool"
    }

    fn num_threads(&self) -> usize {
        self.threads.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_tasks() {
        let pool = MutexPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_drains() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = MutexPool::new(2);
            for _ in 0..32 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn panic_contained() {
        let pool = MutexPool::new(1);
        pool.submit(|| panic!("x"));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }
}
