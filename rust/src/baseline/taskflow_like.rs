//! Taskflow-proxy executor: the paper's comparator, reimplemented.
//!
//! Taskflow's executor is itself a Chase–Lev work-stealer; what the
//! paper's Fig. 1/Fig. 2 compare is two *flavors* of the same family.
//! This stand-in reproduces the algorithmically relevant differences
//! of Taskflow's executor so the comparison isolates them:
//!
//! * the **fence-based** Chase–Lev deque (`atomic_thread_fence` style,
//!   [`crate::pool::fence_deque`]) — the exact code the paper quotes;
//! * a **bounded steal loop** (`MAX_STEALS = 2 * (N + 1)` attempts with
//!   `yield_now` between rounds, like Taskflow's
//!   `executor.hpp` waiter loop) instead of our retry-informed sweep;
//! * thread-id → worker lookup via a shared registration map (the
//!   "typical approach" the paper contrasts with its thread-local
//!   trick, §2.1) — each submit from a worker thread pays a hash
//!   lookup.
//!
//! Everything else (eventcount parking, injector, drain-on-drop) is
//! shared infrastructure, so measured deltas come from the above.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::{JoinHandle, ThreadId};

use crate::pool::event_count::EventCount;
use crate::pool::fence_deque::{fence_deque, FenceStealer, FenceWorker};
use crate::pool::injector::{Injector, MutexInjector};
use crate::pool::Steal;
use crate::util::XorShift64Star;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    injector: MutexInjector<Task>,
    stealers: Vec<FenceStealer<Task>>,
    /// Thread-id → worker-index map: the lookup-based alternative to
    /// the paper's thread-local registration.
    registry: RwLock<HashMap<ThreadId, usize>>,
    ec: EventCount,
    pending: AtomicUsize,
    shutdown: AtomicBool,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
}

/// See module docs.
pub struct TaskflowLike {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    /// Per-worker queue handles, owned by the worker threads via Arc
    /// indirection (the map approach needs them reachable from submit).
    locals: Vec<Arc<LocalQueue>>,
}

/// The owner side of a worker's deque, shared so that `submit` (after a
/// registry lookup) can push to it from the owning thread.
struct LocalQueue {
    worker: FenceWorker<Task>,
}

// SAFETY: `worker` is only pushed/popped from its owning thread — the
// registry maps exactly that thread's id to this slot, and `submit`
// only uses the slot when called *on* that thread.
unsafe impl Send for LocalQueue {}
unsafe impl Sync for LocalQueue {}

impl TaskflowLike {
    /// Creates an executor with `num_threads` workers (clamped >= 1).
    pub fn new(num_threads: usize) -> Self {
        let n = num_threads.max(1);
        let mut locals = Vec::with_capacity(n);
        let mut stealers = Vec::with_capacity(n);
        for _ in 0..n {
            let (w, s) = fence_deque::<Task>(256);
            locals.push(Arc::new(LocalQueue { worker: w }));
            stealers.push(s);
        }
        let shared = Arc::new(Shared {
            injector: MutexInjector::new(),
            stealers,
            registry: RwLock::new(HashMap::new()),
            ec: EventCount::new(),
            pending: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            idle_mutex: Mutex::new(()),
            idle_cv: Condvar::new(),
        });
        let threads = locals
            .iter()
            .enumerate()
            .map(|(index, local)| {
                let shared = shared.clone();
                let local = local.clone();
                std::thread::Builder::new()
                    .name(format!("taskflow-like-{index}"))
                    .spawn(move || {
                        shared
                            .registry
                            .write()
                            .unwrap()
                            .insert(std::thread::current().id(), index);
                        worker_loop(shared, index, local);
                    })
                    .expect("spawn failed")
            })
            .collect();
        Self {
            shared,
            threads,
            locals,
        }
    }

    /// Submits a task: registry lookup first (a worker pushes to its
    /// own deque), injector otherwise.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_task(Box::new(f));
    }

    fn submit_task(&self, task: Task) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        let tid = std::thread::current().id();
        let idx = self.shared.registry.read().unwrap().get(&tid).copied();
        match idx {
            Some(i) => self.locals[i].worker.push(task),
            None => self.shared.injector.push(task),
        }
        self.shared.ec.notify_one();
    }

    /// Blocks until all submitted work has finished.
    pub fn wait_idle(&self) {
        let mut g = self.shared.idle_mutex.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            g = self.shared.idle_cv.wait(g).unwrap();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize, local: Arc<LocalQueue>) {
    let mut rng = XorShift64Star::from_entropy();
    let n = shared.stealers.len();
    let max_steals = 2 * (n + 1); // Taskflow's MAX_STEALS heuristic

    let find_task = |rng: &mut XorShift64Star| -> Option<Task> {
        if let Some(t) = local.worker.pop() {
            return Some(t);
        }
        if let Some(t) = shared.injector.pop() {
            return Some(t);
        }
        let mut attempts = 0;
        while attempts < max_steals {
            let victim = rng.next_below(n);
            if victim != index {
                match shared.stealers[victim].steal() {
                    Steal::Success(t) => return Some(t),
                    Steal::Retry => {}
                    Steal::Empty => {}
                }
            }
            attempts += 1;
            if attempts % (n + 1) == 0 {
                std::thread::yield_now();
            }
        }
        None
    };

    loop {
        while let Some(task) = find_task(&mut rng) {
            let _ = catch_unwind(AssertUnwindSafe(task));
            if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                drop(shared.idle_mutex.lock().unwrap());
                shared.idle_cv.notify_all();
            }
        }
        let token = shared.ec.prepare_wait();
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.ec.cancel_wait(token);
            while let Some(task) = find_task(&mut rng) {
                let _ = catch_unwind(AssertUnwindSafe(task));
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    drop(shared.idle_mutex.lock().unwrap());
                    shared.idle_cv.notify_all();
                }
            }
            return;
        }
        if !shared.injector.is_empty() || shared.stealers.iter().any(|s| !s.is_empty()) {
            shared.ec.cancel_wait(token);
            continue;
        }
        shared.ec.commit_wait(token);
    }
}

impl Drop for TaskflowLike {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.ec.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl super::Executor for TaskflowLike {
    fn submit_boxed(&self, f: Box<dyn FnOnce() + Send + 'static>) {
        self.submit_task(f);
    }

    fn wait_idle(&self) {
        TaskflowLike::wait_idle(self);
    }

    fn name(&self) -> &'static str {
        "taskflow-like"
    }

    fn num_threads(&self) -> usize {
        self.locals.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_tasks() {
        let ex = TaskflowLike::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..200 {
            let c = count.clone();
            ex.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        ex.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn worker_submit_goes_to_local_deque() {
        let ex = Arc::new(TaskflowLike::new(1));
        let done = Arc::new(AtomicUsize::new(0));
        let (e, d) = (ex.clone(), done.clone());
        ex.submit(move || {
            let d2 = d.clone();
            e.submit(move || {
                d2.fetch_add(1, Ordering::Relaxed);
            });
        });
        ex.wait_idle();
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_drains() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let ex = TaskflowLike::new(2);
            for _ in 0..64 {
                let c = count.clone();
                ex.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }
}
