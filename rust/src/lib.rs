//! # scheduling
//!
//! A simple and fast Rust thread pool capable of running task graphs —
//! a from-scratch reproduction of Puyda, *"A simple and fast C++ thread
//! pool implementation capable of running task graphs"* (2024), extended
//! with an AOT-compiled JAX/Pallas compute runtime (PJRT) so task-graph
//! nodes can execute real tensor kernels with no Python on the request
//! path.
//!
//! ## Layout
//!
//! * [`pool`] — the work-stealing thread pool (Chase–Lev deques,
//!   thread-local worker registration, eventcount parking).
//! * [`graph`] — task graphs: successor lists + atomic predecessor
//!   counters, inline continuation of the first ready successor.
//! * [`baseline`] — comparator executors (centralized mutex queue,
//!   thread-per-task, Taskflow-like fence-based work stealer).
//! * [`serve`] — graph-as-a-service front-end: tenant-fair DRR
//!   admission, budgeted retry with backoff, and brownout shedding.
//! * [`obs`] — observability: per-worker flight-recorder rings,
//!   log-bucketed atomic histograms, post-run scheduling profiles,
//!   and Prometheus text exposition.
//! * [`runtime`] — PJRT client + artifact registry for AOT-compiled
//!   HLO produced by `python/compile/aot.py`.
//! * [`workloads`] — benchmark workload generators (fibonacci, linear
//!   chain, binary tree, graph traversal, wavefront, blocked matmul).
//! * [`bench_harness`] — wall/CPU-time measurement and statistics.
//! * [`cli`] — argument parsing and config for the launcher binary.
//!
//! ## Quickstart
//!
//! ```
//! use scheduling::pool::ThreadPool;
//! use std::sync::atomic::{AtomicUsize, Ordering};
//! use std::sync::Arc;
//!
//! let pool = ThreadPool::new(2);
//! let hits = Arc::new(AtomicUsize::new(0));
//! for _ in 0..16 {
//!     let hits = hits.clone();
//!     pool.submit(move || { hits.fetch_add(1, Ordering::Relaxed); });
//! }
//! pool.wait_idle();
//! assert_eq!(hits.load(Ordering::Relaxed), 16);
//! ```

#![warn(missing_docs)]

pub mod baseline;
pub mod bench_harness;
pub mod cli;
pub mod graph;
pub mod obs;
pub mod pool;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workloads;
