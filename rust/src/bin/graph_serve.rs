//! `graph_serve` — standalone TCP serving front-end (PR 8).
//!
//! Wraps [`scheduling::serve::WireServer`] into a process: a server
//! mode hosting a fixed demo tenant/template registry, plus `client`
//! and `scrape` subcommands speaking the wire protocol, so the CI
//! smoke step and `benches/serving.rs` `WIRE=1` mode can exercise the
//! full cross-process path with nothing but this binary.
//!
//! ```text
//! graph_serve serve    [--addr A] [--metrics-addr A] [--threads N]
//!                      [--max-inflight N] [--work-steps N]
//! graph_serve client   --addr A [--token T] [--template NAME]
//!                      [--deadline-micros D] [--count N]
//! graph_serve scrape   --addr A [--v2]
//! graph_serve dump     --addr A [--out FILE]
//! graph_serve validate --addr A
//! ```
//!
//! `scrape --v2` fetches the STATS v2 frame (exposition + quantile
//! summary gauges), `dump` fetches the server's flight recorder as
//! Chrome-trace JSON (PR 9), and `validate` strictly checks both the
//! STATS and STATS v2 expositions with
//! [`scheduling::obs::validate`] — the CI smoke step runs it
//! cross-process so a malformed exposition fails the build.
//!
//! The server registers tenants `gold` (weight 4, High), `silver`
//! (weight 2, Normal), and `storm` (weight 1, Low) — token = name —
//! and templates `diamond4`, `diamond16`, `chain64`, `wavefront8`.

use std::process;
use std::time::{Duration, Instant};

use scheduling::graph::RunPriority;
use scheduling::pool::ThreadPool;
use scheduling::serve::{
    wire_scrape, GraphService, ServiceConfig, TenantSpec, WireClient, WireServer, WireStatus,
};
use scheduling::workloads::Dag;
use std::sync::Arc;

const USAGE: &str = "usage:
  graph_serve serve    [--addr A] [--metrics-addr A] [--threads N] [--max-inflight N] [--work-steps N]
  graph_serve client   --addr A [--token T] [--template NAME] [--deadline-micros D] [--count N]
  graph_serve scrape   --addr A [--v2]
  graph_serve dump     --addr A [--out FILE]
  graph_serve validate --addr A";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("serve") => serve(&args[1..]),
        Some("client") => client(&args[1..]),
        Some("scrape") => scrape(&args[1..]),
        Some("dump") => dump(&args[1..]),
        Some("validate") => validate(&args[1..]),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    process::exit(code);
}

/// Looks up `--name value` in `args`; exits with usage on a flag
/// missing its value.
fn flag(args: &[String], name: &str) -> Option<String> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == name {
            match it.next() {
                Some(v) => return Some(v.clone()),
                None => {
                    eprintln!("{name} needs a value\n{USAGE}");
                    process::exit(2);
                }
            }
        }
    }
    None
}

fn parse<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> T {
    match flag(args, name) {
        None => default,
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad value for {name}: {v:?}\n{USAGE}");
            process::exit(2);
        }),
    }
}

fn serve(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or_else(|| "127.0.0.1:7450".to_string());
    let metrics_addr = flag(args, "--metrics-addr").unwrap_or_else(|| "127.0.0.1:7451".to_string());
    let default_threads = std::thread::available_parallelism().map_or(4, |n| n.get());
    let threads = parse(args, "--threads", default_threads);
    let max_inflight = parse(args, "--max-inflight", 32usize);
    let work_steps = parse(args, "--work-steps", 256u32);

    let svc = Arc::new(GraphService::new(
        ThreadPool::new(threads),
        ServiceConfig { max_inflight, ..ServiceConfig::default() },
    ));
    let gold = svc.register_tenant(TenantSpec::new("gold").weight(4).class(RunPriority::High));
    let silver = svc.register_tenant(TenantSpec::new("silver").weight(2));
    let storm = svc.register_tenant(TenantSpec::new("storm").weight(1).class(RunPriority::Low));

    let handle = WireServer::new(svc)
        .tenant("gold", gold)
        .tenant("silver", silver)
        .tenant("storm", storm)
        .template("diamond4", move || Dag::diamond_chain(4).to_task_graph(work_steps).0)
        .template("diamond16", move || Dag::diamond_chain(16).to_task_graph(work_steps).0)
        .template("chain64", move || Dag::linear_chain(64).to_task_graph(work_steps).0)
        .template("wavefront8", move || Dag::wavefront(8).to_task_graph(work_steps).0)
        .serve_with_metrics(&addr, &metrics_addr);
    let handle = match handle {
        Ok(h) => h,
        Err(e) => {
            eprintln!("graph_serve: bind failed: {e}");
            return 1;
        }
    };
    // The readiness line the CI smoke step and the wire bench wait for.
    println!("graph_serve listening on {} (metrics on {})", handle.frame_addr(), handle.metrics_addr().unwrap());
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn client(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--addr") else {
        eprintln!("client needs --addr\n{USAGE}");
        return 2;
    };
    let token = flag(args, "--token").unwrap_or_else(|| "gold".to_string());
    let template = flag(args, "--template").unwrap_or_else(|| "diamond4".to_string());
    let deadline_micros = parse(args, "--deadline-micros", 0u64);
    let deadline = (deadline_micros > 0).then(|| Duration::from_micros(deadline_micros));
    let count = parse(args, "--count", 1usize);

    let mut conn = match WireClient::connect(addr.as_str()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("graph_serve client: connect {addr}: {e}");
            return 1;
        }
    };
    let mut failures = 0usize;
    for i in 1..=count {
        let start = Instant::now();
        match conn.run(&token, &template, deadline) {
            Ok((WireStatus::Ok, _)) => {
                println!("run {i}/{count}: Ok ({:.1}us)", start.elapsed().as_secs_f64() * 1e6);
            }
            Ok((status, msg)) => {
                println!("run {i}/{count}: {status:?} ({msg})");
                failures += 1;
            }
            Err(e) => {
                eprintln!("run {i}/{count}: transport error: {e}");
                failures += 1;
            }
        }
    }
    i32::from(failures > 0)
}

fn scrape(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--addr") else {
        eprintln!("scrape needs --addr\n{USAGE}");
        return 2;
    };
    let v2 = args.iter().any(|a| a == "--v2");
    let body = if v2 {
        WireClient::connect(addr.as_str()).and_then(|mut c| c.scrape_v2())
    } else {
        wire_scrape(addr.as_str())
    };
    match body {
        Ok(body) => {
            print!("{body}");
            0
        }
        Err(e) => {
            eprintln!("graph_serve scrape: {addr}: {e}");
            1
        }
    }
}

/// Fetches the server's flight recorder as Chrome-trace JSON and
/// prints it (or writes `--out FILE` for loading into Perfetto /
/// `chrome://tracing`).
fn dump(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--addr") else {
        eprintln!("dump needs --addr\n{USAGE}");
        return 2;
    };
    let json = match WireClient::connect(addr.as_str()).and_then(|mut c| c.dump()) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("graph_serve dump: {addr}: {e}");
            return 1;
        }
    };
    match flag(args, "--out") {
        None => {
            println!("{json}");
            0
        }
        Some(path) => match std::fs::write(&path, &json) {
            Ok(()) => {
                eprintln!("graph_serve dump: wrote {} bytes to {path}", json.len());
                0
            }
            Err(e) => {
                eprintln!("graph_serve dump: write {path}: {e}");
                1
            }
        },
    }
}

/// Scrapes both STATS and STATS v2 over the frame protocol and runs
/// the strict exposition validator on each — exit 0 only when both
/// parse cleanly.
fn validate(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--addr") else {
        eprintln!("validate needs --addr\n{USAGE}");
        return 2;
    };
    let mut conn = match WireClient::connect(addr.as_str()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("graph_serve validate: connect {addr}: {e}");
            return 1;
        }
    };
    let mut code = 0;
    for (name, body) in [("STATS", conn.scrape()), ("STATS2", conn.scrape_v2())] {
        match body {
            Ok(text) => match scheduling::obs::validate(&text) {
                Ok(()) => println!("{name}: valid exposition ({} lines)", text.lines().count()),
                Err(e) => {
                    eprintln!("{name}: INVALID exposition: {e}");
                    code = 1;
                }
            },
            Err(e) => {
                eprintln!("{name}: transport error: {e}");
                code = 1;
            }
        }
    }
    code
}
