//! Measurement loops: wall clock and process CPU time.

use std::time::{Duration, Instant};

use super::stats::Summary;
use crate::util::process_cpu_time;

/// What a benchmark run measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Measurement {
    /// Elapsed wall-clock time per iteration (paper Fig. 1).
    Wall,
    /// Process CPU time (user+sys, all threads) per iteration
    /// (paper Fig. 2). Resolution 10 ms — iterations are batched until
    /// each sample spans at least [`BenchOptions::min_sample_time`].
    Cpu,
}

/// Knobs for a measurement.
#[derive(Debug, Clone, Copy)]
pub struct BenchOptions {
    /// Warmup iterations (not recorded).
    pub warmup_iters: u32,
    /// Recorded samples.
    pub samples: u32,
    /// Minimum time one sample should span; the harness batches
    /// multiple iterations into one sample to reach it (essential for
    /// CPU time with its 10 ms granularity).
    pub min_sample_time: Duration,
}

impl Default for BenchOptions {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            samples: 10,
            min_sample_time: Duration::from_millis(50),
        }
    }
}

impl BenchOptions {
    /// Fast profile for CI / smoke runs (`BENCH_FAST=1`).
    pub fn fast() -> Self {
        Self {
            warmup_iters: 1,
            samples: 3,
            min_sample_time: Duration::from_millis(20),
        }
    }

    /// Reads `BENCH_FAST` from the environment.
    pub fn from_env() -> Self {
        if std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
            Self::fast()
        } else {
            Self::default()
        }
    }
}

/// Calibrates how many iterations of `f` are needed to span
/// `min_sample_time`, then records `samples` batched samples and
/// reports the per-iteration wall time.
pub fn bench_wall<F: FnMut()>(options: &BenchOptions, mut f: F) -> Summary {
    for _ in 0..options.warmup_iters {
        f();
    }
    let batch = calibrate(options, &mut f);
    let mut samples = Vec::with_capacity(options.samples as usize);
    for _ in 0..options.samples {
        let start = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(start.elapsed() / batch);
    }
    Summary::from_samples(&samples)
}

/// Like [`bench_wall`] but reads process CPU time around each batch.
pub fn bench_cpu<F: FnMut()>(options: &BenchOptions, mut f: F) -> Summary {
    for _ in 0..options.warmup_iters {
        f();
    }
    let batch = calibrate(options, &mut f);
    let mut samples = Vec::with_capacity(options.samples as usize);
    for _ in 0..options.samples {
        let start = process_cpu_time();
        for _ in 0..batch {
            f();
        }
        let spent = process_cpu_time().saturating_sub(start);
        samples.push(spent / batch);
    }
    Summary::from_samples(&samples)
}

fn calibrate<F: FnMut()>(options: &BenchOptions, f: &mut F) -> u32 {
    // Double the batch until one batch spans min_sample_time.
    let mut batch = 1u32;
    loop {
        let start = Instant::now();
        for _ in 0..batch {
            f();
        }
        let took = start.elapsed();
        if took >= options.min_sample_time || batch >= 1 << 20 {
            return batch;
        }
        // Jump close to the target, at least doubling, capped at 2^20.
        let factor = (options.min_sample_time.as_secs_f64() / took.as_secs_f64().max(1e-9)).ceil();
        batch = batch
            .saturating_mul(factor.clamp(2.0, 64.0) as u32)
            .min(1 << 20);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_measures_sleep() {
        let opts = BenchOptions {
            warmup_iters: 0,
            samples: 3,
            min_sample_time: Duration::from_millis(5),
        };
        let s = bench_wall(&opts, || std::thread::sleep(Duration::from_millis(2)));
        assert!(s.mean >= Duration::from_millis(1), "mean={:?}", s.mean);
        assert!(s.mean < Duration::from_millis(50));
    }

    #[test]
    fn cpu_of_sleep_is_tiny_vs_spin() {
        let opts = BenchOptions {
            warmup_iters: 0,
            samples: 2,
            min_sample_time: Duration::from_millis(30),
        };
        let spin = bench_cpu(&opts, || {
            let start = Instant::now();
            let mut x = 0u64;
            while start.elapsed() < Duration::from_millis(5) {
                x = x.wrapping_add(1);
            }
            std::hint::black_box(x);
        });
        // Spinning for 5ms should cost ~5ms of CPU per iteration.
        assert!(
            spin.mean >= Duration::from_millis(2),
            "spin cpu mean {:?}",
            spin.mean
        );
    }

    #[test]
    fn calibrate_batches_fast_functions() {
        let opts = BenchOptions {
            warmup_iters: 0,
            samples: 1,
            min_sample_time: Duration::from_millis(10),
        };
        let mut count = 0u64;
        let b = calibrate(&opts, &mut || {
            count += 1;
        });
        assert!(b > 1, "trivial fn should batch, got {b}");
    }
}
