//! Benchmark report assembly: aligned tables for the terminal, CSV for
//! plotting, and paper-shape assertions recorded in EXPERIMENTS.md.

use std::time::Duration;

use super::stats::{fmt_duration, Summary};

/// One measured cell: a workload/executor combination.
#[derive(Debug, Clone)]
pub struct Row {
    /// Workload parameter rendered as text (e.g. "fib(30)").
    pub param: String,
    /// Executor / series name.
    pub series: String,
    /// Measured summary.
    pub summary: Summary,
}

/// A named collection of rows — one table or figure reproduction.
#[derive(Debug, Clone)]
pub struct Report {
    /// E.g. "FIG1 fibonacci wall time".
    pub title: String,
    /// Units note / testbed caveat printed under the title.
    pub note: String,
    /// Measured cells.
    pub rows: Vec<Row>,
}

impl Report {
    /// Creates an empty report.
    pub fn new(title: impl Into<String>, note: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            note: note.into(),
            rows: Vec::new(),
        }
    }

    /// Appends a measurement.
    pub fn push(&mut self, param: impl Into<String>, series: impl Into<String>, summary: Summary) {
        self.rows.push(Row {
            param: param.into(),
            series: series.into(),
            summary,
        });
    }

    /// Mean duration for a (param, series) cell, if present.
    pub fn mean_of(&self, param: &str, series: &str) -> Option<Duration> {
        self.rows
            .iter()
            .find(|r| r.param == param && r.series == series)
            .map(|r| r.summary.mean)
    }

    /// Speedup of `series_a` over `series_b` at `param`
    /// (times; >1 means `a` is faster).
    pub fn speedup(&self, param: &str, series_a: &str, series_b: &str) -> Option<f64> {
        let a = self.mean_of(param, series_a)?.as_secs_f64();
        let b = self.mean_of(param, series_b)?.as_secs_f64();
        if a == 0.0 {
            None
        } else {
            Some(b / a)
        }
    }

    /// Prints the aligned table followed by the CSV block (both go to
    /// stdout so `cargo bench | tee` captures everything).
    pub fn print(&self) {
        println!("{}", markdown_table(self));
        println!();
        println!("CSV {}", self.title);
        print!("{}", csv_report(self));
        println!();
    }
}

/// Renders a report as a GitHub-flavored markdown table.
pub fn markdown_table(report: &Report) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n## {}\n", report.title));
    if !report.note.is_empty() {
        out.push_str(&format!("_{}_\n", report.note));
    }
    let headers = ["param", "series", "mean", "median", "stddev", "min", "max", "samples"];
    let mut table: Vec<[String; 8]> = Vec::new();
    for r in &report.rows {
        table.push([
            r.param.clone(),
            r.series.clone(),
            fmt_duration(r.summary.mean),
            fmt_duration(r.summary.median),
            fmt_duration(r.summary.stddev),
            fmt_duration(r.summary.min),
            fmt_duration(r.summary.max),
            r.summary.n.to_string(),
        ]);
    }
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in &table {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        format!("| {} |", padded.join(" | "))
    };
    out.push_str(&fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    ));
    out.push('\n');
    for row in &table {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Renders a report as CSV (`param,series,mean_ns,median_ns,...`).
pub fn csv_report(report: &Report) -> String {
    let mut out = String::from("param,series,mean_ns,median_ns,stddev_ns,min_ns,max_ns,samples\n");
    for r in &report.rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            r.param,
            r.series,
            r.summary.mean.as_nanos(),
            r.summary.median.as_nanos(),
            r.summary.stddev.as_nanos(),
            r.summary.min.as_nanos(),
            r.summary.max.as_nanos(),
            r.summary.n
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(ms: u64) -> Summary {
        Summary::from_samples(&[Duration::from_millis(ms)])
    }

    #[test]
    fn table_contains_all_cells() {
        let mut rep = Report::new("t", "n");
        rep.push("fib(30)", "scheduling", summary(10));
        rep.push("fib(30)", "taskflow-like", summary(12));
        let t = markdown_table(&rep);
        assert!(t.contains("fib(30)"));
        assert!(t.contains("scheduling"));
        assert!(t.contains("taskflow-like"));
        assert!(t.contains("10.00 ms"));
    }

    #[test]
    fn csv_round_numbers() {
        let mut rep = Report::new("t", "");
        rep.push("p", "s", summary(1));
        let csv = csv_report(&rep);
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("p,s,1000000,"));
    }

    #[test]
    fn speedup_math() {
        let mut rep = Report::new("t", "");
        rep.push("p", "fast", summary(10));
        rep.push("p", "slow", summary(40));
        let s = rep.speedup("p", "fast", "slow").unwrap();
        assert!((s - 4.0).abs() < 1e-9);
        assert!(rep.speedup("p", "fast", "missing").is_none());
    }

    #[test]
    fn mean_of_lookup() {
        let mut rep = Report::new("t", "");
        rep.push("a", "x", summary(3));
        assert_eq!(rep.mean_of("a", "x"), Some(Duration::from_millis(3)));
        assert_eq!(rep.mean_of("a", "y"), None);
    }
}
