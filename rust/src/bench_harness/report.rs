//! Benchmark report assembly: aligned tables for the terminal, CSV for
//! plotting, paper-shape assertions recorded in EXPERIMENTS.md, and a
//! machine-readable JSON ledger ([`record_json`]) so successive PRs can
//! diff perf against a committed baseline.

use std::time::Duration;

use super::stats::{fmt_duration, Summary};

/// One measured cell: a workload/executor combination.
#[derive(Debug, Clone)]
pub struct Row {
    /// Workload parameter rendered as text (e.g. "fib(30)").
    pub param: String,
    /// Executor / series name.
    pub series: String,
    /// Measured summary.
    pub summary: Summary,
}

/// A named collection of rows — one table or figure reproduction.
#[derive(Debug, Clone)]
pub struct Report {
    /// E.g. "FIG1 fibonacci wall time".
    pub title: String,
    /// Units note / testbed caveat printed under the title.
    pub note: String,
    /// Measured cells.
    pub rows: Vec<Row>,
}

impl Report {
    /// Creates an empty report.
    pub fn new(title: impl Into<String>, note: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            note: note.into(),
            rows: Vec::new(),
        }
    }

    /// Appends a measurement.
    pub fn push(&mut self, param: impl Into<String>, series: impl Into<String>, summary: Summary) {
        self.rows.push(Row {
            param: param.into(),
            series: series.into(),
            summary,
        });
    }

    /// Mean duration for a (param, series) cell, if present.
    pub fn mean_of(&self, param: &str, series: &str) -> Option<Duration> {
        self.rows
            .iter()
            .find(|r| r.param == param && r.series == series)
            .map(|r| r.summary.mean)
    }

    /// Speedup of `series_a` over `series_b` at `param`
    /// (times; >1 means `a` is faster).
    pub fn speedup(&self, param: &str, series_a: &str, series_b: &str) -> Option<f64> {
        let a = self.mean_of(param, series_a)?.as_secs_f64();
        let b = self.mean_of(param, series_b)?.as_secs_f64();
        if a == 0.0 {
            None
        } else {
            Some(b / a)
        }
    }

    /// Prints the aligned table followed by the CSV block (both go to
    /// stdout so `cargo bench | tee` captures everything).
    pub fn print(&self) {
        println!("{}", markdown_table(self));
        println!();
        println!("CSV {}", self.title);
        print!("{}", csv_report(self));
        println!();
    }
}

/// Renders a report as a GitHub-flavored markdown table.
pub fn markdown_table(report: &Report) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n## {}\n", report.title));
    if !report.note.is_empty() {
        out.push_str(&format!("_{}_\n", report.note));
    }
    let headers = ["param", "series", "mean", "median", "stddev", "min", "max", "samples"];
    let mut table: Vec<[String; 8]> = Vec::new();
    for r in &report.rows {
        table.push([
            r.param.clone(),
            r.series.clone(),
            fmt_duration(r.summary.mean),
            fmt_duration(r.summary.median),
            fmt_duration(r.summary.stddev),
            fmt_duration(r.summary.min),
            fmt_duration(r.summary.max),
            r.summary.n.to_string(),
        ]);
    }
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in &table {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        format!("| {} |", padded.join(" | "))
    };
    out.push_str(&fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    ));
    out.push('\n');
    for row in &table {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Renders a report as CSV (`param,series,mean_ns,median_ns,...`).
pub fn csv_report(report: &Report) -> String {
    let mut out = String::from("param,series,mean_ns,median_ns,stddev_ns,min_ns,max_ns,samples\n");
    for r in &report.rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            r.param,
            r.series,
            r.summary.mean.as_nanos(),
            r.summary.median.as_nanos(),
            r.summary.stddev.as_nanos(),
            r.summary.min.as_nanos(),
            r.summary.max.as_nanos(),
            r.summary.n
        ));
    }
    out
}

/// Default path of the perf-trajectory ledger, relative to the bench
/// process working directory (`cargo bench` runs at the package root).
/// One ledger per PR: `BENCH_pr1.json`–`BENCH_pr9.json` hold the
/// frozen PR 1–9 baselines; this PR's runs accumulate in
/// `BENCH_pr10.json` so successive ledgers can be diffed.
pub const BENCH_JSON_DEFAULT: &str = "BENCH_pr10.json";

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Renders one ledger entry as a single JSON-object line.
fn json_entry(bench: &str, metric: &str, threads: usize, report_title: &str, row: &Row) -> String {
    format!(
        "{{\"bench\":\"{}\",\"title\":\"{}\",\"param\":\"{}\",\"series\":\"{}\",\"metric\":\"{}\",\
         \"threads\":{},\"samples\":{},\"median_ns\":{},\"mean_ns\":{},\"stddev_ns\":{},\
         \"min_ns\":{},\"max_ns\":{}}}",
        json_escape(bench),
        json_escape(report_title),
        json_escape(&row.param),
        json_escape(&row.series),
        json_escape(metric),
        threads,
        row.summary.n,
        row.summary.median.as_nanos(),
        row.summary.mean.as_nanos(),
        row.summary.stddev.as_nanos(),
        row.summary.min.as_nanos(),
        row.summary.max.as_nanos(),
    )
}

/// Appends `report` to the machine-readable benchmark ledger
/// (`BENCH_pr10.json` at the package root by default; override the
/// path with `BENCH_JSON=path`, disable with `BENCH_JSON=0`).
///
/// The ledger is one JSON object with an `entries` array of one-line
/// objects — per (bench, param, series): median/mean wall or CPU time
/// in nanoseconds, sample count, and thread count. Entries are merged
/// by (bench, report title): re-running a bench replaces its previous
/// rows and leaves every other bench's rows in place, so one `cargo
/// bench` sweep accumulates the full trajectory snapshot for the PR.
/// `metric` is `"wall"` or `"cpu"` depending on how the report's rows
/// were measured.
pub fn record_json(bench: &str, metric: &str, threads: usize, report: &Report) {
    let path = match std::env::var("BENCH_JSON") {
        Err(_) => BENCH_JSON_DEFAULT.to_string(),
        Ok(v) if v.is_empty() || v == "0" => return,
        Ok(v) => v,
    };
    record_json_to(&path, bench, metric, threads, report);
}

/// [`record_json`] with an explicit ledger path (no environment read) —
/// for callers managing their own output location, and for tests,
/// which must not mutate process-global environment under the parallel
/// test harness.
pub fn record_json_to(path: &str, bench: &str, metric: &str, threads: usize, report: &Report) {
    // Keep entries from other benches/reports; replace our own.
    let drop_key = format!(
        "\"bench\":\"{}\",\"title\":\"{}\"",
        json_escape(bench),
        json_escape(&report.title)
    );
    let mut entries: Vec<String> = Vec::new();
    if let Ok(existing) = std::fs::read_to_string(path) {
        for line in existing.lines() {
            let line = line.trim().trim_end_matches(',');
            if line.starts_with("{\"bench\":") && !line.contains(&drop_key) {
                entries.push(line.to_string());
            }
        }
    }
    for row in &report.rows {
        entries.push(json_entry(bench, metric, threads, &report.title, row));
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("\"schema\": \"scheduling-bench-v1\",\n");
    out.push_str(
        "\"note\": \"per-bench medians from the in-crate harness; re-running a bench replaces its own entries\",\n",
    );
    out.push_str("\"entries\": [\n");
    out.push_str(&entries.join(",\n"));
    out.push_str("\n]\n}\n");
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("warning: could not write bench ledger {path}: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(ms: u64) -> Summary {
        Summary::from_samples(&[Duration::from_millis(ms)])
    }

    #[test]
    fn table_contains_all_cells() {
        let mut rep = Report::new("t", "n");
        rep.push("fib(30)", "scheduling", summary(10));
        rep.push("fib(30)", "taskflow-like", summary(12));
        let t = markdown_table(&rep);
        assert!(t.contains("fib(30)"));
        assert!(t.contains("scheduling"));
        assert!(t.contains("taskflow-like"));
        assert!(t.contains("10.00 ms"));
    }

    #[test]
    fn csv_round_numbers() {
        let mut rep = Report::new("t", "");
        rep.push("p", "s", summary(1));
        let csv = csv_report(&rep);
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("p,s,1000000,"));
    }

    #[test]
    fn speedup_math() {
        let mut rep = Report::new("t", "");
        rep.push("p", "fast", summary(10));
        rep.push("p", "slow", summary(40));
        let s = rep.speedup("p", "fast", "slow").unwrap();
        assert!((s - 4.0).abs() < 1e-9);
        assert!(rep.speedup("p", "fast", "missing").is_none());
    }

    #[test]
    fn json_entry_shape_and_escaping() {
        let row = Row {
            param: "chain(8192)".to_string(),
            series: "with \"quotes\"".to_string(),
            summary: summary(2),
        };
        let line = json_entry("linear_chain", "wall", 2, "GH-LC", &row);
        assert!(line.starts_with("{\"bench\":\"linear_chain\""));
        assert!(line.contains("\"median_ns\":2000000"));
        assert!(line.contains("\"threads\":2"));
        assert!(line.contains("with \\\"quotes\\\""));
        assert!(line.ends_with('}'));
    }

    #[test]
    fn record_json_merges_per_bench() {
        // Uses the explicit-path variant: mutating BENCH_JSON via
        // set_var would race other tests' getenv calls under the
        // parallel test harness.
        let dir = std::env::temp_dir().join(format!("bench_ledger_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ledger.json");
        let path = path.to_str().unwrap();

        let mut a = Report::new("T-A", "");
        a.push("p1", "s1", summary(1));
        record_json_to(path, "bench_a", "wall", 2, &a);

        let mut b = Report::new("T-B", "");
        b.push("p2", "s2", summary(3));
        record_json_to(path, "bench_b", "cpu", 4, &b);

        // Re-record bench_a with a new value: replaces, not duplicates.
        let mut a2 = Report::new("T-A", "");
        a2.push("p1", "s1", summary(7));
        record_json_to(path, "bench_a", "wall", 2, &a2);

        let text = std::fs::read_to_string(path).unwrap();
        let _ = std::fs::remove_dir_all(&dir);

        assert_eq!(text.matches("\"bench\":\"bench_a\"").count(), 1, "{text}");
        assert_eq!(text.matches("\"bench\":\"bench_b\"").count(), 1, "{text}");
        assert!(text.contains("\"median_ns\":7000000"), "{text}");
        assert!(!text.contains("\"median_ns\":1000000"), "{text}");
        assert!(text.contains("\"metric\":\"cpu\""));
        assert!(text.trim_start().starts_with('{'));
        assert!(text.trim_end().ends_with('}'));
    }

    #[test]
    fn mean_of_lookup() {
        let mut rep = Report::new("t", "");
        rep.push("a", "x", summary(3));
        assert_eq!(rep.mean_of("a", "x"), Some(Duration::from_millis(3)));
        assert_eq!(rep.mean_of("a", "y"), None);
    }
}
