//! Benchmark harness: measurement, statistics, and table/CSV output.
//!
//! criterion is not available in the offline vendor set, and the paper
//! (§3) needs two measurement modes criterion does not provide out of
//! the box anyway: wall time *and process CPU time* (Fig. 2). So the
//! harness is implemented here: warmup, fixed-iteration measurement,
//! robust statistics, aligned-table and CSV emitters. `cargo bench`
//! targets (`benches/*.rs`, `harness = false`) drive it.

mod measure;
mod report;
mod stats;

pub use measure::{bench_cpu, bench_wall, BenchOptions, Measurement};
pub use report::{csv_report, markdown_table, record_json, record_json_to, Report, Row, BENCH_JSON_DEFAULT};
pub use stats::Summary;
