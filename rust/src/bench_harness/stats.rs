//! Summary statistics over benchmark samples.

use std::time::Duration;

/// Robust summary of a set of duration samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: Duration,
    /// Median (50th percentile, linear interpolation).
    pub median: Duration,
    /// Sample standard deviation (Bessel-corrected).
    pub stddev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
}

impl Summary {
    /// Computes a summary; panics on an empty slice.
    pub fn from_samples(samples: &[Duration]) -> Self {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            secs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = secs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(median),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *samples.iter().min().unwrap(),
            max: *samples.iter().max().unwrap(),
        }
    }

    /// Relative standard deviation (stddev / mean), for noise gating.
    pub fn rsd(&self) -> f64 {
        let m = self.mean.as_secs_f64();
        if m == 0.0 {
            0.0
        } else {
            self.stddev.as_secs_f64() / m
        }
    }
}

/// Human formatting for durations: picks ns/µs/ms/s.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::from_samples(&[ms(10), ms(10), ms(10)]);
        assert_eq!(s.mean, ms(10));
        assert_eq!(s.median, ms(10));
        assert_eq!(s.stddev, Duration::ZERO);
        assert_eq!(s.min, ms(10));
        assert_eq!(s.max, ms(10));
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::from_samples(&[ms(1), ms(2), ms(3), ms(4)]);
        let us = |d: Duration| d.as_secs_f64() * 1e6;
        assert!((us(s.mean) - 2500.0).abs() < 0.01, "mean={:?}", s.mean);
        assert!((us(s.median) - 2500.0).abs() < 0.01, "median={:?}", s.median);
        assert_eq!(s.min, ms(1));
        assert_eq!(s.max, ms(4));
        // var = ((1.5)^2+(0.5)^2+(0.5)^2+(1.5)^2)/3 ms^2 = 5/3 -> sd ~1.29ms
        let sd_ms = s.stddev.as_secs_f64() * 1e3;
        // Durations quantize to ns, so allow that much slack (1e-6 ms).
        assert!((sd_ms - (5.0f64 / 3.0).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn median_odd() {
        let s = Summary::from_samples(&[ms(5), ms(1), ms(9)]);
        assert_eq!(s.median, ms(5));
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn empty_panics() {
        Summary::from_samples(&[]);
    }

    #[test]
    fn fmt_picks_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(fmt_duration(Duration::from_millis(2500)), "2.500 s");
        assert!(fmt_duration(Duration::from_micros(12)).contains("µs"));
    }
}
