//! Allocation-free task representation: [`RawTask`].
//!
//! The seed implementation stored every submitted closure as
//! `Box<dyn FnOnce()>` — one heap allocation + one virtual call per
//! task, paid on the hottest path in the system. For the paper's
//! workloads (fib, chain, tree: millions of tiny tasks whose captures
//! are one or two `Arc`s) the allocator dwarfs the actual work.
//!
//! [`RawTask`] is a small-closure-optimized task cell, the same trick
//! `std::task::RawWaker` and Tokio's task cells use:
//!
//! * closures whose captures fit in **3 words** (24 bytes on 64-bit)
//!   and align to at most a word are stored **inline** — zero heap
//!   traffic from submit to execute;
//! * larger closures fall back to a single `Box` whose pointer is
//!   stored inline (exactly the seed's cost, no worse);
//! * task-graph nodes ([`NodeRun`]: one `Arc` pointer + one index) fit
//!   inline by construction — a compile-time assertion guards this.
//!
//! Dispatch is a two-entry vtable (`call`, `drop`) monomorphized per
//! closure type; `call` receives the pool and the executing lane index
//! (a worker index, or the pool's shared helper lane when a
//! caller-assist thread runs the task — see `thread_pool::assist_until`)
//! so graph nodes can chain successors and closure panics can be
//! counted without re-boxing any context.

use std::marker::PhantomData;
use std::mem::{self, ManuallyDrop, MaybeUninit};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::ptr;
use std::sync::Arc;

use super::thread_pool::PoolInner;
use crate::graph::NodeRun;

/// Payload words available for inline storage.
const WORDS: usize = 3;

/// Raw payload storage: 3 machine words, word-aligned.
struct TaskData {
    words: MaybeUninit<[usize; WORDS]>,
}

impl TaskData {
    #[inline]
    fn uninit() -> Self {
        TaskData {
            words: MaybeUninit::uninit(),
        }
    }

    /// # Safety
    /// `T` must satisfy [`fits_inline`]; the slot must be vacant.
    #[inline]
    unsafe fn write<T>(&mut self, value: T) {
        ptr::write(self.words.as_mut_ptr() as *mut T, value);
    }

    /// # Safety
    /// The slot must hold an initialized `T` written by [`TaskData::write`];
    /// this call consumes it.
    #[inline]
    unsafe fn take<T>(&mut self) -> T {
        ptr::read(self.words.as_ptr() as *const T)
    }
}

/// How a [`RawTask`] stores its payload (exposed for tests/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TaskKind {
    /// Closure stored inline in the task cell (no heap allocation).
    Inline,
    /// Closure spilled to a `Box`; the cell holds the pointer.
    Boxed,
    /// Task-graph node (`Arc<RunState>` + node index), stored inline.
    Node,
}

struct VTable {
    /// Consumes the payload and runs the task. Closure panics are
    /// caught here and counted on the pool; graph nodes contain their
    /// own panics (see `graph::execute_node`).
    call: unsafe fn(&mut TaskData, &Arc<PoolInner>, usize),
    /// Consumes the payload without running it (pool teardown paths).
    drop: unsafe fn(&mut TaskData),
    kind: TaskKind,
}

/// True when `F` can be stored inline in the 3-word payload.
const fn fits_inline<F>() -> bool {
    mem::size_of::<F>() <= mem::size_of::<[usize; WORDS]>()
        && mem::align_of::<F>() <= mem::align_of::<[usize; WORDS]>()
}

// A NodeRun must always fit inline (Arc pointer + usize index).
const _: () = assert!(
    mem::size_of::<NodeRun>() <= mem::size_of::<[usize; WORDS]>()
        && mem::align_of::<NodeRun>() <= mem::align_of::<[usize; WORDS]>()
);

unsafe fn call_inline<F: FnOnce()>(data: &mut TaskData, pool: &Arc<PoolInner>, _worker: usize) {
    let f = data.take::<F>();
    if catch_unwind(AssertUnwindSafe(f)).is_err() {
        pool.note_panic();
    }
}

unsafe fn drop_inline<F>(data: &mut TaskData) {
    drop(data.take::<F>());
}

unsafe fn call_boxed<F: FnOnce()>(data: &mut TaskData, pool: &Arc<PoolInner>, _worker: usize) {
    let f = data.take::<Box<F>>();
    if catch_unwind(AssertUnwindSafe(*f)).is_err() {
        pool.note_panic();
    }
}

unsafe fn drop_boxed<F>(data: &mut TaskData) {
    drop(data.take::<Box<F>>());
}

unsafe fn call_node(data: &mut TaskData, pool: &Arc<PoolInner>, worker: usize) {
    let run = data.take::<NodeRun>();
    crate::graph::execute_node(pool, worker, run);
}

unsafe fn drop_node(data: &mut TaskData) {
    drop(data.take::<NodeRun>());
}

/// Per-closure-type vtable holder; `&VTableFor::<F>::INLINE` is
/// promoted to `'static` (fn pointers only, no Drop, no interior
/// mutability). Never instantiated — only its associated consts are
/// used.
struct VTableFor<F>(#[allow(dead_code)] PhantomData<F>);

impl<F: FnOnce() + Send + 'static> VTableFor<F> {
    const INLINE: VTable = VTable {
        call: call_inline::<F>,
        drop: drop_inline::<F>,
        kind: TaskKind::Inline,
    };
    const BOXED: VTable = VTable {
        call: call_boxed::<F>,
        drop: drop_boxed::<F>,
        kind: TaskKind::Boxed,
    };
}

static NODE_VTABLE: VTable = VTable {
    call: call_node,
    drop: drop_node,
    kind: TaskKind::Node,
};

/// A unit of work owned by the pool: an inline-storage closure, a
/// boxed closure, or a task-graph node. See the module docs.
pub(crate) struct RawTask {
    data: TaskData,
    vtable: &'static VTable,
}

// SAFETY: every payload variant is `Send` by construction — closures
// are constrained `F: Send`, `NodeRun` is `Send` (`RunState` is
// `Send + Sync`) — and the cell is just raw storage for it.
unsafe impl Send for RawTask {}

impl RawTask {
    /// Wraps a closure, storing it inline when it fits and boxing it
    /// otherwise.
    #[inline]
    pub(crate) fn closure<F: FnOnce() + Send + 'static>(f: F) -> Self {
        if fits_inline::<F>() {
            let mut data = TaskData::uninit();
            // SAFETY: fits_inline::<F>() holds; the slot is vacant.
            unsafe { data.write(f) };
            RawTask {
                data,
                vtable: &VTableFor::<F>::INLINE,
            }
        } else {
            Self::boxed_closure(f)
        }
    }

    /// Wraps a closure behind a `Box` unconditionally — the seed's
    /// representation, kept as the `inline_tasks = false` ablation arm.
    #[inline]
    pub(crate) fn boxed_closure<F: FnOnce() + Send + 'static>(f: F) -> Self {
        let boxed: Box<F> = Box::new(f);
        let mut data = TaskData::uninit();
        // SAFETY: Box<F> is one word; the slot is vacant.
        unsafe { data.write(boxed) };
        RawTask {
            data,
            vtable: &VTableFor::<F>::BOXED,
        }
    }

    /// Wraps a task-graph node (never allocates; see the const assert).
    #[inline]
    pub(crate) fn node(run: NodeRun) -> Self {
        let mut data = TaskData::uninit();
        // SAFETY: NodeRun fits inline (compile-time assertion above).
        unsafe { data.write(run) };
        RawTask {
            data,
            vtable: &NODE_VTABLE,
        }
    }

    /// Storage class, for tests and diagnostics.
    #[allow(dead_code)]
    pub(crate) fn kind(&self) -> TaskKind {
        self.vtable.kind
    }

    /// Executes the task, consuming it. `pool`/`worker` give graph
    /// nodes their scheduling context and closure panics a counter.
    #[inline]
    pub(crate) fn run(self, pool: &Arc<PoolInner>, worker: usize) {
        let mut this = ManuallyDrop::new(self);
        // SAFETY: the payload is initialized (constructors guarantee
        // it) and consumed exactly once — ManuallyDrop suppresses the
        // Drop impl that would otherwise consume it again.
        unsafe { (this.vtable.call)(&mut this.data, pool, worker) }
    }
}

impl Drop for RawTask {
    fn drop(&mut self) {
        // SAFETY: `run` never lets Drop observe a consumed payload
        // (ManuallyDrop), so the payload here is still initialized.
        unsafe { (self.vtable.drop)(&mut self.data) }
    }
}

impl std::fmt::Debug for RawTask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RawTask").field("kind", &self.vtable.kind).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn small_captures_stay_inline() {
        let a = Arc::new(AtomicUsize::new(0));
        let t = RawTask::closure(move || {
            a.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(t.kind(), TaskKind::Inline);

        // Two Arcs + a usize = 3 words: still inline.
        let (a, b) = (Arc::new(0u64), Arc::new(1u64));
        let x = 7usize;
        let t = RawTask::closure(move || {
            let _ = (&a, &b, x);
        });
        assert_eq!(t.kind(), TaskKind::Inline);
    }

    #[test]
    fn large_captures_spill_to_box() {
        let big = [0u64; 16];
        let t = RawTask::closure(move || {
            let _ = big;
        });
        assert_eq!(t.kind(), TaskKind::Boxed);
    }

    #[test]
    fn forced_boxing_always_boxes() {
        let t = RawTask::boxed_closure(|| {});
        assert_eq!(t.kind(), TaskKind::Boxed);
    }

    #[test]
    fn dropping_unran_task_releases_captures() {
        let payload = Arc::new(());
        assert_eq!(Arc::strong_count(&payload), 1);
        let p = payload.clone();
        let t = RawTask::closure(move || {
            let _ = &p;
        });
        assert_eq!(Arc::strong_count(&payload), 2);
        drop(t);
        assert_eq!(Arc::strong_count(&payload), 1);

        // Same through the boxed path.
        let p = payload.clone();
        let big = [0u8; 64];
        let t = RawTask::closure(move || {
            let _ = (&p, &big);
        });
        assert_eq!(t.kind(), TaskKind::Boxed);
        drop(t);
        assert_eq!(Arc::strong_count(&payload), 1);
    }

    #[test]
    fn zero_sized_closures_are_inline() {
        let t = RawTask::closure(|| {});
        assert_eq!(t.kind(), TaskKind::Inline);
    }
}
