//! Per-worker and pool-level scheduler metrics.
//!
//! Counters are relaxed atomics on cache-padded per-worker blocks —
//! incrementing them costs one uncontended RMW and never synchronizes
//! workers with each other, so leaving them enabled in release builds
//! is fine (the `fib_wall` bench quantifies the cost as sub-1%).

use std::sync::atomic::{AtomicU64, Ordering};

use super::injector::NUM_LANES;
use crate::util::CachePadded;

/// Counters owned by one worker thread.
#[derive(Debug, Default)]
pub struct WorkerMetrics {
    /// Tasks pushed to this worker's own deque.
    pub pushes: AtomicU64,
    /// Tasks popped from this worker's own deque.
    pub pops: AtomicU64,
    /// Tasks stolen *by* this worker from someone else. A batched
    /// steal counts once here (the task it returned for execution);
    /// the extra tasks it moved are tracked by `steal_batch_tasks` and
    /// show up as `pops` when they eventually execute.
    pub steals: AtomicU64,
    /// Steal attempts that found the victim empty or lost the race.
    pub steal_failures: AtomicU64,
    /// Batched steals that moved at least one extra task into this
    /// worker's deque (see `Stealer::steal_batch_and_pop`).
    pub steal_batches: AtomicU64,
    /// Total extra tasks moved by batched steals (batch sizes sum;
    /// average batch size = `steal_batch_tasks / steal_batches + 1`).
    pub steal_batch_tasks: AtomicU64,
    /// Tasks taken from the global injector.
    pub injector_pops: AtomicU64,
    /// Times this worker transitioned into an eventcount park (counted
    /// once per idle spell, not per `commit_wait` call — multi-shard
    /// parks re-check on a timeout backstop, and those cycles are not
    /// new parks).
    pub parks: AtomicU64,
    /// Graph continuations executed inline (paper §2.2: the first ready
    /// successor runs on the same worker without re-queueing).
    pub inline_continuations: AtomicU64,
    /// Steals whose victim lived in a *different shard* (PR 5) — the
    /// level-2 half of the two-level sweep. Also counted in `steals`,
    /// so `remote_steals / steals` is the cross-shard traffic fraction
    /// the locality-aware sweep is meant to keep low.
    pub remote_steals: AtomicU64,
    /// Injector pops served by a *remote shard's* injector (PR 5).
    /// Also counted in `injector_pops`, same ratio semantics as
    /// `remote_steals`.
    pub remote_injector_pops: AtomicU64,
}

macro_rules! bump {
    ($($name:ident => $field:ident),* $(,)?) => {
        $(
            #[doc = concat!("Increments `", stringify!($field), "` (relaxed).")]
            #[inline]
            pub fn $name(&self) {
                self.$field.fetch_add(1, Ordering::Relaxed);
            }
        )*
    };
}

impl WorkerMetrics {
    bump! {
        on_push => pushes,
        on_pop => pops,
        on_steal => steals,
        on_steal_failure => steal_failures,
        on_injector_pop => injector_pops,
        on_park => parks,
        on_inline_continuation => inline_continuations,
        on_remote_steal => remote_steals,
        on_remote_injector_pop => remote_injector_pops,
    }

    /// Records a batched steal that moved `extra` additional tasks
    /// into this worker's deque (relaxed).
    #[inline]
    pub fn on_steal_batch(&self, extra: u64) {
        self.steal_batches.fetch_add(1, Ordering::Relaxed);
        self.steal_batch_tasks.fetch_add(extra, Ordering::Relaxed);
    }

    /// Increments `pushes` by `n` (relaxed) — used when a burst of
    /// tasks enters the local deque through one batched operation.
    #[inline]
    pub fn on_push_n(&self, n: u64) {
        self.pushes.fetch_add(n, Ordering::Relaxed);
    }
}

/// A point-in-time snapshot of one worker's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSnapshot {
    /// Tasks pushed to the worker's own deque.
    pub pushes: u64,
    /// Tasks popped from the worker's own deque.
    pub pops: u64,
    /// Tasks stolen by this worker (batched steals count once).
    pub steals: u64,
    /// Steal attempts that failed (empty victim or lost race).
    pub steal_failures: u64,
    /// Batched steals that moved extra tasks (see `WorkerMetrics`).
    pub steal_batches: u64,
    /// Total extra tasks moved by batched steals.
    pub steal_batch_tasks: u64,
    /// Tasks taken from the global injector.
    pub injector_pops: u64,
    /// Times the worker transitioned into an eventcount park (one per
    /// idle spell; backstop re-check cycles do not recount).
    pub parks: u64,
    /// Graph continuations executed inline (paper §2.2).
    pub inline_continuations: u64,
    /// Cross-shard steals (subset of `steals`; PR 5).
    pub remote_steals: u64,
    /// Remote-shard injector pops (subset of `injector_pops`; PR 5).
    pub remote_injector_pops: u64,
}

impl WorkerSnapshot {
    /// Jobs executed by this worker. Every executed job was acquired
    /// by exactly one of pop/steal/injector-pop, so this is derived
    /// rather than counted — one fewer RMW on the execute path
    /// (EXPERIMENTS.md §Perf iteration 3).
    pub fn executed(&self) -> u64 {
        self.pops + self.steals + self.injector_pops
    }
}

impl WorkerMetrics {
    /// Takes a relaxed snapshot.
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            pushes: self.pushes.load(Ordering::Relaxed),
            pops: self.pops.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            steal_failures: self.steal_failures.load(Ordering::Relaxed),
            steal_batches: self.steal_batches.load(Ordering::Relaxed),
            steal_batch_tasks: self.steal_batch_tasks.load(Ordering::Relaxed),
            injector_pops: self.injector_pops.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            inline_continuations: self.inline_continuations.load(Ordering::Relaxed),
            remote_steals: self.remote_steals.load(Ordering::Relaxed),
            remote_injector_pops: self.remote_injector_pops.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time queue depths of one shard (PR 5): how much work is
/// sitting in the shard's injector lanes and its members' deques, and
/// how many of its workers are parked. All values are relaxed probes —
/// exact only while the pool is quiescent — but good enough for the
/// imbalance signal the ABL-8 storm bench reports.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardSnapshot {
    /// Worker-index range `[start, end)` of the shard's members.
    pub workers: (usize, usize),
    /// Per-lane injector depths (lane 0 = most urgent).
    pub lane_depths: [usize; NUM_LANES],
    /// Total injector depth (sum of `lane_depths`).
    pub injector_depth: usize,
    /// Summed depth of the member workers' deques.
    pub deque_depth: usize,
    /// Members currently registered as (prospective) sleepers on the
    /// shard's eventcount.
    pub parked: usize,
}

impl ShardSnapshot {
    /// Queued work visible in this shard (injector + member deques).
    pub fn queued(&self) -> usize {
        self.injector_depth + self.deque_depth
    }
}

/// Aggregated snapshot across all workers of a pool.
#[derive(Debug, Clone, Default)]
pub struct PoolSnapshot {
    /// Per-worker snapshots, indexed by worker id.
    pub workers: Vec<WorkerSnapshot>,
    /// Per-shard queue depths (PR 5); a flat pool reports one shard.
    pub shards: Vec<ShardSnapshot>,
    /// Workers currently inside their run loop (PR 6). Equal to the
    /// configured thread count for a healthy pool — the worker-revival
    /// path exists precisely so this never silently drops.
    pub alive_workers: usize,
    /// Times a worker caught an unwind that escaped task containment
    /// and revived in place (PR 6). Zero in a correct build; nonzero
    /// means panic containment regressed somewhere.
    pub worker_revivals: u64,
    /// Low-class runs rejected by admission control (PR 6's shed-first
    /// overload policy).
    pub shed_runs: u64,
    /// Dispatch-queue-delay EWMA in nanoseconds (PR 7): how long run
    /// requests waited at a serving front-end before dispatch, as fed
    /// by [`crate::pool::ThreadPool::note_queue_delay`]. Zero until a
    /// front-end reports. The brownout controller and the
    /// deadline-infeasibility admission check both key off this.
    pub queue_delay_ewma_ns: u64,
}

/// Point-in-time view of one serving tenant (PR 7), produced by
/// `serve::GraphService::tenant_snapshots`. Lives here rather than in
/// `serve/` so the pool- and tenant-level metrics share one vocabulary
/// (and one import) in benches and dashboards.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    /// Registry index of the tenant.
    pub id: usize,
    /// Human-readable tenant name.
    pub name: String,
    /// DRR weight — the tenant's share of dispatch grants under
    /// contention.
    pub weight: u32,
    /// Requests currently granted and not yet completed.
    pub inflight: usize,
    /// Requests accepted into the dispatch queue.
    pub submitted: u64,
    /// Requests that completed successfully (goodput).
    pub completed: u64,
    /// Launch attempts beyond each request's first (retry traffic).
    pub retries: u64,
    /// Requests shed by brownout because the tenant's class is `Low`.
    pub shed_low: u64,
    /// Requests shed by brownout because the tenant was over quota.
    pub shed_over_quota: u64,
    /// Requests rejected as deadline-infeasible at admission.
    pub shed_deadline: u64,
    /// Requests that ultimately failed (retries exhausted or a
    /// non-retryable error).
    pub failed: u64,
    /// Per-tenant service-time EWMA in nanoseconds (PR 8): grant →
    /// successful completion. Zero until the tenant's first
    /// completion. Feeds deadline feasibility and slow-tenant
    /// demotion in the serving gate.
    pub service_ewma_ns: u64,
    /// Launches demoted off the tenant's declared class because this
    /// EWMA exceeded `ServiceConfig::demote_slow_after` (PR 8).
    pub demotions: u64,
}

impl TenantSnapshot {
    /// Total requests shed or rejected before reaching the pool.
    pub fn shed_total(&self) -> u64 {
        self.shed_low + self.shed_over_quota + self.shed_deadline
    }
}

impl PoolSnapshot {
    /// Sum over workers.
    pub fn total(&self) -> WorkerSnapshot {
        let mut t = WorkerSnapshot::default();
        for w in &self.workers {
            t.pushes += w.pushes;
            t.pops += w.pops;
            t.steals += w.steals;
            t.steal_failures += w.steal_failures;
            t.steal_batches += w.steal_batches;
            t.steal_batch_tasks += w.steal_batch_tasks;
            t.injector_pops += w.injector_pops;
            t.parks += w.parks;
            t.inline_continuations += w.inline_continuations;
            t.remote_steals += w.remote_steals;
            t.remote_injector_pops += w.remote_injector_pops;
        }
        t
    }

    /// Shard-depth imbalance at snapshot time: max over shards of
    /// queued work divided by the mean (1.0 = perfectly even, higher =
    /// one shard hoards the queue). 0.0 when there is nothing queued
    /// or only one shard — the flat pool has no imbalance to report.
    pub fn shard_imbalance(&self) -> f64 {
        if self.shards.len() < 2 {
            return 0.0;
        }
        let depths: Vec<usize> = self.shards.iter().map(|s| s.queued()).collect();
        let total: usize = depths.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / depths.len() as f64;
        *depths.iter().max().unwrap() as f64 / mean
    }

    /// Fraction of executed tasks that arrived by stealing — the
    /// load-balancing signal the Chase–Lev design optimizes.
    pub fn steal_ratio(&self) -> f64 {
        let t = self.total();
        if t.executed() == 0 {
            0.0
        } else {
            t.steals as f64 / t.executed() as f64
        }
    }
}

impl std::fmt::Display for PoolSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = self.total();
        writeln!(
            f,
            "pool: executed={} pushes={} pops={} steals={} steal_fail={} steal_batches={} \
             batch_tasks={} injector={} parks={} inline={} remote_steals={} remote_injector={}",
            t.executed(), t.pushes, t.pops, t.steals, t.steal_failures, t.steal_batches,
            t.steal_batch_tasks, t.injector_pops, t.parks, t.inline_continuations,
            t.remote_steals, t.remote_injector_pops
        )?;
        writeln!(
            f,
            "  lifecycle: alive_workers={} worker_revivals={} shed_runs={} queue_delay_ewma={}ns",
            self.alive_workers, self.worker_revivals, self.shed_runs, self.queue_delay_ewma_ns
        )?;
        for (i, w) in self.workers.iter().enumerate() {
            writeln!(
                f,
                "  w{i}: executed={} pops={} steals={} parks={} inline={}",
                w.executed(), w.pops, w.steals, w.parks, w.inline_continuations
            )?;
        }
        for (i, s) in self.shards.iter().enumerate() {
            writeln!(
                f,
                "  shard{i}[w{}..w{}): injector={} lanes={:?} deques={} parked={}",
                s.workers.0, s.workers.1, s.injector_depth, s.lane_depths, s.deque_depth, s.parked
            )?;
        }
        Ok(())
    }
}

/// The padded per-worker metrics block as stored by the pool.
pub type PaddedMetrics = CachePadded<WorkerMetrics>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_bumps() {
        let m = WorkerMetrics::default();
        m.on_push();
        m.on_push();
        m.on_pop();
        m.on_steal();
        m.on_steal_batch(3);
        m.on_push_n(3);
        let s = m.snapshot();
        assert_eq!(s.pushes, 5);
        assert_eq!(s.pops, 1);
        assert_eq!(s.steals, 1);
        assert_eq!(s.steal_batches, 1);
        assert_eq!(s.steal_batch_tasks, 3);
        assert_eq!(s.executed(), 2); // pop + steal
    }

    #[test]
    fn pool_total_and_ratio() {
        let a = WorkerSnapshot {
            pops: 6,
            steals: 2,
            ..Default::default()
        };
        let b = WorkerSnapshot {
            steals: 3,
            injector_pops: 2,
            ..Default::default()
        };
        let p = PoolSnapshot { workers: vec![a, b], ..PoolSnapshot::default() };
        assert_eq!(p.total().executed(), 13);
        assert!((p.steal_ratio() - 5.0 / 13.0).abs() < 1e-12);
    }

    #[test]
    fn empty_pool_ratio_is_zero() {
        assert_eq!(PoolSnapshot::default().steal_ratio(), 0.0);
    }

    #[test]
    fn shard_imbalance_max_over_mean() {
        let mk = |inj: usize, deq: usize| ShardSnapshot {
            injector_depth: inj,
            deque_depth: deq,
            ..ShardSnapshot::default()
        };
        let p = PoolSnapshot {
            shards: vec![mk(6, 0), mk(1, 1), mk(0, 0), mk(0, 0)],
            ..PoolSnapshot::default()
        };
        // depths 6,2,0,0 — mean 2, max 6.
        assert!((p.shard_imbalance() - 3.0).abs() < 1e-12);
        // Single shard / empty queues report no imbalance.
        let flat = PoolSnapshot { shards: vec![mk(5, 5)], ..PoolSnapshot::default() };
        assert_eq!(flat.shard_imbalance(), 0.0);
        let idle = PoolSnapshot { shards: vec![mk(0, 0), mk(0, 0)], ..PoolSnapshot::default() };
        assert_eq!(idle.shard_imbalance(), 0.0);
    }

    #[test]
    fn remote_counters_roll_up() {
        let m = WorkerMetrics::default();
        m.on_steal();
        m.on_steal();
        m.on_remote_steal();
        m.on_injector_pop();
        m.on_remote_injector_pop();
        let s = m.snapshot();
        assert_eq!(s.steals, 2);
        assert_eq!(s.remote_steals, 1);
        assert_eq!(s.injector_pops, 1);
        assert_eq!(s.remote_injector_pops, 1);
        // Remote counters are subsets, not additional executions.
        assert_eq!(s.executed(), 3);
    }
}
