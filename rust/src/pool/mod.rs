//! The work-stealing thread pool — the paper's core contribution (§2).
//!
//! * [`deque`] — Chase–Lev deque, fence-free memory orders (adopted).
//! * [`fence_deque`] — Chase–Lev deque, Lê et al. fence style (ablation).
//! * [`injector`] — global submission queue for non-worker threads.
//! * [`event_count`] — sleep/wake protocol for idle workers.
//! * [`thread_pool`] — [`ThreadPool`]: per-worker deques + thread-local
//!   worker registration + steal loop.
//! * [`metrics`] — relaxed per-worker counters.

pub mod deque;
pub mod event_count;
pub mod fence_deque;
pub mod injector;
pub mod handle;
pub mod metrics;
pub mod scope;
pub mod thread_pool;

pub use deque::{deque, Steal, Stealer, Worker};
pub use event_count::EventCount;
pub use fence_deque::{fence_deque, FenceStealer, FenceWorker};
pub use injector::{Injector, MutexInjector, SegQueue};
pub use handle::{JoinError, TaskHandle};
pub use metrics::{PoolSnapshot, WorkerMetrics, WorkerSnapshot};
pub use scope::Scope;
pub use thread_pool::{InjectorKind, PoolConfig, ThreadPool};
