//! The work-stealing thread pool — the paper's core contribution (§2).
//!
//! * [`deque`] — Chase–Lev deque, fence-free memory orders (adopted),
//!   with single-task `steal` and half-the-run `steal_batch_and_pop`.
//! * [`fence_deque`] — Chase–Lev deque, Lê et al. fence style
//!   (ablation comparator), same steal API.
//! * [`injector`] — submission queue for non-worker threads, with a
//!   batched `push_batch` for fan-out bursts. Since PR 5 the pool
//!   holds one injector **per shard** rather than one global one.
//! * [`topology`] — the shard layer (PR 5): workers are grouped into
//!   cache-sharing shards; each shard owns an injector and an
//!   eventcount, submissions route by origin (worker deque / assist
//!   home shard / striped round-robin), and the idle sweep is
//!   two-level (home shard first, then remote shards).
//! * [`event_count`] — sleep/wake protocol for idle workers.
//! * `task` (crate-private) — `RawTask`: the allocation-free task
//!   cell. Closures up to 3 words (and all task-graph nodes) are
//!   stored inline; larger captures spill to a single box.
//! * [`thread_pool`] — [`ThreadPool`]: per-worker deques + thread-local
//!   worker registration + steal loop + sharded pending counters.
//! * [`metrics`] — relaxed per-worker counters, including batch-steal
//!   sizes.
//!
//! # Scheduling hot path
//!
//! A submitted task travels: [`ThreadPool::submit`] → `RawTask` cell
//! (no allocation for ≤3-word captures) → owner deque push (one
//! Release store) → pop / batched steal → vtable call. The bookkeeping
//! around it is sharded per worker ([`thread_pool`] module docs):
//! submit and completion each touch one cache-padded single-writer
//! counter cell, and wakeups are throttled to an O(1) load unless a
//! worker is actually parked. Cross-thread submissions are further
//! sharded by [`topology`] (PR 5): each worker shard owns its own
//! injector lanes and eventcount, so producer storms spread over
//! `num_shards` queues and wakeups target cache-sharing neighbours
//! first. `benches/ablations.rs` toggles each of these optimizations
//! independently via [`PoolConfig`] (ABL-8 covers flat vs. sharded).
//!
//! Besides the workers, external threads can temporarily execute pool
//! tasks as **helpers**: a caller-assisted graph run
//! (`graph::RunOptions`, PR 2) drains the injector and steals from
//! workers on the calling thread instead of sleeping, with its metrics
//! on a shared extra lane (the last entry of
//! [`ThreadPool::metrics`]'s snapshot).
//!
//! Threads waiting on an **async run handle** (`graph::RunHandle`,
//! PR 3) are a third population: they take no work, so they park on a
//! *dedicated* run-completion eventcount (`PoolInner::wait_run`)
//! rather than the workers' one — a run waiter must never swallow a
//! work-arrival `notify_one` meant for a sleeping worker.

pub mod deque;
pub mod event_count;
pub mod fence_deque;
pub mod injector;
pub mod handle;
pub mod metrics;
pub mod scope;
pub(crate) mod task;
pub mod thread_pool;
pub(crate) mod timer;
pub mod topology;

pub use deque::{deque, Steal, Stealer, Worker, MAX_STEAL_BATCH};
pub use event_count::EventCount;
pub use fence_deque::{fence_deque, FenceStealer, FenceWorker};
pub use injector::{Injector, LaneInjector, MutexInjector, SegQueue, DEFAULT_LANE, NUM_LANES};
pub use handle::{JoinError, TaskHandle};
pub use metrics::{PoolSnapshot, ShardSnapshot, TenantSnapshot, WorkerMetrics, WorkerSnapshot};
pub use scope::Scope;
pub use thread_pool::{InjectorKind, PoolConfig, ThreadPool};
pub use topology::{PoolTopology, DEFAULT_SHARD_WORKERS};
