//! Pool topology: how workers are grouped into **shards** (PR 5).
//!
//! The paper's pool has one global injection queue and a flat victim
//! sweep: every external submission serializes on a single CAS/mutex
//! line, and a thief is as likely to steal from a worker on the far
//! side of the machine as from its cache-sharing neighbour. Taskflow's
//! executor and the ROADMAP's "Distributed injector" / "NUMA-aware
//! stealing" items both point the same way: group workers into shards
//! of cache-sharing neighbours, give each shard its own injector (and
//! its own sleep/wake domain), and make the idle sweep **two-level** —
//! exhaust the home shard before crossing to remote shards.
//!
//! This module is pure arithmetic over `(num_workers, shard_size)`:
//! it owns no queues and no synchronization, so the scheduling code in
//! `thread_pool.rs` can ask "whose shard is worker 7 in?" or "which
//! workers belong to shard 2?" without any shared state. Workers are
//! assigned to shards contiguously (`worker / shard_size`), matching
//! how OSes enumerate SMT siblings and core-complex neighbours, so a
//! shard approximates an L3/CCX domain without any platform probing.
//!
//! A pool with **one shard** is exactly the pre-PR 5 flat pool: one
//! injector, one eventcount, one victim sweep over everyone. Small
//! pools (and any pool configured with `shard_size >= num_threads`)
//! are clamped to that shape, and `ABL-8` in `benches/ablations.rs`
//! measures flat vs. sharded under a many-producer storm.

/// Workers per shard when [`crate::pool::PoolConfig::shard_size`] is
/// left at 0 (auto). Eight matches the core-complex / L3-slice size of
/// the common desktop and server parts this crate targets; pools with
/// at most this many workers (i.e. most `available_parallelism()`
/// laptops and all of the paper's testbeds) collapse to a single
/// shard and keep the exact pre-PR 5 behaviour.
pub const DEFAULT_SHARD_WORKERS: usize = 8;

/// The shard layout of one pool. Immutable after construction; shared
/// freely by reference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolTopology {
    num_workers: usize,
    shard_size: usize,
    num_shards: usize,
}

impl PoolTopology {
    /// Computes the layout for `num_workers` workers with a configured
    /// shard size (`0` = auto, see [`DEFAULT_SHARD_WORKERS`]). The
    /// effective shard size is clamped to `1..=num_workers`, so
    /// `shard_size >= num_workers` (or a small pool under auto) yields
    /// exactly one shard — the flat pre-PR 5 pool.
    pub fn new(num_workers: usize, shard_size: usize) -> Self {
        let num_workers = num_workers.max(1);
        let shard_size = if shard_size == 0 {
            DEFAULT_SHARD_WORKERS
        } else {
            shard_size
        }
        .clamp(1, num_workers);
        let num_shards = num_workers.div_ceil(shard_size);
        PoolTopology {
            num_workers,
            shard_size,
            num_shards,
        }
    }

    /// Total worker count.
    #[inline]
    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// Workers per shard (the last shard may hold fewer).
    #[inline]
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Number of shards (≥ 1).
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// True when the pool is flat (a single shard) — the configuration
    /// that must route through the pre-PR 5 code paths bit-identically.
    #[inline]
    pub fn is_flat(&self) -> bool {
        self.num_shards == 1
    }

    /// Home shard of `worker` (contiguous assignment).
    #[inline]
    pub fn shard_of(&self, worker: usize) -> usize {
        debug_assert!(worker < self.num_workers);
        worker / self.shard_size
    }

    /// Worker-index range of `shard`'s members.
    #[inline]
    pub fn members(&self, shard: usize) -> std::ops::Range<usize> {
        debug_assert!(shard < self.num_shards);
        let start = shard * self.shard_size;
        start..((start + self.shard_size).min(self.num_workers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_pools_collapse_to_one_shard() {
        for n in 1..=DEFAULT_SHARD_WORKERS {
            let t = PoolTopology::new(n, 0);
            assert!(t.is_flat(), "{n} workers");
            assert_eq!(t.num_shards(), 1);
            assert_eq!(t.members(0), 0..n);
        }
    }

    #[test]
    fn explicit_shard_size_partitions_contiguously() {
        let t = PoolTopology::new(8, 2);
        assert_eq!(t.num_shards(), 4);
        assert_eq!(t.shard_size(), 2);
        for w in 0..8 {
            assert_eq!(t.shard_of(w), w / 2);
            assert!(t.members(t.shard_of(w)).contains(&w));
        }
        assert_eq!(t.members(3), 6..8);
    }

    #[test]
    fn ragged_last_shard() {
        let t = PoolTopology::new(9, 4);
        assert_eq!(t.num_shards(), 3);
        assert_eq!(t.members(0), 0..4);
        assert_eq!(t.members(1), 4..8);
        assert_eq!(t.members(2), 8..9);
        assert_eq!(t.shard_of(8), 2);
    }

    #[test]
    fn oversized_shard_size_is_flat() {
        let t = PoolTopology::new(3, 64);
        assert!(t.is_flat());
        assert_eq!(t.shard_size(), 3);
        assert_eq!(t.members(0), 0..3);
    }

    #[test]
    fn shard_size_one_is_per_worker_shards() {
        let t = PoolTopology::new(4, 1);
        assert_eq!(t.num_shards(), 4);
        for w in 0..4 {
            assert_eq!(t.shard_of(w), w);
            assert_eq!(t.members(w), w..w + 1);
        }
    }

    #[test]
    fn auto_splits_large_pools() {
        let t = PoolTopology::new(32, 0);
        assert_eq!(t.shard_size(), DEFAULT_SHARD_WORKERS);
        assert_eq!(t.num_shards(), 4);
        assert!(!t.is_flat());
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let t = PoolTopology::new(0, 0);
        assert_eq!(t.num_workers(), 1);
        assert!(t.is_flat());
    }
}
