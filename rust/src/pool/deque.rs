//! Chase–Lev work-stealing deque — the fence-free variant (paper §2.1).
//!
//! The owning worker pushes and pops at the *bottom*; thieves steal at
//! the *top*. This file implements the variant the paper ultimately
//! adopts: **no `atomic_thread_fence`** — every ordering constraint is
//! expressed on the atomic operation itself (the style of Google
//! Filament's `WorkStealingDequeue`, which the paper credits for being
//! clean under ThreadSanitizer). The fence-based C11 formulation of
//! Lê et al. lives in [`super::fence_deque`] as an ablation comparator.
//!
//! Differences from Filament's fixed-capacity deque:
//! * the buffer grows geometrically on overflow (like Chase–Lev's
//!   original dynamic circular array and crossbeam-deque); retired
//!   buffers are kept alive until the deque is dropped so a racing
//!   thief can always safely read through a stale buffer pointer;
//! * `steal` distinguishes `Empty` from `Retry` (lost CAS race) so the
//!   pool's steal loop can make an informed back-off decision.
//!
//! # Safety model
//!
//! * `top` and `bottom` are `AtomicI64` on separate cache lines
//!   ([`CachePadded`]): thieves only CAS `top`; the owner mostly touches
//!   `bottom`, so steals do not invalidate the owner's line on push/pop.
//! * Slots hold `MaybeUninit<T>`-style raw storage. A thief may read a
//!   slot that the owner concurrently overwrites (the classic benign
//!   Chase–Lev race); the read value is only *used* if the subsequent
//!   `top` CAS succeeds, which proves the slot was not yet reclaimed.
//! * An element is logically removed exactly once: either the owner's
//!   `pop` (bottom side, with a CAS against `top` for the last element)
//!   or a thief's successful `steal` CAS. Dropped-but-not-consumed
//!   elements are destroyed when the deque is dropped.

use std::cell::Cell;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicI64, AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::CachePadded;

/// Result of a steal attempt.
#[derive(Debug, PartialEq, Eq)]
pub enum Steal<T> {
    /// The deque was observed empty.
    Empty,
    /// Lost a race (another thief or the owner took the element);
    /// retrying immediately may succeed.
    Retry,
    /// Stole an element.
    Success(T),
}

impl<T> Steal<T> {
    /// Converts to `Option`, mapping both `Empty` and `Retry` to `None`.
    pub fn success(self) -> Option<T> {
        match self {
            Steal::Success(t) => Some(t),
            _ => None,
        }
    }

    /// True if the deque was observed empty.
    pub fn is_empty(&self) -> bool {
        matches!(self, Steal::Empty)
    }
}

/// A growable circular buffer of raw slots.
struct Buffer<T> {
    ptr: *mut MaybeUninit<T>,
    cap: usize, // power of two
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        let mut slots = Vec::<MaybeUninit<T>>::with_capacity(cap);
        // SAFETY: capacity was just reserved; the slots stay logically
        // uninitialized (MaybeUninit) so setting len is sound.
        unsafe { slots.set_len(cap) };
        let boxed = slots.into_boxed_slice();
        let ptr = Box::into_raw(boxed) as *mut MaybeUninit<T>;
        Box::into_raw(Box::new(Buffer { ptr, cap }))
    }

    /// # Safety
    /// `buf` must have been produced by [`Buffer::alloc`] and not freed.
    unsafe fn dealloc(buf: *mut Buffer<T>) {
        let b = Box::from_raw(buf);
        drop(Vec::from_raw_parts(b.ptr, 0, b.cap)); // slots themselves are not dropped
    }

    #[inline]
    fn slot(&self, index: i64) -> *mut MaybeUninit<T> {
        // cap is a power of two; index is monotone, wrap with a mask.
        unsafe { self.ptr.add(index as usize & (self.cap - 1)) }
    }

    /// # Safety: slot must hold an initialized value that this call
    /// uniquely consumes (or whose consumption is validated by a later
    /// successful CAS that proves ownership).
    #[inline]
    unsafe fn read(&self, index: i64) -> MaybeUninit<T> {
        ptr::read(self.slot(index))
    }

    /// # Safety: owner-only; `index` must be outside the live range of
    /// any thief-validated read (guaranteed by the Chase–Lev protocol).
    #[inline]
    unsafe fn write(&self, index: i64, value: T) {
        ptr::write(self.slot(index), MaybeUninit::new(value));
    }
}

struct Inner<T> {
    /// Next index to steal from. Thieves CAS this upward.
    top: CachePadded<AtomicI64>,
    /// Next index to push at. Owner-only store.
    bottom: CachePadded<AtomicI64>,
    /// Current buffer. Owner swaps on grow; thieves read with Acquire.
    buffer: AtomicPtr<Buffer<T>>,
    /// Buffers retired by `grow`, freed when the deque drops. Keeping
    /// them alive makes stale-pointer reads by racing thieves safe
    /// without an epoch/hazard-pointer scheme — bounded by log2(maxlen)
    /// buffers totalling < 2x the peak buffer size.
    retired: Mutex<Vec<*mut Buffer<T>>>,
}

unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Exclusive access: drain remaining elements, then free buffers.
        let top = self.top.load(Ordering::Relaxed);
        let bottom = self.bottom.load(Ordering::Relaxed);
        let buf = self.buffer.load(Ordering::Relaxed);
        unsafe {
            let mut i = top;
            while i < bottom {
                drop((*buf).read(i).assume_init());
                i += 1;
            }
            Buffer::dealloc(buf);
            for &old in self.retired.lock().unwrap().iter() {
                Buffer::dealloc(old);
            }
        }
    }
}

/// Owner handle: `push` and `pop`. Not `Sync`/`Clone` — exactly one
/// thread may own the bottom end, which is what makes the paper's
/// thread-local-registration trick necessary in the pool.
pub struct Worker<T> {
    inner: Arc<Inner<T>>,
    /// Cached bottom to avoid an atomic load on push; only the owner
    /// mutates bottom so the cache is always exact.
    bottom_cache: Cell<i64>,
    _not_sync: PhantomData<*mut ()>,
}

unsafe impl<T: Send> Send for Worker<T> {}

/// Thief handle: `steal`. Cheap to clone and share.
pub struct Stealer<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Stealer<T> {
    fn clone(&self) -> Self {
        Stealer {
            inner: self.inner.clone(),
        }
    }
}

/// Creates a deque with the given initial capacity (rounded up to a
/// power of two, minimum 2), returning the owner and a thief handle.
pub fn deque<T: Send>(min_capacity: usize) -> (Worker<T>, Stealer<T>) {
    let cap = min_capacity.next_power_of_two().max(2);
    let inner = Arc::new(Inner {
        top: CachePadded::new(AtomicI64::new(0)),
        bottom: CachePadded::new(AtomicI64::new(0)),
        buffer: AtomicPtr::new(Buffer::<T>::alloc(cap)),
        retired: Mutex::new(Vec::new()),
    });
    (
        Worker {
            inner: inner.clone(),
            bottom_cache: Cell::new(0),
            _not_sync: PhantomData,
        },
        Stealer { inner },
    )
}

impl<T: Send> Worker<T> {
    /// Pushes an element at the bottom. Owner-only. Grows on overflow.
    pub fn push(&self, value: T) {
        let b = self.bottom_cache.get();
        let t = self.inner.top.load(Ordering::Acquire);
        let mut buf = self.inner.buffer.load(Ordering::Relaxed);

        // SAFETY: owner-only access to bottom; len computed from our own
        // cached bottom and an Acquire top is a lower bound on free space.
        unsafe {
            if b - t >= (*buf).cap as i64 {
                buf = self.grow(t, b, buf);
            }
            (*buf).write(b, value);
        }
        // Release: the slot write must be visible before the new bottom
        // (pairs with the thief's Acquire-or-stronger bottom load).
        // Filament stores seq_cst here, but the push side needs no
        // store-load barrier — only pop does, and its SeqCst fetch_sub
        // provides it (crossbeam uses Release here too). Measured: a
        // SeqCst store is an XCHG on x86 and cost ~15% on the owner
        // path (EXPERIMENTS.md §Perf iteration 2).
        self.inner.bottom.store(b + 1, Ordering::Release);
        self.bottom_cache.set(b + 1);
    }

    /// Pops an element from the bottom. Owner-only.
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom_cache.get();
        let t_approx = self.inner.top.load(Ordering::Relaxed);
        if t_approx >= b {
            // Fast path: certainly empty (top only moves up).
            return None;
        }

        // Reserve the bottom element: publish bottom = b - 1 and *then*
        // read top. fetch_sub is a read-modify-write with SeqCst, which
        // gives the store-load barrier between our bottom store and the
        // top load that the fence-based variant gets from
        // atomic_thread_fence(seq_cst) — this is the fence-free trick.
        let b = self.inner.bottom.fetch_sub(1, Ordering::SeqCst) - 1;
        self.bottom_cache.set(b);
        let buf = self.inner.buffer.load(Ordering::Relaxed);
        let t = self.inner.top.load(Ordering::SeqCst);

        if t < b {
            // More than one element; no thief can take the bottom one.
            // SAFETY: indices t..=b are initialized; we uniquely consume b.
            return Some(unsafe { (*buf).read(b).assume_init() });
        }

        let result = if t == b {
            // Exactly one element: race the thieves with a CAS on top.
            // SAFETY: validated by the CAS below before being used.
            let value = unsafe { (*buf).read(b) };
            if self
                .inner
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                // SAFETY: CAS success proves we own index b.
                Some(unsafe { value.assume_init() })
            } else {
                // A thief won; the value was moved out by the thief's
                // read — ours is a phantom copy we must forget, which
                // MaybeUninit does by simply not calling assume_init.
                None
            }
        } else {
            // t > b: deque was empty and a thief moved top past us.
            None
        };

        // Restore bottom to its pre-pop value (b + 1). Combined with the
        // CAS above this re-establishes the canonical empty state
        // bottom == top whether we won (top = b + 1) or lost the race.
        self.inner.bottom.store(b + 1, Ordering::SeqCst);
        self.bottom_cache.set(b + 1);
        result
    }

    /// Number of elements, as seen by the owner (exact between its own
    /// push/pop calls, approximate under concurrent steals).
    pub fn len(&self) -> usize {
        let b = self.bottom_cache.get();
        let t = self.inner.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// True if empty from the owner's perspective.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Returns a new thief handle.
    pub fn stealer(&self) -> Stealer<T> {
        Stealer {
            inner: self.inner.clone(),
        }
    }

    /// Doubles the buffer, copying live elements `t..b`. Owner-only.
    ///
    /// # Safety
    /// `old` must be the current buffer; `t..b` must be the live range.
    unsafe fn grow(&self, t: i64, b: i64, old: *mut Buffer<T>) -> *mut Buffer<T> {
        let new = Buffer::<T>::alloc(((*old).cap * 2).max(2));
        let mut i = t;
        while i < b {
            ptr::copy_nonoverlapping((*old).slot(i), (*new).slot(i), 1);
            i += 1;
        }
        // Publish the new buffer before any subsequent bottom bump.
        self.inner.buffer.store(new, Ordering::Release);
        // Old buffer stays alive for racing thieves; freed on drop.
        self.inner.retired.lock().unwrap().push(old);
        new
    }
}

/// Most elements one [`Stealer::steal_batch_and_pop`] call moves into
/// the destination deque (in addition to the element it returns).
/// Matches crossbeam-deque's bound; keeps a thief from draining a
/// victim wholesale and bounds the latency of one steal visit.
pub const MAX_STEAL_BATCH: usize = 32;

impl<T: Send> Stealer<T> {
    /// Attempts to steal one element from the top.
    pub fn steal(&self) -> Steal<T> {
        let t = self.inner.top.load(Ordering::SeqCst);
        let b = self.inner.bottom.load(Ordering::SeqCst);
        if t >= b {
            return Steal::Empty;
        }
        // Acquire pairs with the Release store in `grow`, so the buffer
        // we read contains the elements published up to `b`.
        let buf = self.inner.buffer.load(Ordering::Acquire);
        // SAFETY: speculative read; only used if the CAS validates it.
        let value = unsafe { (*buf).read(t) };
        if self
            .inner
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            // SAFETY: CAS success proves index t belonged to us.
            Steal::Success(unsafe { value.assume_init() })
        } else {
            // Lost to the owner or another thief; value is a phantom
            // copy and must not be dropped.
            Steal::Retry
        }
    }

    /// Steals up to half of the victim's elements (bounded by
    /// [`MAX_STEAL_BATCH`]): the first stolen element is returned for
    /// immediate execution, the rest are pushed onto `dest` — which the
    /// calling thread must own (`Worker` is `!Sync`, so holding `&dest`
    /// proves that).
    ///
    /// Implemented as a short loop of single-element steals. A batched
    /// top-CAS (claiming `t..t+k` in one shot, as crossbeam does for
    /// FIFO deques) is **unsound** against a LIFO owner: `pop` takes
    /// `bottom - 1` without touching `top` whenever it observes more
    /// than one element, so a multi-slot claim based on a stale
    /// `bottom` could overlap slots the owner has already consumed.
    /// Per-element CAS keeps the proven exactly-once protocol while
    /// still amortizing the find-task sweep, the metrics bumps, and
    /// the park/wake round-trips over the whole batch.
    pub fn steal_batch_and_pop(&self, dest: &Worker<T>) -> Steal<T> {
        self.steal_batch_and_pop_counted(dest).0
    }

    /// [`Stealer::steal_batch_and_pop`], additionally returning how
    /// many extra elements were moved into `dest` (for scheduler
    /// metrics).
    pub fn steal_batch_and_pop_counted(&self, dest: &Worker<T>) -> (Steal<T>, usize) {
        // Size the batch from a pre-steal snapshot: half of what is
        // observably available, at least the one element we return.
        let t = self.inner.top.load(Ordering::SeqCst);
        let b = self.inner.bottom.load(Ordering::SeqCst);
        let available = b - t;
        if available <= 0 {
            return (Steal::Empty, 0);
        }
        let first = match self.steal() {
            Steal::Success(v) => v,
            other => return (other, 0),
        };
        let want = ((available as usize + 1) / 2).min(MAX_STEAL_BATCH).saturating_sub(1);
        let mut extra = 0usize;
        while extra < want {
            match self.steal() {
                Steal::Success(v) => {
                    dest.push(v);
                    extra += 1;
                }
                // Empty: the victim drained; Retry: someone else is
                // racing us — either way we already have work, go run it.
                _ => break,
            }
        }
        (Steal::Success(first), extra)
    }

    /// Approximate length (may be stale immediately).
    pub fn len(&self) -> usize {
        let t = self.inner.top.load(Ordering::Relaxed);
        let b = self.inner.bottom.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Approximate emptiness check used by the pool before parking.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> std::fmt::Debug for Worker<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Worker").field("len", &(self.bottom_cache.get())).finish()
    }
}

impl<T> std::fmt::Debug for Stealer<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Stealer").finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn lifo_for_owner() {
        let (w, _s) = deque::<i32>(4);
        w.push(1);
        w.push(2);
        w.push(3);
        assert_eq!(w.pop(), Some(3));
        assert_eq!(w.pop(), Some(2));
        assert_eq!(w.pop(), Some(1));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn fifo_for_thief() {
        let (w, s) = deque::<i32>(4);
        w.push(1);
        w.push(2);
        w.push(3);
        assert_eq!(s.steal().success(), Some(1));
        assert_eq!(s.steal().success(), Some(2));
        assert_eq!(w.pop(), Some(3));
        assert!(s.steal().is_empty());
    }

    #[test]
    fn grows_past_initial_capacity() {
        let (w, s) = deque::<usize>(2);
        for i in 0..1000 {
            w.push(i);
        }
        assert_eq!(w.len(), 1000);
        assert_eq!(s.steal().success(), Some(0));
        for i in (1..1000).rev() {
            assert_eq!(w.pop(), Some(i));
        }
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn interleaved_push_pop_steal() {
        let (w, s) = deque::<usize>(4);
        for round in 0..50 {
            for i in 0..10 {
                w.push(round * 10 + i);
            }
            let mut got = 0;
            while got < 5 {
                if s.steal().success().is_some() {
                    got += 1;
                }
            }
            for _ in 0..5 {
                assert!(w.pop().is_some());
            }
            assert!(w.is_empty());
        }
    }

    #[test]
    fn steal_batch_takes_half_and_pops_one() {
        let (victim, thief) = deque::<usize>(16);
        let (mine, _s) = deque::<usize>(16);
        for i in 0..10 {
            victim.push(i);
        }
        let (got, extra) = thief.steal_batch_and_pop_counted(&mine);
        // Oldest element comes back for immediate execution; roughly
        // half of the rest lands in our deque.
        assert_eq!(got.success(), Some(0));
        assert_eq!(extra, 4); // ceil(10/2) - 1
        assert_eq!(mine.len(), 4);
        assert_eq!(victim.len(), 5);
        // Moved elements preserve steal (FIFO) order under owner pop
        // reversal: mine holds 1,2,3,4 bottom-most last.
        assert_eq!(mine.pop(), Some(4));
        assert_eq!(mine.pop(), Some(3));
    }

    #[test]
    fn steal_batch_on_empty_and_singleton() {
        let (victim, thief) = deque::<usize>(4);
        let (mine, _s) = deque::<usize>(4);
        assert!(thief.steal_batch_and_pop(&mine).is_empty());
        victim.push(42);
        let (got, extra) = thief.steal_batch_and_pop_counted(&mine);
        assert_eq!(got.success(), Some(42));
        assert_eq!(extra, 0);
        assert!(mine.is_empty());
    }

    #[test]
    fn steal_batch_respects_max() {
        let (victim, thief) = deque::<usize>(8);
        let (mine, _s) = deque::<usize>(8);
        for i in 0..1000 {
            victim.push(i);
        }
        let (got, extra) = thief.steal_batch_and_pop_counted(&mine);
        assert_eq!(got.success(), Some(0));
        assert_eq!(extra, MAX_STEAL_BATCH - 1);
    }

    #[test]
    fn drop_releases_remaining_elements() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        {
            let (w, _s) = deque::<D>(2);
            for _ in 0..10 {
                w.push(D);
            }
            w.pop().unwrap();
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn concurrent_owner_vs_thieves_each_item_once() {
        const ITEMS: usize = 20_000;
        const THIEVES: usize = 3;
        let (w, s) = deque::<usize>(8);
        let seen = Arc::new((0..ITEMS).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let handles: Vec<_> = (0..THIEVES)
            .map(|_| {
                let s = s.clone();
                let seen = seen.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut count = 0usize;
                    loop {
                        match s.steal() {
                            Steal::Success(v) => {
                                seen[v].fetch_add(1, Ordering::Relaxed);
                                count += 1;
                            }
                            Steal::Empty => {
                                if done.load(Ordering::Acquire) {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                            Steal::Retry => {}
                        }
                    }
                    count
                })
            })
            .collect();

        let mut popped = 0usize;
        for i in 0..ITEMS {
            w.push(i);
            if i % 3 == 0 {
                if let Some(v) = w.pop() {
                    seen[v].fetch_add(1, Ordering::Relaxed);
                    popped += 1;
                }
            }
        }
        while let Some(v) = w.pop() {
            seen[v].fetch_add(1, Ordering::Relaxed);
            popped += 1;
        }
        done.store(true, Ordering::Release);
        let stolen: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();

        assert_eq!(popped + stolen, ITEMS);
        for (i, c) in seen.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} seen wrong number of times");
        }
    }
}
