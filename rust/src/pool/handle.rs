//! Typed task results: `submit_with_result` returns a [`TaskHandle`]
//! that can be joined for the task's return value.
//!
//! The paper's API is fire-and-forget (`void()` tasks, outputs through
//! captures, §4.1); this is the obvious quality-of-life extension —
//! a miniature `std::thread::JoinHandle` backed by the pool:
//!
//! ```
//! use scheduling::pool::ThreadPool;
//!
//! let pool = ThreadPool::new(2);
//! let h = pool.submit_with_result(|| 6 * 7);
//! assert_eq!(h.join().unwrap(), 42);
//! ```

use std::sync::{Arc, Condvar, Mutex};

use super::thread_pool::ThreadPool;

/// Result slot states.
enum Slot<T> {
    Pending,
    Ready(T),
    Panicked(String),
    Taken,
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Error returned by [`TaskHandle::join`] when the task panicked.
#[derive(Debug, PartialEq, Eq)]
pub struct JoinError {
    /// Rendered panic message.
    pub message: String,
}

impl std::fmt::Display for JoinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "task panicked: {}", self.message)
    }
}

impl std::error::Error for JoinError {}

/// Handle to a task's eventual result. See module docs.
#[must_use = "join() the handle or the result is lost"]
pub struct TaskHandle<T> {
    shared: Arc<Shared<T>>,
}

impl<T> TaskHandle<T> {
    /// Blocks until the task finishes; returns its value, or the panic
    /// message if it panicked.
    ///
    /// Must not be called from a worker of the same pool (it blocks;
    /// with one worker it would deadlock).
    pub fn join(self) -> Result<T, JoinError> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(v) => return Ok(v),
                Slot::Panicked(message) => return Err(JoinError { message }),
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = self.shared.cv.wait(slot).unwrap();
                }
                Slot::Taken => unreachable!("join consumes the handle"),
            }
        }
    }

    /// Non-blocking poll: `Some(result)` once finished.
    pub fn try_join(self) -> Result<Result<T, JoinError>, Self> {
        let mut slot = self.shared.slot.lock().unwrap();
        match std::mem::replace(&mut *slot, Slot::Taken) {
            Slot::Ready(v) => Ok(Ok(v)),
            Slot::Panicked(message) => Ok(Err(JoinError { message })),
            Slot::Pending => {
                *slot = Slot::Pending;
                drop(slot);
                Err(self)
            }
            Slot::Taken => unreachable!(),
        }
    }

    /// True once the task has finished (without consuming the handle).
    pub fn is_finished(&self) -> bool {
        !matches!(*self.shared.slot.lock().unwrap(), Slot::Pending)
    }
}

impl ThreadPool {
    /// Submits a value-returning task; the result is retrieved through
    /// the returned [`TaskHandle`]. Panics inside the task are captured
    /// and surfaced as [`JoinError`] (they do not count toward
    /// [`ThreadPool::panic_count`] — the handle owns the outcome).
    pub fn submit_with_result<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot::Pending),
            cv: Condvar::new(),
        });
        let s2 = shared.clone();
        self.submit(move || {
            let outcome = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                Ok(v) => Slot::Ready(v),
                Err(payload) => Slot::Panicked(
                    payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "<non-string panic payload>".to_string()),
                ),
            };
            *s2.slot.lock().unwrap() = outcome;
            s2.cv.notify_all();
        });
        TaskHandle { shared }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn join_returns_value() {
        let pool = ThreadPool::new(2);
        let h = pool.submit_with_result(|| "hello".to_string());
        assert_eq!(h.join().unwrap(), "hello");
    }

    #[test]
    fn join_surfaces_panic_message() {
        let pool = ThreadPool::new(2);
        let h = pool.submit_with_result(|| -> u32 { panic!("typed boom") });
        let err = h.join().unwrap_err();
        assert!(err.message.contains("typed boom"));
        // Handle-owned panics are not pool-level panics.
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 0);
    }

    #[test]
    fn try_join_pending_then_ready() {
        let pool = ThreadPool::new(1);
        let h = pool.submit_with_result(|| {
            std::thread::sleep(Duration::from_millis(30));
            7u32
        });
        // Either still pending (expected) or already done on a fast box.
        match h.try_join() {
            Err(h) => {
                pool.wait_idle();
                assert!(h.is_finished());
                match h.try_join() {
                    Ok(v) => assert_eq!(v.unwrap(), 7),
                    Err(_) => panic!("task finished but try_join still pending"),
                }
            }
            Ok(v) => assert_eq!(v.unwrap(), 7),
        }
    }

    #[test]
    fn many_handles_fan_in() {
        let pool = ThreadPool::new(3);
        let handles: Vec<_> = (0..64u64).map(|i| pool.submit_with_result(move || i * i)).collect();
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, (0..64u64).map(|i| i * i).sum());
    }

    #[test]
    fn is_finished_without_consuming() {
        let pool = ThreadPool::new(1);
        let h = pool.submit_with_result(|| 1);
        pool.wait_idle();
        assert!(h.is_finished());
        assert_eq!(h.join().unwrap(), 1);
    }
}
