//! Scoped tasks: submit borrowing tasks, block until they finish.
//!
//! The paper's C++ tasks capture locals by reference and the user is
//! on their own to keep them alive; in Rust that pattern needs a
//! scope (same shape as `std::thread::scope`): tasks submitted through
//! a [`Scope`] may borrow from the enclosing stack frame, and
//! [`ThreadPool::scope`] does not return until every scoped task has
//! completed, making those borrows sound.
//!
//! ```
//! use scheduling::pool::ThreadPool;
//!
//! let pool = ThreadPool::new(4);
//! let mut parts = vec![0u64; 8];
//! let input: Vec<u64> = (0..8_000).collect();
//! pool.scope(|s| {
//!     for (i, chunk) in parts.iter_mut().zip(input.chunks(1000)) {
//!         s.submit(move || *i = chunk.iter().sum());
//!     }
//! });
//! assert_eq!(parts.iter().sum::<u64>(), (0..8_000).sum());
//! ```

use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::thread_pool::ThreadPool;

struct ScopeState {
    /// Scoped tasks submitted but not finished.
    active: AtomicUsize,
    done_mutex: Mutex<()>,
    done_cv: Condvar,
    /// First panic payload from a scoped task, rethrown by `scope`.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Handle for submitting borrowing tasks; see module docs.
///
/// Lifetimes mirror `std::thread::Scope`: `'scope` is the scope of the
/// spawned tasks (invariant), `'env` the environment they may borrow
/// from; the `'env: 'scope` bound is what lets the HRTB in
/// [`ThreadPool::scope`] instantiate `'scope` below the borrowed data.
pub struct Scope<'scope, 'env: 'scope> {
    pool: &'env ThreadPool,
    state: Arc<ScopeState>,
    scope_marker: PhantomData<&'scope mut &'scope ()>,
    env_marker: PhantomData<&'env mut &'env ()>,
}

impl<'scope> Scope<'scope, '_> {
    /// Submits a task that may borrow anything outliving `'scope`.
    pub fn submit<F>(&'scope self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        self.state.active.fetch_add(1, Ordering::SeqCst);
        let state = self.state.clone();
        // SAFETY: the closure (and everything it borrows, bounded by
        // 'scope) outlives its execution because `scope` blocks until
        // `active` reaches zero before returning — the same argument
        // as std::thread::scope. The transmute only erases 'scope.
        let boxed: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        let boxed: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(boxed) };
        self.pool.submit(move || {
            if let Err(payload) = std::panic::catch_unwind(AssertUnwindSafe(boxed)) {
                let mut p = state.panic.lock().unwrap();
                if p.is_none() {
                    *p = Some(payload);
                }
            }
            if state.active.fetch_sub(1, Ordering::SeqCst) == 1 {
                drop(state.done_mutex.lock().unwrap());
                state.done_cv.notify_all();
            }
        });
    }
}

impl ThreadPool {
    /// Runs `f` with a [`Scope`]; blocks until every task submitted
    /// through the scope (including tasks submitted by those tasks)
    /// has finished. If any scoped task panicked, the first panic is
    /// resumed on the caller after all tasks drain — mirroring
    /// `std::thread::scope`.
    ///
    /// Must be called from a non-worker thread (it blocks). The same
    /// rule covers every blocking wait on a pool from inside its own
    /// tasks — `wait_idle`, `scope`, and `graph::RunHandle::wait`
    /// alike: a scoped task that holds a run handle for this pool gets
    /// `GraphError::RunFromWorker` from `wait()` rather than a
    /// deadlock, and blocking waits against a *different* pool remain
    /// fine (the guards are per-pool).
    pub fn scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> R,
    {
        debug_assert!(
            self.current_worker().is_none() && !self.inner().on_assisting_thread(),
            "ThreadPool::scope called from inside a task of the same pool (would deadlock)"
        );
        let state = Arc::new(ScopeState {
            active: AtomicUsize::new(0),
            done_mutex: Mutex::new(()),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        let scope = Scope {
            pool: self,
            state: state.clone(),
            scope_marker: PhantomData,
            env_marker: PhantomData,
        };
        // Run the scope body; even if it panics we must wait for
        // already-submitted tasks before unwinding (their borrows die
        // with this frame).
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| f(&scope)));

        let mut guard = state.done_mutex.lock().unwrap();
        while state.active.load(Ordering::SeqCst) != 0 {
            guard = state.done_cv.wait(guard).unwrap();
        }
        drop(guard);

        if let Some(payload) = state.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
        match result {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrows_local_slices() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..10_000).collect();
        let mut partials = [0u64; 10];
        pool.scope(|s| {
            for (out, chunk) in partials.iter_mut().zip(data.chunks(1000)) {
                s.submit(move || {
                    *out = chunk.iter().sum();
                });
            }
        });
        assert_eq!(partials.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn scope_returns_closure_value() {
        let pool = ThreadPool::new(1);
        let n = pool.scope(|s| {
            s.submit(|| {});
            42
        });
        assert_eq!(n, 42);
    }

    #[test]
    fn nested_scoped_submission() {
        // A scoped task submits more scoped tasks; all must finish
        // before scope returns. (Scope is Sync: share it by reference.)
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..10 {
                let counter = &counter;
                s.submit(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scoped_panic_propagates_after_drain() {
        let pool = ThreadPool::new(2);
        let finished = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.submit(|| panic!("scoped boom"));
                for _ in 0..20 {
                    let finished = &finished;
                    s.submit(move || {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                        finished.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }));
        assert!(result.is_err(), "scope should rethrow the task panic");
        // All sibling tasks drained before the rethrow.
        assert_eq!(finished.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn sequential_scopes_reuse_pool() {
        let pool = ThreadPool::new(2);
        for round in 1..=5 {
            let hits = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..round {
                    let hits = &hits;
                    s.submit(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
            assert_eq!(hits.load(Ordering::SeqCst), round);
        }
    }

    #[test]
    fn empty_scope_is_fine() {
        let pool = ThreadPool::new(1);
        pool.scope(|_s| {});
    }

    #[test]
    fn scoped_task_graph_guards_are_per_pool() {
        // A scoped task of pool A may run (and block on) graphs
        // targeting pool B — sync and async alike — but blocking waits
        // against its OWN pool are rejected deterministically.
        use crate::graph::{GraphError, TaskGraph};
        use std::sync::atomic::AtomicUsize;

        let pool_a = ThreadPool::new(1);
        let pool_b = ThreadPool::new(1);
        let hits = AtomicUsize::new(0);
        pool_a.scope(|s| {
            let (hits, pool_a, pool_b) = (&hits, &pool_a, &pool_b);
            s.submit(move || {
                // Other pool: sync run works...
                let mut g = TaskGraph::new();
                g.add(|| {});
                g.run(pool_b).unwrap();
                // ...and an async handle can be waited on.
                let h = g.run_async(pool_b).unwrap();
                h.wait().unwrap();
                // Own pool: launch is rejected, not deadlocked.
                let mut own = TaskGraph::new();
                own.add(|| {});
                assert!(matches!(own.run_async(pool_a), Err(GraphError::RunFromWorker)));
                hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
